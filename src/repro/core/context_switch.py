"""Context switches: saving/restoring vector state under preemption.

AraOS §3.1: a context switch between two vector processes saves and restores
the vector state (VRF + vector CSRs) at memory bandwidth — ~3.2 k cycles for
an 8-KiB VRF over a 64-bit/cycle path (vs ~1 k cycles scalar-only).

Serving analogue: when the page pool is exhausted (OutOfPagesError) or the
scheduler quantum expires, a victim request is *preempted*: its vector state
(KV pages / recurrent-state slab + sampler state + resume cursor) is spilled
to a host-side swap area, its frames are freed, and it is re-mapped and
restored later.  The cost is measured in real bytes moved and reported in
modeled AraOS cycles so the §3.1 comparison is direct.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel
from repro.core.vmem import VirtualMemory


@dataclasses.dataclass
class SpilledState:
    """Swap-area record for one preempted request."""

    seq_id: int
    num_tokens: int
    page_data: np.ndarray            # [n_pages, ...] copied out of the pool
    extra_state: Any = None          # sampler state, resume cursor, ...
    bytes_moved: int = 0


@dataclasses.dataclass
class SwitchStats:
    """Accounting mirrored on the paper's measurements."""

    switches: int = 0
    bytes_spilled: int = 0
    bytes_restored: int = 0
    modeled_cycles: float = 0.0

    def modeled_seconds(self, cost: CostModel) -> float:
        return cost.seconds(self.modeled_cycles)


class ContextSwitcher:
    """Spill/restore engine over a physical KV pool.

    The pool array layout is ``[num_phys_pages, page_size, ...]`` (kernels
    index it through the page table).  Spill copies the victim's pages out in
    logical order; restore writes them into freshly allocated frames — the
    physical pages may differ, exactly as after an OS swap-in.
    """

    def __init__(self, vmem: VirtualMemory, cost: CostModel | None = None,
                 page_axis: int = 0):
        self.vmem = vmem
        self.cost = cost or CostModel()
        self.stats = SwitchStats()
        self._swap: dict[int, SpilledState] = {}
        #: which axis of the pool array indexes physical pages (stacked
        #: per-layer pools use axis=1: [L, P, page, ...])
        self.page_axis = page_axis

    # ---- spill ------------------------------------------------------------

    def spill(self, seq_id: int, pool: jnp.ndarray,
              extra_state: Any = None) -> jnp.ndarray:
        """Preempt ``seq_id``: copy its pages out, free its frames.

        Returns the pool (unchanged — data in freed frames is dead, exactly
        like freed physical memory).
        """
        state = self.vmem.seq(seq_id)
        pages = np.asarray(state.pages, dtype=np.int32)
        page_data = np.asarray(
            jnp.take(pool, jnp.asarray(pages), axis=self.page_axis)
        )
        nbytes = int(page_data.nbytes)
        self._swap[seq_id] = SpilledState(
            seq_id=seq_id,
            num_tokens=state.length,
            page_data=page_data,
            extra_state=extra_state,
            bytes_moved=nbytes,
        )
        self.vmem.spill_seq(seq_id)
        self.stats.switches += 1
        self.stats.bytes_spilled += nbytes
        self.stats.modeled_cycles += (
            self.cost.scalar_ctx_switch_cycles
            + self.cost.bytes_move_cycles(nbytes)
        )
        return pool

    # ---- restore ------------------------------------------------------------

    def can_restore(self, seq_id: int) -> bool:
        if seq_id not in self._swap:
            return False
        spilled = self._swap[seq_id]
        need = self.vmem.config.pages_for(spilled.num_tokens)
        return self.vmem.pool.num_free >= need and bool(self.vmem._free_slots)

    def restore(self, seq_id: int, pool: jnp.ndarray) -> tuple[jnp.ndarray, Any]:
        """Swap ``seq_id`` back in: new frames, data copied into them.

        Returns the updated pool and the request's ``extra_state``.
        Raises OutOfPagesError if frames are unavailable (caller preempts
        another victim first).
        """
        spilled = self._swap[seq_id]
        state = self.vmem.restore_seq(seq_id, spilled.num_tokens)  # may raise
        new_pages = jnp.asarray(np.asarray(state.pages, dtype=np.int32))
        if self.page_axis == 0:
            pool = pool.at[new_pages].set(jnp.asarray(spilled.page_data))
        elif self.page_axis == 1:
            pool = pool.at[:, new_pages].set(jnp.asarray(spilled.page_data))
        else:
            raise NotImplementedError(f"page_axis={self.page_axis}")
        del self._swap[seq_id]
        nbytes = int(spilled.page_data.nbytes)
        self.stats.bytes_restored += nbytes
        self.stats.modeled_cycles += self.cost.bytes_move_cycles(nbytes)
        return pool, spilled.extra_state

    @property
    def swapped_out(self) -> list[int]:
        return sorted(self._swap)
