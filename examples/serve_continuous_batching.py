"""Serving example: continuous batching with paged KV + forced preemption.

A small transformer serves a queue of batched requests through the split
serving engine — host-side Scheduler (admission, victim selection: the
CVA6/OS plane) driving a device-resident Executor (KV pools, persistent
delta-updated page table, page-granular spills: the Ara2 data plane).
The pool is deliberately undersized, so the scheduler must take page
faults (on-demand allocation) and context-switch requests out and back in
(the paper's §3.1 measurement, reproduced functionally).  Outputs are
verified identical to a run with an abundant pool — preemption
transparency.

Run:  PYTHONPATH=src python examples/serve_continuous_batching.py
"""

import copy
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import CostModel
from repro.models import build_model
from repro.serve import Engine, ServeConfig, ServeRequest


def make_requests(cfg, n=8, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            req_id=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(5, 14))
            ).astype(np.int32),
            max_new_tokens=16,
        )
        for i in range(n)
    ]


def run(engine_cfg, reqs, model, params):
    eng = Engine(model, params, engine_cfg)
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    t0 = time.perf_counter()
    done = eng.run()
    return eng, done, time.perf_counter() - t0


def main() -> None:
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    reqs = make_requests(cfg)

    # deliberately tight pool: 15 usable frames x 4 tokens = 60 tokens
    tight = ServeConfig(page_size=4, num_pages=16, max_pages_per_seq=16,
                        max_batch=3)
    roomy = ServeConfig(page_size=4, num_pages=512, max_pages_per_seq=16,
                        max_batch=8)

    eng_t, done_t, dt_t = run(tight, reqs, model, params)
    eng_r, done_r, dt_r = run(roomy, reqs, model, params)

    st = eng_t.stats()
    cost = CostModel()
    print("tight pool (preempting):")
    print(f"  page faults:      {st['counters'].get('page_faults', 0)}")
    print(f"  preemptions:      {st['counters'].get('preemptions', 0)}")
    print(f"  restores:         {st['counters'].get('restores', 0)}")
    sw = st["switch_stats"]
    print(f"  ctx-switch bytes: {sw['bytes_spilled']} spilled / "
          f"{sw['bytes_restored']} restored "
          f"({sw['pages_spilled']} page copies across K+V pools — "
          f"page-granular, never the full pool)")
    print(f"  modeled cycles:   {sw['modeled_cycles']:.0f} "
          f"(paper: ~3.2k/switch for an 8-KiB VRF; ours moves KV pages)")
    print(f"  modeled seconds @50 MHz: "
          f"{cost.seconds(sw['modeled_cycles'])*1e3:.2f} ms")
    print(f"  satp delta sync:  "
          f"{st['counters'].get('ptab_rows_uploaded', 0)} page-table rows "
          f"uploaded over {eng_t.scheduler.step_i} steps "
          f"(wholesale re-upload would be "
          f"{eng_t.scheduler.step_i * eng_t.cfg.max_batch})")

    identical = all(
        [int(x) for x in done_t[i].output] == [int(x) for x in done_r[i].output]
        for i in range(len(reqs))
    )
    print(f"\npreemption transparency: outputs identical = {identical}")
    assert identical
    assert st["counters"].get("preemptions", 0) > 0, "expected preemptions"
    print(f"(tight {dt_t:.1f}s vs roomy {dt_r:.1f}s wall on CPU interpret)")


if __name__ == "__main__":
    main()
