"""Replica sweep: ReplicaRouter over N Engines vs the N=1 plain engine.

Runs the SAME workload through the plain single-replica engine and through
a :class:`ReplicaRouter` over N in {1, 2, 4} independent Engine replicas
(least-loaded-pages placement, one KV pool + page table each) and reports,
per N:

  * token identity per request vs the N=1 reference — the router's
    correctness contract (placement must be semantically invisible; greedy
    decoding is per-sequence, so replica count cannot change a stream);
  * done-status permutation vs the reference;
  * global-accounting consistency: the router's merged page/counter view
    must equal the sum of the per-replica views
    (``ReplicaRouter.check_invariants``);
  * the amortization counters per decoded token (host syncs, ptab syncs)
    and the mean fused horizon, summed across replicas — deterministic
    scheduler events, which is what ``scripts/bench_regress.py`` gates on
    (never wall tok/s: shared-CPU wall clock swings 5x between runs).

Pools are roomy per replica: the identity claim requires staying off the
degraded growth-stall path (scratch-routed decode writes are the one
intentional stream divergence); admission still queues behind
``max_batch``, so placement, cross-replica admission and horizon
collapse/reopen all fire.

``benchmarks/run.py --only router`` gates on token identity + accounting
identity and appends the metrics to ``BENCH_serve.json`` (section
``router``).
"""

from __future__ import annotations

import copy
import time

# same workload generator / jit-cache warmer as the seed-vs-split bench
from benchmarks.bench_serve_throughput import _warm, _workload


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ReplicaRouter, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=32,
                            max_batch=3)
    reqs = _workload(cfg, n=8, seed=4, max_new=12)
    _warm(Engine, model, params, cfg, serve_cfg)

    ref = Engine(model, params, serve_cfg)
    for r in reqs:
        ref.submit(copy.deepcopy(r))
    ref_done = ref.run()
    ref_out = {i: [int(x) for x in ref_done[i].output] for i in ref_done}
    ref_statuses = sorted((i, r.status) for i, r in ref_done.items())

    sweep: dict[str, dict] = {}
    all_identical = True
    accounting_ok = True
    for n in (1, 2, 4):
        engines = [Engine(model, params, serve_cfg) for _ in range(n)]
        router = ReplicaRouter(
            [eng.as_replica(i) for i, eng in enumerate(engines)]
        )
        for r in reqs:
            router.submit(copy.deepcopy(r))
        t0 = time.perf_counter()
        done = router.run()
        wall = time.perf_counter() - t0
        out = {i: [int(x) for x in done[i].output] for i in done}
        token_identical = out == ref_out
        permuted_ok = (sorted((i, r.status) for i, r in done.items())
                       == ref_statuses)
        all_identical &= token_identical and permuted_ok
        try:
            router.check_invariants()
        except AssertionError as e:
            accounting_ok = False
            print(f"FAIL (N={n} accounting): {e}")
        total = router.global_counters()
        toks = total["decode_tokens"]
        decode_s = sum(eng.counters.seconds("decode") for eng in engines)
        sweep[str(n)] = dict(
            wall=wall,
            decode_tokens=int(toks),
            decode_tok_per_s=toks / max(decode_s, 1e-9),
            host_syncs_per_tok=total["host_syncs"] / max(toks, 1),
            ptab_syncs_per_tok=total["ptab_syncs"] / max(toks, 1),
            mean_horizon=(total["decode_horizon"]
                          / max(total["decode_dispatches"], 1)),
            placements=[
                router.counters.get(f"placements_replica{i}")
                for i in range(n)
            ],
            token_identical=bool(token_identical),
        )
        s = sweep[str(n)]
        print(f"N={n}: {s['decode_tok_per_s']:.1f} decode tok/s (summed), "
              f"{s['host_syncs_per_tok']:.3f} host syncs/tok, "
              f"{s['ptab_syncs_per_tok']:.3f} ptab syncs/tok, "
              f"mean horizon {s['mean_horizon']:.2f}, "
              f"placements {s['placements']}, "
              f"token-identical {token_identical}")

    print(f"replica sweep token-identical to N=1 reference (all N): "
          f"{all_identical}; global accounting == per-replica sums: "
          f"{accounting_ok}")
    metrics = {
        "token_identical": bool(all_identical),
        "accounting_identical": bool(accounting_ok),
        # the cross-PR regression pair (deterministic scheduler events,
        # N=2 run): see scripts/bench_regress.py
        "host_syncs_per_token": float(sweep["2"]["host_syncs_per_tok"]),
        "mean_horizon": float(sweep["2"]["mean_horizon"]),
        "sweep": sweep,
    }
    csv = [
        f"router_token_identical,0,{int(all_identical)}",
        f"router_accounting_identical,0,{int(accounting_ok)}",
        f"router_host_syncs_per_tok_n2,0,"
        f"{sweep['2']['host_syncs_per_tok']:.4f}",
        f"router_ptab_syncs_per_tok_n2,0,"
        f"{sweep['2']['ptab_syncs_per_tok']:.4f}",
        f"router_mean_horizon_n2,0,{sweep['2']['mean_horizon']:.2f}",
        f"router_decode_tok_per_s_n4,0,"
        f"{sweep['4']['decode_tok_per_s']:.2f}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
