"""Pure-jnp oracles for every kernel in this package.

Each function is the semantic ground truth its Pallas kernel is tested
against (tests/test_kernels.py sweeps shapes and dtypes with
``assert_allclose``).  They are also the *lowering path used by dry-runs*:
XLA:TPU fuses these natively, so roofline numbers derived from them reflect
what a non-Pallas implementation would cost — the Pallas kernels are the
hand-tiled fast path for real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def matmul_ref(x: jax.Array, y: jax.Array,
               out_dtype: jnp.dtype | None = None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(
        x, y, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def flash_attention_ref(
    q: jax.Array,      # [B, Hq, Sq, D]
    k: jax.Array,      # [B, Hkv, Sk, D]
    v: jax.Array,      # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, sq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        q_pos = jnp.arange(sq)[:, None]
        k_pos = jnp.arange(sk)[None, :]
        s = jnp.where(q_pos + (sk - sq) >= k_pos, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def chunked_attention_ref(
    q: jax.Array,      # [B, Hq, Sq, D]
    k: jax.Array,      # [B, Hkv, Sk, D]
    v: jax.Array,      # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bk: int = 512,
) -> jax.Array:
    """Online-softmax attention chunked over KV — pure jnp, differentiable.

    The XLA-native flash restatement: a ``lax.scan`` over KV blocks with a
    running (max, normalizer, accumulator) carry.  Peak live memory is one
    ``[B, Hq, Sq, bk]`` score block instead of the full [Sq, Sk] matrix —
    this is what makes 32k-token prefill and 4k training *fit* without the
    Pallas kernel (dry-run memory_analysis is the proof).  ``window``
    restricts keys to ``(q_pos - window, q_pos]`` (RG local attention).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    nb = -(-sk // bk)
    pad = nb * bk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, hkv, g, sq, d)
    q_pos = jnp.arange(sq) + (sk - sq)  # diagonal anchored at the end
    kb = k.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk_i = xs                     # [B, Hkv, bk, D]
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
            kblk.astype(jnp.float32),
        ) * scale                                   # [B,Hkv,G,Sq,bk]
        k_pos = blk_i * bk + jnp.arange(bk)
        valid = k_pos[None, :] < sk
        if causal:
            valid &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            valid &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(valid[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb, vb, jnp.arange(nb))
    )
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l[..., None]).reshape(b, hq, sq, d)
    return out.astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,            # [B, Hkv, G, D]
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    seq_lens: jax.Array,     # [B] int32
    *,
    page_size: int,
    scale: float | None = None,
    window: int | None = None,
    kv_scale: float | None = None,
) -> jax.Array:
    """Gathers logical KV through the page table, then dense attention.

    ``kv_scale``: dequantization factor for int8 KV pools (§Perf cell A —
    halves pool bytes vs bf16)."""
    b, hkv, g, d = q.shape
    max_pages = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5
    # the table may have more slots than the query batch (like the kernel,
    # only the first b rows are consulted)
    page_table = page_table[:b]
    seq_lens = seq_lens[:b]
    frames = jnp.maximum(page_table, 0)                      # [B, maxp]
    k_log = k_pool[frames]                                   # [B, maxp, page, Hkv, D]
    v_log = v_pool[frames]
    max_t = max_pages * page_size
    k_log = k_log.reshape(b, max_t, hkv, d)
    v_log = v_log.reshape(b, max_t, hkv, d)
    if kv_scale is not None:
        # int8 dequantization, rounded to the model compute dtype — the
        # same precision the kernel's in-VMEM upcast lands on, so fp-pool
        # and int8-pool paths are compared like for like.
        k_log = (k_log.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v_log = (v_log.astype(jnp.float32) * kv_scale).astype(q.dtype)
    s = jnp.einsum("bhgd,bthd->bhgt", q.astype(jnp.float32),
                   k_log.astype(jnp.float32)) * scale
    pos = jnp.arange(max_t)[None, :]
    valid = pos < seq_lens[:, None]                          # [B, maxT]
    if window is not None:
        valid &= pos >= jnp.maximum(seq_lens[:, None] - window, 0)
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    # fully-masked rows (empty sequences) -> zeros, matching the kernel
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v_log.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_prefill_attention_ref(
    q: jax.Array,            # [B, S, Hkv, G, D] chunk queries
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32 — tokens already cached per row
    *,
    page_size: int,
    scale: float | None = None,
    kv_scale: float | None = None,
) -> jax.Array:
    """Gathered-pages continuation-prefill attention (the oracle).

    Materializes the WHOLE logical prefix — ``max_pages * page_size``
    tokens — through the page table and runs dense attention with a causal
    mask on absolute positions (``k_pos <= starts[b] + t``): cache plus
    committed chunk prefix.  This is the pre-kernel hot path of
    ``TransformerLM.prefill_continue`` and the differential ground truth
    the Pallas kernel is tested against.  ``kv_scale`` dequantizes int8 KV
    pools.  Returns [B, S, Hkv, G, D]."""
    b, s, hkv, g, d = q.shape
    max_pages = page_table.shape[1]
    max_t = max_pages * page_size
    scale = scale if scale is not None else d ** -0.5
    page_table = page_table[:b]
    frames = jnp.maximum(page_table, 0)                      # [B, maxp]
    k_log = k_pool[frames].reshape(b, max_t, hkv, d)
    v_log = v_pool[frames].reshape(b, max_t, hkv, d)
    if kv_scale is not None:
        # int8 dequantization at model compute precision (see the decode
        # oracle above)
        k_log = (k_log.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v_log = (v_log.astype(jnp.float32) * kv_scale).astype(q.dtype)
    positions = starts[:b, None] + jnp.arange(s)[None, :]    # [B, S]
    k_pos = jnp.arange(max_t)[None, None, :]                 # [1,1,maxT]
    causal = k_pos <= positions[:, :, None]                  # [B,S,maxT]
    sc = jnp.einsum(
        "bshgd,bthd->bshgt", q.astype(jnp.float32),
        k_log.astype(jnp.float32),
    ) * scale
    sc = jnp.where(causal[:, :, None, None, :], sc, _NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(causal[:, :, None, None, :], p, 0.0)
    o = jnp.einsum("bshgt,bthd->bshgd", p, v_log.astype(jnp.float32))
    return o.astype(q.dtype)


def paged_copy_ref(
    src: jax.Array,          # [B, S, W]
    pool: jax.Array,         # [P, page, W]
    page_table: jax.Array,   # [B, max_pages] int32
    lens: jax.Array,         # [B] int32
    *,
    page_size: int,
) -> jax.Array:
    b, s, w = src.shape
    p, page, _ = pool.shape
    tok = jnp.arange(s)[None, :]                              # [1, S]
    valid = tok < lens[:, None]                               # [B, S]
    frames = jnp.maximum(jnp.take_along_axis(
        page_table, jnp.minimum(tok // page_size, page_table.shape[1] - 1),
        axis=1), 0)
    rows = frames * page_size + tok % page_size               # [B, S]
    trash = p * page                                          # one spare row
    rows = jnp.where(valid, rows, trash)
    flat = jnp.concatenate(
        [pool.reshape(-1, w), jnp.zeros((1, w), pool.dtype)], axis=0
    )
    flat = flat.at[rows.reshape(-1)].set(
        src.reshape(-1, w).astype(pool.dtype)
    )
    return flat[:-1].reshape(p, page, w)


def paged_copy_at_ref(
    src: jax.Array,          # [B, S, W]
    pool: jax.Array,         # [P, page, W]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32 — logical position of src[:, 0]
    lens: jax.Array,         # [B] int32
    *,
    page_size: int,
) -> jax.Array:
    """Continuation copy: token ``t`` lands at logical ``starts[b] + t``."""
    b, s, w = src.shape
    p, page, _ = pool.shape
    max_pages = page_table.shape[1]
    tok = jnp.arange(s)[None, :]                              # [1, S]
    pos = starts[:, None] + tok                               # [B, S]
    vpn = pos // page_size
    entry = jnp.take_along_axis(
        page_table, jnp.minimum(vpn, max_pages - 1), axis=1
    )
    valid = (tok < lens[:, None]) & (entry >= 0) & (vpn < max_pages)
    rows = jnp.maximum(entry, 0) * page_size + pos % page_size
    trash = p * page                                          # one spare row
    rows = jnp.where(valid, rows, trash)
    flat = jnp.concatenate(
        [pool.reshape(-1, w), jnp.zeros((1, w), pool.dtype)], axis=0
    )
    flat = flat.at[rows.reshape(-1)].set(
        src.reshape(-1, w).astype(pool.dtype)
    )
    return flat[:-1].reshape(p, page, w)


def paged_gather_ref(
    pool: jax.Array,            # [P, page, W]
    page_table_row: jax.Array,  # [max_pages] int32
    positions: jax.Array,       # [N] int32
    *,
    page_size: int,
) -> jax.Array:
    _, page, w = pool.shape
    frames = jnp.maximum(page_table_row[positions // page_size], 0)
    rows = frames * page_size + positions % page_size
    return pool.reshape(-1, w)[rows]


def wkv6_ref(
    r: jax.Array,   # [BH, T, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,   # [BH, N]
    initial_state: jax.Array | None = None,  # [BH, N, N]
) -> tuple[jax.Array, jax.Array]:
    """Returns (o [BH, T, N], final_state [BH, N, N])."""
    bh, t, n = r.shape
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bh, n, n), jnp.float32))

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                   # each [BH, N]
        kv = kt[:, :, None] * vt[:, None, :]    # [BH, N, N]
        o = jnp.einsum(
            "bi,bij->bj", rt.astype(jnp.float32),
            u[:, :, None].astype(jnp.float32) * kv + s,
        )
        s = wt[:, :, None].astype(jnp.float32) * s + kv
        return s, o

    xs = (r.swapaxes(0, 1), k.swapaxes(0, 1),
          v.swapaxes(0, 1), w.swapaxes(0, 1))
    s_fin, o = jax.lax.scan(step, s0, xs)
    return o.swapaxes(0, 1).astype(r.dtype), s_fin


def wkv6_chunked_ref(
    r: jax.Array,   # [BH, T, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,   # [BH, N]
    initial_state: jax.Array | None = None,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """wkv6_ref with chunked rematerialization.

    A plain scan over T saves an [BH, N, N] state residual per STEP for the
    backward pass — 4096-token training would retain terabytes.  Scanning
    over chunks with ``jax.checkpoint`` saves one state per CHUNK and
    recomputes the inner steps in the backward sweep (the linear-recurrence
    analogue of flash attention's recompute strategy)."""
    bh, t, n = r.shape
    if t <= chunk:
        return wkv6_ref(r, k, v, w, u, initial_state)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        zp = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, zp)
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        w = jnp.pad(w, zp, constant_values=1.0)  # identity decay
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bh, n, n), jnp.float32))
    split = lambda z: z.reshape(bh, nc, chunk, n).swapaxes(0, 1)

    @jax.checkpoint
    def outer(s, xs):
        rc, kc, vc, wc = xs
        o, s2 = wkv6_ref(rc, kc, vc, wc, u, s)
        return s2, o

    s_fin, o = jax.lax.scan(outer, s0, (split(r), split(k), split(v), split(w)))
    o = o.swapaxes(0, 1).reshape(bh, nc * chunk, n)
    return o[:, :t], s_fin


def wkv6_chunked_matmul_ref(
    r: jax.Array,   # [BH, T, N]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,   # decay in (0, 1)
    u: jax.Array,   # [BH, N]
    initial_state: jax.Array | None = None,
    chunk: int = 64,
) -> tuple[jax.Array, jax.Array]:
    """Chunk-parallel WKV (flash-linear-attention formulation) — §Perf C.

    The sequential recurrence streams the [N, N] state through HBM every
    token; this reformulation processes ``chunk`` tokens per step with
    dense matmuls, so state traffic drops by the chunk length and the
    arithmetic feeds the MXU:

      intra-chunk:  o_i += ((r_i * A_i) (k_j / A_j)^T  masked j<i) v_j
                    + diagonal u-bonus term
      inter-chunk:  o_i += (r_i * A_i) S_prev
      state update: S   = D_C * S_prev + sum_j (D_C / A_j prefix) k_j v_j^T

    where ``A_i = prod_{j<=i-1} w_j`` within the chunk (exclusive cumulative
    decay) and ``D_C`` the full-chunk decay.  All cross-position factors are
    expressed as exp(cum_i - cum_j) with i >= j, so every exponent is <= 0 —
    no overflow, and underflow only where the contribution is genuinely
    negligible.  Exactly equal to ``wkv6_ref`` (tests sweep both).
    """
    bh, t, n = r.shape
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        zp = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, zp)
        k = jnp.pad(k, zp)
        v = jnp.pad(v, zp)
        w = jnp.pad(w, zp, constant_values=1.0)
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((bh, n, n), jnp.float32))
    f32 = lambda z: z.astype(jnp.float32)
    split = lambda z: f32(z).reshape(bh, nc, chunk, n).swapaxes(0, 1)
    rc, kc, vc, wc = split(r), split(k), split(v), split(w)

    def one_chunk(s, xs):
        rr, kk, vv, ww = xs                        # [BH, C, N]
        logw = jnp.log(jnp.maximum(ww, 1e-38))
        cum = jnp.cumsum(logw, axis=1)             # inclusive: sum_{j<=i}
        cum_excl = cum - logw                      # exclusive: sum_{j<i}
        a_in = jnp.exp(cum_excl)                   # decay from chunk start
        # inter-chunk: r_i * A_i @ S_prev
        o = jnp.einsum("bcn,bnm->bcm", rr * a_in, s)
        # intra-chunk: exp(cum_excl_i - cum_j) for j < i  (<= 0 exponents;
        # mask in LOG space — masked entries would have positive exponents
        # and exp-overflow before the mask could zero them)
        delta = cum_excl[:, :, None, :] - cum[:, None, :, :]   # [BH,C,C,N]
        mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
        delta = jnp.where(mask[None, :, :, None], delta, -jnp.inf)
        att = jnp.einsum("bin,bjn,bijn->bij", rr, kk, jnp.exp(delta))
        o = o + jnp.einsum("bij,bjm->bim", att, vv)
        # diagonal bonus: u * k_i v_i at the self position
        o = o + (
            (rr * u[:, None, :].astype(jnp.float32) * kk).sum(-1, keepdims=True)
            * vv
        )
        # state: S = D_C S + sum_j exp(cum_C - cum_j) k_j v_j^T
        d_c = jnp.exp(cum[:, -1, :])               # [BH, N]
        tail = jnp.exp(cum[:, -1:, :] - cum)       # [BH, C, N]
        s_new = d_c[:, :, None] * s + jnp.einsum(
            "bcn,bcm->bnm", kk * tail, vv
        )
        return s_new, o

    # remat the chunk body: the [BH, C, C, N] intra-chunk tensor is a
    # transient; without checkpoint the backward saves it per chunk
    s_fin, o = jax.lax.scan(
        jax.checkpoint(one_chunk), f32(s0), (rc, kc, vc, wc)
    )
    o = o.swapaxes(0, 1).reshape(bh, nc * chunk, n)
    return o[:, :t].astype(r.dtype), s_fin


# ---------------------------------------------------------------------------
# chunked attention with a flash-style hand-written backward (§Perf cell B)
# ---------------------------------------------------------------------------

import functools as _ft


@_ft.lru_cache(maxsize=None)
def _chunked_attention_vjp(causal: bool, window: int | None,
                           scale: float | None, bk: int):
    """Factory: chunked attention with a custom VJP.

    Autodiff of the KV-block scan saves per-block score residuals —
    O(Sq x Sk) memory and traffic again, defeating the chunking.  The
    flash-attention backward stores only (out, m, l) per row [O(Sq)] and
    RECOMPUTES each block's probabilities in the backward sweep:

        p   = exp(s - m) / l
        dv += p^T do
        ds  = p * (do v^T - rowsum(do * out))
        dq += ds k ;  dk += ds^T q
    """

    def fwd_only(q, k, v):
        return _chunked_fwd(q, k, v)[0]

    def _chunked_fwd(q, k, v):
        b, hq, sq, d = q.shape
        _, hkv, sk, _ = k.shape
        g = hq // hkv
        sc = scale if scale is not None else d ** -0.5
        nb = -(-sk // bk)
        pad = nb * bk - sk
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
        qg = q.reshape(b, hkv, g, sq, d)
        q_pos = jnp.arange(sq) + (sk - sq)
        kb = kp.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)
        vb = vp.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)

        def valid_mask(blk_i):
            k_pos = blk_i * bk + jnp.arange(bk)
            valid = k_pos[None, :] < sk
            if causal:
                valid &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                valid &= q_pos[:, None] - k_pos[None, :] < window
            return valid

        def step(carry, xs):
            m, l, acc = carry
            kblk, vblk, blk_i = xs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * sc
            s = jnp.where(valid_mask(blk_i)[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, sq), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
        acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0),
                                      (kb, vb, jnp.arange(nb)))
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l[..., None]).reshape(b, hq, sq, d).astype(q.dtype)
        return out, (m, l, valid_mask, kb, vb, qg, sc, nb, pad)

    @jax.custom_vjp
    def attn(q, k, v):
        return fwd_only(q, k, v)

    def attn_fwd(q, k, v):
        out, (m, l, _, _, _, _, _, _, _) = _chunked_fwd(q, k, v)
        return out, (q, k, v, out, m, l)

    def attn_bwd(res, do):
        q, k, v, out, m, l = res
        b, hq, sq, d = q.shape
        _, hkv, sk, _ = k.shape
        g = hq // hkv
        sc = scale if scale is not None else d ** -0.5
        nb = -(-sk // bk)
        pad = nb * bk - sk
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
        qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
        dog = do.reshape(b, hkv, g, sq, d).astype(jnp.float32)
        outg = out.reshape(b, hkv, g, sq, d).astype(jnp.float32)
        delta = (dog * outg).sum(-1)                    # [B,Hkv,G,Sq]
        q_pos = jnp.arange(sq) + (sk - sq)
        kb = kp.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)
        vb = vp.reshape(b, hkv, nb, bk, d).transpose(2, 0, 1, 3, 4)

        def step(dq, xs):
            kblk, vblk, blk_i = xs
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                           kblk.astype(jnp.float32)) * sc
            k_pos = blk_i * bk + jnp.arange(bk)
            valid = k_pos[None, :] < sk
            if causal:
                valid &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                valid &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(valid[None, None, None], s, _NEG_INF)
            p = jnp.exp(s - m[..., None]) / l[..., None]     # [B,H,G,Sq,bk]
            dv_blk = jnp.einsum("bhgqk,bhgqd->bhkd", p, dog)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", dog,
                            vblk.astype(jnp.float32))
            ds = p * (dp - delta[..., None]) * sc
            dq = dq + jnp.einsum("bhgqk,bhkd->bhgqd", ds,
                                 kblk.astype(jnp.float32))
            dk_blk = jnp.einsum("bhgqk,bhgqd->bhkd", ds, qg)
            return dq, (dk_blk, dv_blk)

        dq0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
        dq, (dk_b, dv_b) = jax.lax.scan(
            step, dq0, (kb, vb, jnp.arange(nb))
        )
        dk = dk_b.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nb * bk, d)
        dv = dv_b.transpose(1, 2, 0, 3, 4).reshape(b, hkv, nb * bk, d)
        return (dq.reshape(b, hq, sq, d).astype(q.dtype),
                dk[:, :, :sk].astype(k.dtype),
                dv[:, :, :sk].astype(v.dtype))

    attn.defvjp(attn_fwd, attn_bwd)
    return attn


def chunked_attention_flashbwd_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, bk: int = 512,
) -> jax.Array:
    """``chunked_attention_ref`` with the flash custom VJP (same semantics,
    O(Sq) backward residuals)."""
    return _chunked_attention_vjp(causal, window, scale, bk)(q, k, v)
