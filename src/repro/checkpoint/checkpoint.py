"""Sharded checkpointing with atomic commits, async save, auto-resume.

Fault-tolerance contract (orbax is not available; this is self-contained):

  * SAVE: leaves are written one file per leaf under a temp directory;
    a ``manifest.json`` records the treedef, shapes, dtypes and step; the
    temp dir is ``os.rename``d to ``step_<n>`` last — readers can never see
    a partial checkpoint (atomic commit).
  * ASYNC: ``save_async`` snapshots to host memory synchronously (cheap)
    and writes in a daemon thread, overlapping I/O with the next step.
  * RESTORE: ``latest_step`` scans the directory; restore maps files back to
    the pytree and ``device_put``s with *target* shardings — checkpoints are
    mesh-shape agnostic (elastic resharding on load: any source mesh ->
    any target mesh).
  * RETENTION: ``keep`` newest checkpoints survive garbage collection.

Multi-host note: on a real cluster each process writes only the shards it
owns (``addressable_shards``) and process 0 writes the manifest; on this
single-process container that degenerates to full-array writes, same layout.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest: dict[str, Any] = {"step": step, "leaves": {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"{name}.npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write in a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()  # one outstanding save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save(self.ckpt_dir, step, host_tree)
            garbage_collect(self.ckpt_dir, self.keep)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
            os.path.join(ckpt_dir, name, _MANIFEST)
        ):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    target: Any,
    shardings: Any | None = None,
) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (same structure) reshards on load —
    the elastic-scaling path: the stored mesh shape is irrelevant."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    names = [name for name, _ in _leaf_paths(target)]
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint at step {step} missing leaves: {missing[:5]}")
    arrays = [np.load(os.path.join(d, f"{n}.npy")) for n in names]
    flat_t, treedef = jax.tree.flatten(target)
    if shardings is not None:
        flat_s = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_s)]
    else:
        arrays = [jax.device_put(np.asarray(a)) for a in arrays]
    return treedef.unflatten(arrays)


def garbage_collect(ckpt_dir: str, keep: int) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, _MANIFEST))
    )
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
