"""FROZEN seed serving engine — the pre-split reference implementation.

This is the monolithic engine the Scheduler/Executor refactor replaced
(see :mod:`repro.serve.engine` for the architecture note).  It is kept
verbatim for two purposes only:

  * ``tests/test_serve_executor.py`` asserts the refactored engine produces
    token-for-token identical greedy outputs to this one;
  * ``benchmarks/bench_serve_throughput.py`` measures the before/after cost
    of its two hot-path pathologies (wholesale page-table re-upload each
    step; full-pool stack+reshape on every spill/restore).

Do not extend it; new serving work goes through Scheduler/Executor.

Responsibilities mapped from the paper:
  * page-table ownership and on-demand page allocation (the MMU + OS kernel);
  * page faults during decode (append_tokens) with precise accounting;
  * PREEMPTION when the physical pool is exhausted: a victim's vector state
    (its KV pages + sampler state + progress cursor) is spilled to a swap
    area and restored later — the §3.1 context switch, measured in real bytes
    and modeled cycles;
  * scheduler quanta and tick accounting (100 Hz analogue);
  * perf counters + snapshot FIFO (the paper's measurement infrastructure).

The engine runs a fixed ``max_batch`` of device-side slots; requests flow
queued -> running -> (swapped <->) running -> done.  Decode always executes
the full slot array (inactive slots are masked by unmapped page-table rows —
their writes land in the reserved scratch frame).

The device pool reserves its LAST frame as scratch: the engine hands
``VirtualMemory`` one frame fewer than physically allocated.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ContextSwitcher,
    CostModel,
    OutOfPagesError,
    PerfCounters,
    VirtualMemory,
    VMemConfig,
)
from repro.models.transformer import PagedKVState, TransformerLM
from repro.serve.scheduler import Request, ServeConfig  # shared data types


class ReferenceEngine:
    """Continuous batching over a paged-KV transformer (frozen seed)."""

    def __init__(self, model: TransformerLM, params: Any, cfg: ServeConfig,
                 cost: CostModel | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cost = cost or CostModel()
        # the device pool has num_pages frames; the allocator sees one less
        # (last frame = scratch for masked writes)
        self.vmem = VirtualMemory(VMemConfig(
            page_size=cfg.page_size,
            num_pages=cfg.num_pages - 1,
            max_pages_per_seq=cfg.max_pages_per_seq,
            max_seqs=cfg.max_batch,
        ))
        self.switcher = ContextSwitcher(self.vmem, self.cost, page_axis=1)
        self.counters = PerfCounters()
        self.kv = model.init_kv_state(
            cfg.max_batch, cfg.num_pages, cfg.page_size, cfg.max_pages_per_seq
        )
        self.queue: deque[Request] = deque()
        self.swapped: deque[int] = deque()
        self._swap_requests: dict[int, Request] = {}
        self.running: dict[int, Request] = {}    # req_id -> Request
        self.done: dict[int, Request] = {}
        self._slot_of: dict[int, int] = {}       # req_id -> device slot
        self._step_i = 0
        self._rng = jax.random.PRNGKey(cfg.seed)
        #: shared-prefix ("system prompt") support: one resident sequence
        #: whose whole pages are refcount-shared into forked requests.
        #: KV pages are append-only, so shared pages are never rewritten —
        #: copy-on-write degenerates to copy-the-tail-page at fork time.
        self.PREFIX_ID = -1
        self._prefix_len = 0

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def preload_prefix(self, prefix_tokens: "np.ndarray") -> None:
        """Prefill a resident shared prefix (system-prompt caching).

        Subsequent ``submit(req, share_prefix=True)`` requests fork their
        page tables from it: whole prefix pages are shared by refcount, only
        the partial tail page is copied.
        """
        assert self.vmem.num_seqs == 0, "preload before serving"
        n = len(prefix_tokens)
        self.vmem.map_seq(self.PREFIX_ID, n)
        slot = self.vmem.seq(self.PREFIX_ID).slot
        pt_row = self.vmem.device_page_table()[jnp.asarray([slot])]
        state = PagedKVState(
            self.kv.k_pools, self.kv.v_pools, pt_row,
            jnp.zeros((1,), jnp.int32),
        )
        tokens = np.asarray(prefix_tokens, np.int32)[None, :]
        page = self.cfg.page_size
        pad = (-len(prefix_tokens)) % page
        if pad:
            tokens = np.pad(tokens, ((0, 0), (0, pad)))
        _, new_state = self.model.prefill(
            self.params, jnp.asarray(tokens),
            jnp.asarray([n], jnp.int32), state,
        )
        self.kv = self.kv._replace(
            k_pools=new_state.k_pools, v_pools=new_state.v_pools
        )
        self._prefix_len = n
        self.counters.inc("prefix_tokens", n)

    def _admit_forked(self, req: Request) -> None:
        """Fork the shared prefix and teacher-force the request's own
        prompt through decode steps (continuation prefill)."""
        state = self.vmem.fork_seq(self.PREFIX_ID, req.req_id,
                                   self._prefix_len)
        slot = state.slot
        # copy the partial tail page (whole pages are shared read-only)
        parent = self.vmem.seq(self.PREFIX_ID)
        page = self.cfg.page_size
        if self._prefix_len % page:
            tail_idx = self._prefix_len // page
            src = parent.pages[tail_idx]
            dst = state.pages[tail_idx]
            self.kv = self.kv._replace(
                k_pools=self.kv.k_pools.at[:, dst].set(
                    self.kv.k_pools[:, src]),
                v_pools=self.kv.v_pools.at[:, dst].set(
                    self.kv.v_pools[:, src]),
            )
        b = self.cfg.max_batch
        logits = None
        for tok in np.asarray(req.prompt, np.int32):
            self.vmem.append_tokens(req.req_id, 1)
            pre_lens = np.zeros((b,), np.int32)
            pre_lens[slot] = self.vmem.seq_len(req.req_id) - 1
            tokens = np.zeros((b,) + np.shape(tok), np.int32)
            tokens[slot] = tok
            st = PagedKVState(
                self.kv.k_pools, self.kv.v_pools,
                self._table_only(slot), jnp.asarray(pre_lens),
            )
            logits, new_state = self.model.decode_step(
                self.params, jnp.asarray(tokens), st
            )
            self.kv = self.kv._replace(
                k_pools=new_state.k_pools, v_pools=new_state.v_pools
            )
        req.status = "running"
        req.prefix_len = self._prefix_len
        req.output.append(np.asarray(self._sample(logits)[slot]))
        self.running[req.req_id] = req
        self._slot_of[req.req_id] = slot
        self.counters.inc("forked_admissions")

    def _table_only(self, slot: int) -> "jnp.ndarray":
        """Page table with every row but `slot` masked (single-seq step)."""
        full = self.vmem.device_page_table()
        mask = jnp.zeros((full.shape[0], 1), bool).at[slot].set(True)
        return jnp.where(mask, full, -1)

    def submit(self, req: Request) -> None:
        req.arrival = self._step_i
        self.queue.append(req)
        self.counters.inc("submitted")
        self.counters.snapshot("submit", req.req_id)

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        """Drive until all submitted requests complete."""
        while (self.queue or self.running or self.swapped) and (
            self._step_i < max_steps
        ):
            self.step()
        return self.done

    def step(self) -> None:
        self._step_i += 1
        if self._step_i % self.cfg.tick_every_steps == 0:
            # 100 Hz scheduler tick accounting (paper §3.1)
            self.counters.inc("ticks")
            self.counters.inc(
                "modeled_tick_cycles", self.cost.sched_tick_cycles
            )
        self._try_restore()
        self._admit()
        if self.running:
            self._decode_once()

    # ------------------------------------------------------------------
    # admission (prefill)
    # ------------------------------------------------------------------

    def _required_pages(self, req: Request) -> int:
        return self.vmem.config.pages_for(len(req.prompt) + 1)

    def _admit(self) -> None:
        admitted: list[Request] = []
        while self.queue and len(self.running) + len(admitted) < self.cfg.max_batch:
            req = self.queue[0]
            need = self._required_pages(req)
            if need > self.vmem.pool.num_free:
                if not self._preempt_for(need):
                    break                      # nothing left to preempt
            if req.share_prefix:
                try:
                    self._admit_forked(req)
                except OutOfPagesError:
                    break
                self.queue.popleft()
                continue
            try:
                self.vmem.map_seq(req.req_id, len(req.prompt))
            except OutOfPagesError:
                break
            self.queue.popleft()
            admitted.append(req)
        if not admitted:
            return
        self._prefill(admitted)

    def _prefill(self, reqs: list[Request]) -> None:
        smax = max(len(r.prompt) for r in reqs)
        page = self.cfg.page_size
        smax = -(-smax // page) * page            # burst-align
        tok_shape = (len(reqs), smax) + reqs[0].prompt.shape[1:]
        tokens = np.zeros(tok_shape, np.int32)
        lens = np.array([len(r.prompt) for r in reqs], np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : len(r.prompt)] = r.prompt
        # page-table rows aligned to the prefill batch
        slots = [self.vmem.seq(r.req_id).slot for r in reqs]
        pt_admit = self.vmem.device_page_table()[jnp.asarray(slots)]
        state = PagedKVState(
            self.kv.k_pools, self.kv.v_pools, pt_admit,
            jnp.zeros((len(reqs),), jnp.int32),
        )
        with self.counters.timer("prefill"):
            logits, new_state = self.model.prefill(
                self.params, jnp.asarray(tokens), jnp.asarray(lens), state
            )
            # measurement fix only (no behavior change): async dispatch
            # returns immediately, so an unblocked timer measured dispatch
            # cost, not execution — the before/after benchmark ratios were
            # fiction
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(
            k_pools=new_state.k_pools, v_pools=new_state.v_pools
        )
        first = self._sample(logits)
        for i, r in enumerate(reqs):
            r.status = "running"
            r.output.append(np.asarray(first[i]))
            self.running[r.req_id] = r
            self._slot_of[r.req_id] = slots[i]
        self.counters.inc("prefill_tokens", int(lens.sum()))
        self.counters.inc("prefill_translation_bursts", int(
            sum(self.vmem.config.pages_for(int(x)) for x in lens)
        ))
        self.counters.snapshot("prefill", [r.req_id for r in reqs])

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------

    def _decode_once(self) -> None:
        cfg = self.cfg
        # 1. fault in pages for every running sequence's next position
        #    (idempotent: a restore may already cover the position)
        for req_id in list(self.running):
            r = self.running.get(req_id)
            if r is None:
                continue  # spilled by an earlier victim selection this step
            grow = r.total_len - self.vmem.seq_len(req_id)
            if grow <= 0:
                continue
            try:
                faults = self.vmem.append_tokens(req_id, grow)
            except OutOfPagesError:
                if not self._preempt_for(1, protect=req_id):
                    continue  # stays running; retried next step
                faults = self.vmem.append_tokens(req_id, grow)
            if faults:
                self.counters.inc("page_faults", len(faults))
                self.counters.inc(
                    "modeled_fault_cycles",
                    len(faults) * (self.cost.ptw_cycles
                                   + self.cost.post_fault_flush_cycles),
                )
        # 2. build the full-slot decode batch
        if not self.running:
            return  # everything got preempted this step
        b = cfg.max_batch
        tokens = np.zeros((b,) + np.shape(
            next(iter(self.running.values())).output[-1]
        ), np.int32)
        pre_lens = np.zeros((b,), np.int32)
        for req_id, r in self.running.items():
            slot = self._slot_of[req_id]
            tokens[slot] = r.output[-1]
            pre_lens[slot] = r.total_len - 1   # position of the new token
        # mask page-table rows of slots that are NOT running this step:
        # mapped-but-idle sequences (e.g. the resident shared prefix) must
        # not receive the inactive-lane scratch writes — with a valid row
        # the guard would route them into a LIVE frame (position 0 of the
        # prefix page!) instead of the reserved scratch row.
        ptab = np.asarray(self.vmem.device_page_table()).copy()
        active_slots = set(self._slot_of.values())
        for sl in range(b):
            if sl not in active_slots:
                ptab[sl] = -1
        state = PagedKVState(
            self.kv.k_pools, self.kv.v_pools,
            jnp.asarray(ptab), jnp.asarray(pre_lens),
        )
        with self.counters.timer("decode"):
            logits, new_state = self.model.decode_step(
                self.params, jnp.asarray(tokens), state
            )
            jax.block_until_ready(logits)   # measurement fix, see prefill
        self.kv = self.kv._replace(
            k_pools=new_state.k_pools, v_pools=new_state.v_pools
        )
        nxt = self._sample(logits)
        self.counters.inc("decode_tokens", len(self.running))
        self.counters.inc("decode_translations", len(self.running))
        # 3. commit sampled tokens, retire finished requests
        for req_id in list(self.running):
            r = self.running[req_id]
            slot = self._slot_of[req_id]
            r.output.append(np.asarray(nxt[slot]))
            if len(r.output) >= r.max_new_tokens:
                r.status = "done"
                self.done[req_id] = r
                del self.running[req_id]
                del self._slot_of[req_id]
                self.vmem.unmap_seq(req_id)
                self.counters.inc("completed")
                self.counters.snapshot("done", req_id)

    # ------------------------------------------------------------------
    # preemption / restore (context switches)
    # ------------------------------------------------------------------

    def _preempt_for(self, pages_needed: int, protect: int | None = None) -> bool:
        """Spill victims until `pages_needed` frames are free."""
        while self.vmem.pool.num_free < pages_needed:
            victims = [
                r for rid, r in self.running.items() if rid != protect
            ]
            if not victims:
                return False
            # policy: most remaining work (cheapest to delay)
            victim = max(victims, key=lambda r: (r.remaining, -r.arrival))
            self._spill(victim)
        return True

    def _spill(self, req: Request) -> None:
        # KV pages of both pools travel together (single vector state)
        stacked = jnp.stack([self.kv.k_pools, self.kv.v_pools])  # [2, L, P, ...]
        self.switcher.spill(
            req.req_id,
            stacked.reshape((-1,) + self.kv.k_pools.shape[1:]),
            extra_state={"output": list(req.output)},
        )
        req.status = "swapped"
        self.swapped.append(req.req_id)
        self._swap_requests[req.req_id] = req
        del self.running[req.req_id]
        del self._slot_of[req.req_id]
        self.counters.inc("preemptions")
        self.counters.snapshot("preempt", req.req_id)

    def _try_restore(self) -> None:
        for _ in range(len(self.swapped)):
            req_id = self.swapped[0]
            if len(self.running) >= self.cfg.max_batch:
                return
            if not self.switcher.can_restore(req_id):
                return
            self.swapped.popleft()
            stacked = jnp.stack([self.kv.k_pools, self.kv.v_pools])
            flat = stacked.reshape((-1,) + self.kv.k_pools.shape[1:])
            flat, extra = self.switcher.restore(req_id, flat)
            restored = flat.reshape(stacked.shape)
            self.kv = self.kv._replace(
                k_pools=restored[0], v_pools=restored[1]
            )
            req = self._swap_requests.pop(req_id)
            req.status = "running"
            req.output = extra["output"]
            self.running[req_id] = req
            self._slot_of[req_id] = self.vmem.seq(req_id).slot
            self.counters.inc("restores")
            self.counters.snapshot("restore", req_id)

    # ------------------------------------------------------------------
    # sampling + stats
    # ------------------------------------------------------------------

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._rng, key = jax.random.split(self._rng)
        return np.asarray(
            jax.random.categorical(key, logits / self.cfg.temperature, axis=-1)
        )

    def stats(self) -> dict[str, Any]:
        rep = self.counters.report()
        rep["switch_stats"] = dataclasses.asdict(self.switcher.stats)
        rep["pool"] = {
            "frames": self.vmem.pool.num_pages,
            "free": self.vmem.pool.num_free,
            "faults": self.vmem.pool.fault_count,
        }
        rep["modeled_ctx_switch_seconds"] = self.switcher.stats.modeled_seconds(
            self.cost
        )
        return rep
