"""Model configuration schema for the assigned architecture pool.

One frozen dataclass covers all five families (dense / moe / hybrid-rglru /
rwkv6 / frontend-stub VLM + audio); family-specific fields are zeroed when
unused.  `src/repro/configs/<arch>.py` instantiates one of these per assigned
architecture with the exact published numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid_rglru", "rwkv6", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int          # query heads (0 for attention-free families)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0       # per-expert hidden dim (d_ff covers dense layers)
    moe_every: int = 1      # 2 = alternate dense/MoE FFN layers (llama4)

    # --- hybrid (recurrentgemma): repeating block pattern ---
    # pattern entries: "rglru" | "local" ; empty = homogeneous attention
    block_pattern: tuple[str, ...] = ()
    local_window: int = 2048
    rglru_dim: int = 0      # recurrence width (defaults to d_model)

    # --- rwkv6 ---
    rwkv_head_size: int = 64

    # --- positional encoding ---
    rope_theta: float = 1_000_000.0
    mrope_sections: tuple[int, int, int] = ()  # M-RoPE (qwen2-vl): t/h/w

    # --- modality frontend stubs ---
    frontend: str | None = None   # None | "vision" | "audio"
    num_codebooks: int = 1        # musicgen EnCodec codebooks

    # --- numerics ---
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    def __post_init__(self) -> None:
        if self.family in ("dense", "moe", "vlm", "audio"):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family == "hybrid_rglru":
            assert self.block_pattern, "hybrid family needs a block pattern"

    # ---- derived ----------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attends(self) -> bool:
        """False for fully attention-free families (rwkv6)."""
        return self.family != "rwkv6"

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is admissible (DESIGN.md §4)."""
        return self.family in ("rwkv6", "hybrid_rglru")

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            # time-mix (r,k,v,w,g,o ~ 6 d^2) + channel-mix (~ 2*3.5 d^2)
            per_layer = 6 * d * d + 2 * d * f
        else:
            h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
            attn = d * h * hd + 2 * d * kv * hd + h * hd * d
            if self.family == "moe":
                moe = self.num_experts * 3 * d * self.moe_d_ff
                dense = 3 * d * f
                n_moe = self.num_layers // self.moe_every
                return (emb + self.num_layers * attn + n_moe * moe
                        + (self.num_layers - n_moe) * dense)
            else:
                ffn = 3 * d * f
            if self.family == "hybrid_rglru":
                n_rec = sum(1 for p in self._full_pattern() if p == "rglru")
                n_att = self.num_layers - n_rec
                rec = 6 * d * d  # gates + recurrence + projections (approx)
                return emb + n_rec * (rec + 3 * d * f) + n_att * (attn + 3 * d * f)
            per_layer = attn + ffn
        return emb + self.num_layers * per_layer

    def active_param_count(self) -> int:
        """Activated params per token (= param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        moe_active = self.experts_per_token * 3 * d * self.moe_d_ff
        dense = 3 * d * self.d_ff
        n_moe = self.num_layers // self.moe_every
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return (emb + self.num_layers * attn + n_moe * moe_active
                + (self.num_layers - n_moe) * dense)

    def _full_pattern(self) -> tuple[str, ...]:
        """Expand block_pattern cyclically over num_layers."""
        if not self.block_pattern:
            return ("attn",) * self.num_layers
        reps = -(-self.num_layers // len(self.block_pattern))
        return (self.block_pattern * reps)[: self.num_layers]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
