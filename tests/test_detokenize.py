"""AsyncDetokenizer: ordered delivery, drain/close semantics, exception
surfacing, backlog-peak accounting — all host-only (no device, no model).

The contract under test (see ``repro/serve/detokenize.py``): ONE consumer
thread makes the delivery order exactly the push (= global commit) order;
``drain()`` blocks until every pushed event is delivered and re-raises
the first callback exception; the scheduler-side ``push`` never raises
for callback failures (they must not unwind the commit loop); requests
without a ``stream_callback`` cost nothing (no thread).
"""

import threading
import time
import types

import numpy as np
import pytest

from repro.core import PerfCounters
from repro.serve.detokenize import AsyncDetokenizer, default_detokenize

pytestmark = pytest.mark.slo


def _req(req_id, cb):
    """The duck-typed producer-side view: ``push`` reads only ``req_id``,
    ``stream_callback`` and (optionally) ``t_last_token``."""
    return types.SimpleNamespace(req_id=req_id, stream_callback=cb,
                                 t_last_token=0.125)


class TestOrdering:
    def test_global_and_per_request_order(self):
        got = []
        detok = AsyncDetokenizer()
        reqs = {i: _req(i, got.append) for i in range(3)}
        pushed = []
        for j in range(5):
            for i in range(3):
                final = j == 4
                detok.push(reqs[i], np.int32(100 * i + j), final)
                pushed.append((i, j))
        detok.drain()
        # delivery order == push order (one consumer, FIFO queue)
        assert [(e.req_id, e.index) for e in got] == pushed
        # per-request indexes are dense 0..n-1 and only the last is final
        for i in range(3):
            evs = [e for e in got if e.req_id == i]
            assert [e.index for e in evs] == list(range(5))
            assert [e.final for e in evs] == [False] * 4 + [True]
        # payloads survive: token and its default detokenization
        assert all(e.text == f"<{int(e.token)}>" for e in got)
        assert all(e.t_commit == 0.125 for e in got)
        detok.close()

    def test_no_callback_no_thread(self):
        detok = AsyncDetokenizer()
        detok.push(_req(0, None), np.int32(1), False)
        assert detok._thread is None          # never spawned
        assert detok.backlog == 0
        detok.drain()
        detok.close()


class TestDrainAndClose:
    def test_drain_blocks_until_delivered(self):
        delivered = []

        def slow(ev):
            time.sleep(0.01)
            delivered.append(ev)

        detok = AsyncDetokenizer()
        r = _req(7, slow)
        for j in range(8):
            detok.push(r, np.int32(j), j == 7)
        detok.drain()
        assert len(delivered) == 8
        detok.close()

    def test_close_idempotent_and_refuses_push(self):
        detok = AsyncDetokenizer()
        r = _req(0, lambda ev: None)
        detok.push(r, np.int32(1), True)
        detok.close()
        detok.close()                          # idempotent
        with pytest.raises(RuntimeError):
            detok.push(r, np.int32(2), False)


class TestExceptions:
    def test_callback_exception_surfaces_on_drain(self):
        """push() never raises for callback failures; the FIRST exception
        re-raises on drain(), and events for OTHER requests around the
        failure are still delivered."""
        good = []

        def bad(ev):
            raise ValueError(f"boom at {ev.index}")

        detok = AsyncDetokenizer()
        rb, rg = _req(0, bad), _req(1, good.append)
        detok.push(rg, np.int32(10), False)
        detok.push(rb, np.int32(20), False)    # raises in the worker
        detok.push(rb, np.int32(21), True)     # second failure: swallowed
        detok.push(rg, np.int32(11), True)     # still delivered
        with pytest.raises(ValueError, match="boom at 0"):
            detok.drain()
        assert [int(e.token) for e in good] == [10, 11]
        # the exception was consumed: drain is clean again
        detok.drain()
        detok.close()

    def test_detokenizer_exception_surfaces_too(self):
        def bad_detok(token):
            raise TypeError("no vocab")

        detok = AsyncDetokenizer(detokenize=bad_detok)
        detok.push(_req(0, lambda ev: None), np.int32(1), True)
        with pytest.raises(TypeError, match="no vocab"):
            detok.close()


class TestBacklogPeak:
    def test_peak_recorded_not_incremented(self):
        """detok_backlog_peak is a PEAK (max depth ever), written directly
        — pushing while the consumer is blocked must record the depth,
        and later shallow pushes must not lower or re-add to it."""
        gate = threading.Event()
        counters = PerfCounters()
        detok = AsyncDetokenizer(counters=counters)
        r = _req(0, lambda ev: gate.wait(timeout=10.0))
        for j in range(6):
            detok.push(r, np.int32(j), False)
        peak = counters.get("detok_backlog_peak")
        assert peak >= 5                       # consumer held on event 0
        gate.set()
        detok.drain()
        detok.push(r, np.int32(6), True)       # depth 1 now: peak unchanged
        detok.drain()
        assert counters.get("detok_backlog_peak") == peak
        detok.close()


class TestDefaultDetokenize:
    def test_shapes(self):
        assert default_detokenize(None) == ""
        assert default_detokenize(np.int32(42)) == "<42>"
        assert default_detokenize(np.array([1, 2, 3])) == "<1,2,3>"
