"""AdamW + schedules + clipping, pure JAX (optax is not available).

Optimizer state is a pytree congruent with params; under pjit the launcher
shards it with the ZeRO-1 rules (DESIGN.md §3): same per-tensor layout as the
parameter, with the first divisible non-`model` dimension additionally sharded
over `data`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # pytree like params (f32)
    v: Any                   # pytree like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    base_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    #: moment dtype — "bfloat16" halves optimizer memory (used for the
    #: 400B-class configs; quality impact is negligible with f32 updates)
    moment_dtype: str = "float32"


def adamw_init(params: Any, moment_dtype: str = "float32") -> AdamWState:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[moment_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    floor = cfg.min_lr_frac
    return cfg.base_lr * warm * (floor + (1 - floor) * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree)
        )
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
    """One AdamW step. Grads may be low precision; moments are f32."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * update
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step, new_m, new_v), metrics
