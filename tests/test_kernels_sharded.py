"""Sharded kernel differential grids (markers: ``sharded`` + ``kernels``).

The single-device differential suites (tests/test_kernels.py,
tests/test_paged_prefill_attention.py) pin each Pallas kernel to its jnp
oracle.  This file closes the remaining gap for the mesh: the SAME grids
run through the shard_map dispatch wrappers (``kernels.ops.*_sharded``)
over a real >1-device ('kv', 'hd') mesh, asserting the three-way identity

    shard-local kernel output == single-device kernel output == jnp oracle

plus that the outputs come back carrying the wrappers' declared specs
(pools sharded ``P(None, None, kv, hd)``, attention outputs sharded over
'kv' only / replicated over 'hd' — with replication checks off, a wrong
claimed spec would silently corrupt the global view, so the identity
checks here are what makes the claims trustworthy).

Needs >1 XLA device:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m pytest -q -m "sharded and kernels"

With a single visible device every test skips cleanly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.launch.mesh import kv_partition_axes, make_host_serve_mesh
from test_paged_prefill_attention import make_case

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.kernels,
    pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs >1 XLA device; set "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8",
    ),
]

KEY = jax.random.PRNGKey(3)

# the differential shapes here use hkv=2, d=16, which a forced-8-device
# host factors as a FULL (kv=2, hd=4) mesh — both axes >1, so the
# head-parallel ('kv') AND the all-gather ('hd') paths are exercised
HKV, G, D = 2, 2, 16


@pytest.fixture(scope="module")
def mesh():
    m = make_host_serve_mesh(HKV, D)
    assert m.size > 1  # guaranteed by the skipif: 2 devices -> (1, 2)
    return m


def _decode_case(page_size, lens, *, hkv=HKV, g=G, d=D, seed=0):
    lens = np.asarray(lens, np.int32)
    b = len(lens)
    max_pages = int(max(-(-int(t) // page_size) for t in lens)) + 1
    n_frames = b * max_pages + 2
    key = jax.random.fold_in(KEY, seed)
    ks = jax.random.split(key, 3)
    k_pool = jax.random.normal(ks[0], (n_frames, page_size, hkv, d))
    v_pool = jax.random.normal(ks[1], (n_frames, page_size, hkv, d))
    rng = np.random.default_rng(seed)
    frames = rng.permutation(n_frames)
    table = np.full((b, max_pages), -1, np.int32)
    fi = 0
    for row in range(b):
        need = -(-int(lens[row]) // page_size)
        table[row, :need] = frames[fi: fi + need]
        fi += need
    q = jax.random.normal(ks[2], (b, hkv, g, d))
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(lens)


def _assert_spec(arr, mesh, *spec):
    want = jax.sharding.NamedSharding(mesh,
                                      jax.sharding.PartitionSpec(*spec))
    assert arr.sharding.is_equivalent_to(want, arr.ndim), (
        f"{arr.sharding} != {want}"
    )


class TestShardedPrefillAttentionGrid:
    """tests/test_paged_prefill_attention.py's core sweep, on the mesh."""

    @pytest.mark.parametrize("page_size", [4, 8])
    @pytest.mark.parametrize("chunk", [1, 3, 8, 17])
    @pytest.mark.parametrize("start", [0, 5, 16])
    def test_grid(self, mesh, page_size, chunk, start):
        q, kp, vp, tab, starts, bq = make_case(
            page_size, [start], [chunk], hkv=HKV, g=G, d=D,
            seed=page_size * 100 + chunk)
        out_sh = ops.paged_prefill_attention_sharded(
            q, kp, vp, tab, starts, page_size=page_size, bq=bq, mesh=mesh)
        out_k = ops.paged_prefill_attention(
            q, kp, vp, tab, starts, page_size=page_size, bq=bq,
            use_kernel=True)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, tab, starts, page_size=page_size, use_kernel=False)
        kv_ax, _ = kv_partition_axes(mesh, HKV, D)
        _assert_spec(out_sh, mesh, None, None, kv_ax, None, None)
        np.testing.assert_allclose(
            np.asarray(out_sh)[0, :chunk], np.asarray(out_k)[0, :chunk],
            rtol=2e-5, atol=2e-5, err_msg="sharded != single-device kernel")
        np.testing.assert_allclose(
            np.asarray(out_sh)[0, :chunk], np.asarray(out_r)[0, :chunk],
            rtol=2e-5, atol=2e-5, err_msg="sharded != jnp oracle")

    def test_batched_rows_mixed_offsets(self, mesh):
        chunks = [10, 7, 1]
        q, kp, vp, tab, starts, bq = make_case(
            8, [5, 0, 13], chunks, hkv=HKV, g=G, d=D, seed=11)
        out_sh = ops.paged_prefill_attention_sharded(
            q, kp, vp, tab, starts, page_size=8, bq=bq, mesh=mesh)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, tab, starts, page_size=8, use_kernel=False)
        for row, chunk in enumerate(chunks):
            np.testing.assert_allclose(
                np.asarray(out_sh)[row, :chunk],
                np.asarray(out_r)[row, :chunk], rtol=2e-5, atol=2e-5,
                err_msg=f"row {row} diverged on the mesh")


class TestShardedDecodeAttention:
    @pytest.mark.parametrize("lens", [[9, 6], [1, 32, 17], [2, 5]])
    def test_vs_single_device_and_oracle(self, mesh, lens):
        q, kp, vp, tab, sl = _decode_case(4, lens, seed=sum(lens))
        out_sh = ops.paged_decode_attention_sharded(
            q, kp, vp, tab, sl, page_size=4, mesh=mesh)
        out_k = ops.paged_decode_attention(
            q, kp, vp, tab, sl, page_size=4, use_kernel=True)
        out_r = ops.paged_decode_attention(
            q, kp, vp, tab, sl, page_size=4, use_kernel=False)
        kv_ax, _ = kv_partition_axes(mesh, HKV, D)
        _assert_spec(out_sh, mesh, None, kv_ax, None, None)
        np.testing.assert_allclose(out_sh, out_k, rtol=2e-5, atol=2e-5,
                                   err_msg="sharded != single-device kernel")
        np.testing.assert_allclose(out_sh, out_r, rtol=2e-5, atol=2e-5,
                                   err_msg="sharded != jnp oracle")


class TestShardedPagedCopies:
    """tests/test_kernels.py's copy grids through the 4-D sharded entry
    points (the merged-W reshape happens inside the shard bodies)."""

    def _copy_case(self, page_size, covers, *, lens=None, s=None, seed=0):
        # ``covers`` sizes the page table (last token each row may touch);
        # ``lens`` is what the op sees; ``s`` is the padded src length.
        covers = np.asarray(covers, np.int32)
        b = len(covers)
        lens = covers if lens is None else np.asarray(lens, np.int32)
        s = s if s is not None else -(-int(covers.max()) // page_size) * page_size
        max_pages = -(-int(covers.max()) // page_size)
        n_frames = b * max_pages + 3
        key = jax.random.fold_in(KEY, 100 + seed)
        ks = jax.random.split(key, 2)
        src = jax.random.normal(ks[0], (b, s, HKV, D))
        pool = jax.random.normal(ks[1], (n_frames, page_size, HKV, D))
        rng = np.random.default_rng(seed)
        frames = rng.permutation(n_frames)
        table = np.full((b, max_pages), -1, np.int32)
        fi = 0
        for row in range(b):
            table[row] = frames[fi: fi + max_pages]
            fi += max_pages
        return src, pool, jnp.asarray(table), jnp.asarray(lens)

    @pytest.mark.parametrize("lens", [[7, 5], [16, 1], [4]])
    def test_paged_copy(self, mesh, lens):
        page = 4
        src, pool, tab, ln = self._copy_case(page, lens, seed=sum(lens))
        out_sh = ops.paged_copy_sharded(
            src, pool, tab, ln, page_size=page, mesh=mesh)
        b, s, hkv, d = src.shape
        out_k = ops.paged_copy(
            src.reshape(b, s, hkv * d),
            pool.reshape(-1, page, hkv * d), tab, ln, page_size=page,
        ).reshape(pool.shape)
        out_r = ops.paged_copy(
            src.reshape(b, s, hkv * d),
            pool.reshape(-1, page, hkv * d), tab, ln, page_size=page,
            use_kernel=False,
        ).reshape(pool.shape)
        kv_ax, hd_ax = kv_partition_axes(mesh, HKV, D)
        _assert_spec(out_sh, mesh, None, None, kv_ax, hd_ax)
        np.testing.assert_array_equal(np.asarray(out_sh), np.asarray(out_k))
        np.testing.assert_array_equal(np.asarray(out_sh), np.asarray(out_r))

    @pytest.mark.parametrize("windows", [[(2, 5), (0, 3)], [(13, 3)]])
    def test_paged_copy_at(self, mesh, windows):
        page = 4
        starts = np.asarray([w[0] for w in windows], np.int32)
        lens = np.asarray([w[1] for w in windows], np.int32)
        smax = int(lens.max())
        src, pool, tab, _ = self._copy_case(
            page, list(starts + lens), lens=lens, s=smax,
            seed=int(lens.sum()))
        st, ln = jnp.asarray(starts), jnp.asarray(lens)
        out_sh = ops.paged_copy_at_sharded(
            src, pool, tab, st, ln, page_size=page, mesh=mesh)
        b, s, hkv, d = src.shape
        out_k = ops.paged_copy_at(
            src.reshape(b, s, hkv * d),
            pool.reshape(-1, page, hkv * d), tab, st, ln, page_size=page,
        ).reshape(pool.shape)
        out_r = ops.paged_copy_at(
            src.reshape(b, s, hkv * d),
            pool.reshape(-1, page, hkv * d), tab, st, ln, page_size=page,
            use_kernel=False,
        ).reshape(pool.shape)
        np.testing.assert_array_equal(np.asarray(out_sh), np.asarray(out_k))
        np.testing.assert_array_equal(np.asarray(out_sh), np.asarray(out_r))


class TestSpecDegradation:
    """Dims that do not divide the mesh must degrade to replicated —
    mirroring ``executor_state_shardings`` exactly — and still match."""

    def test_indivisible_heads_replicate_kv(self, mesh):
        # hkv=3 never divides a kv extent > 1 on this mesh
        q, kp, vp, tab, starts, bq = make_case(
            4, [2], [6], hkv=3, g=2, d=D * mesh.shape["hd"], seed=5)
        out_sh = ops.paged_prefill_attention_sharded(
            q, kp, vp, tab, starts, page_size=4, bq=bq, mesh=mesh)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, tab, starts, page_size=4, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_sh)[0, :6], np.asarray(out_r)[0, :6],
            rtol=2e-5, atol=2e-5)

    def test_indivisible_head_dim_replicates_hd(self, mesh):
        # d=10 does not divide hd extents > 1 from (2,4)/(1,2) meshes
        q, kp, vp, tab, sl = _decode_case(4, [9, 6], d=10, seed=7)
        out_sh = ops.paged_decode_attention_sharded(
            q, kp, vp, tab, sl, page_size=4, mesh=mesh)
        out_r = ops.paged_decode_attention(
            q, kp, vp, tab, sl, page_size=4, use_kernel=False)
        np.testing.assert_allclose(out_sh, out_r, rtol=2e-5, atol=2e-5)


class TestShardedFlashAttention:
    def test_vs_single_device_kernel(self, mesh):
        b, s = 2, 24
        ks = jax.random.split(jax.random.fold_in(KEY, 9), 3)
        q = jax.random.normal(ks[0], (b, HKV * G, s, D))
        k = jax.random.normal(ks[1], (b, HKV, s, D))
        v = jax.random.normal(ks[2], (b, HKV, s, D))
        out_sh = ops.flash_attention_sharded(q, k, v, causal=True,
                                             mesh=mesh)
        out_k = ops.flash_attention(q, k, v, causal=True)
        kv_ax, _ = kv_partition_axes(mesh, HKV, D)
        _assert_spec(out_sh, mesh, None, kv_ax, None, None)
        np.testing.assert_allclose(out_sh, out_k, rtol=2e-5, atol=2e-5)
