"""The typed client surface (``repro.serve.api``) + the single validated
flag surface (``ServeConfig.from_args``): request/result lowering, the
one-PR deprecation shim, and the TTFT/TPOT capture-point contract.

Everything here runs on host-only fault planes (``tests/_fault_plane``):
the token streams are the deterministic ``token_for`` closed form, so the
typed drain() results can be asserted exactly without a device.
"""

import argparse
import threading
import time

import numpy as np
import pytest

from tests._fault_plane import expected_output, make_replica, token_for
from repro.serve import (
    AsyncDetokenizer,
    Replica,
    ReplicaRouter,
    Request,
    SamplingParams,
    ServeConfig,
    ServeRequest,
    ServeResult,
)
from repro.serve.api import RequestTiming, to_internal

pytestmark = pytest.mark.slo


def make_router(n=1, **kw):
    replicas, planes = [], []
    for r in range(n):
        sched, plane = make_replica(replica_id=r, **kw)
        sched.attach_stream(AsyncDetokenizer(counters=sched.counters))
        replicas.append(Replica(replica_id=r, scheduler=sched, plane=plane))
        planes.append(plane)
    return ReplicaRouter(replicas), planes


def sreq(prompt_len=5, max_new=4, **kw):
    return ServeRequest(prompt=np.arange(1, prompt_len + 1, dtype=np.int64),
                        max_new_tokens=max_new, **kw)


class TestServeRequest:
    def test_prompt_coerced_to_int32(self):
        r = sreq()
        assert r.prompt.dtype == np.int32

    def test_empty_prompt_rejected(self):
        with pytest.raises(ValueError, match="non-empty"):
            ServeRequest(prompt=np.array([], np.int32), max_new_tokens=4)

    def test_zero_budget_rejected(self):
        with pytest.raises(ValueError, match="max_new_tokens"):
            sreq(max_new=0)

    def test_to_internal_req_id_resolution(self):
        assert to_internal(sreq(req_id=9)).req_id == 9
        assert to_internal(sreq(), req_id=3).req_id == 3
        assert to_internal(sreq(req_id=9), req_id=3).req_id == 9  # explicit wins
        with pytest.raises(ValueError, match="req_id required"):
            to_internal(sreq())


class TestSamplingParams:
    def test_conflict_raises_at_submit(self):
        cfg = ServeConfig(num_pages=8)          # greedy=True default
        with pytest.raises(ValueError, match="engine-global"):
            to_internal(sreq(sampling=SamplingParams(greedy=False,
                                                     temperature=0.7)),
                        req_id=0, cfg=cfg)

    def test_matching_params_pass(self):
        cfg = ServeConfig(num_pages=8)
        r = to_internal(sreq(sampling=SamplingParams(greedy=True)),
                        req_id=0, cfg=cfg)
        assert r.req_id == 0

    def test_temperature_ignored_when_both_greedy(self):
        # greedy sampling never reads temperature; only the greedy bit
        # must agree
        cfg = ServeConfig(num_pages=8)
        to_internal(sreq(sampling=SamplingParams(greedy=True,
                                                 temperature=9.0)),
                    req_id=0, cfg=cfg)


class TestServeConfigValidation:
    def test_bucket_not_page_multiple(self):
        with pytest.raises(ValueError, match="multiples of"):
            ServeConfig(page_size=4, num_pages=8, aot_buckets=(6,))

    def test_bucket_beyond_reach(self):
        with pytest.raises(ValueError, match="reach"):
            ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=2,
                        aot_buckets=(16,))

    def test_buckets_normalized_sorted_unique(self):
        cfg = ServeConfig(page_size=4, num_pages=64,
                          aot_buckets=(16, 8, 16))
        assert cfg.aot_buckets == (8, 16)

    def test_empty_buckets_become_none(self):
        assert ServeConfig(num_pages=8, aot_buckets=()).aot_buckets is None

    def test_bad_kv_dtype_and_mesh(self):
        with pytest.raises(ValueError, match="kv_dtype"):
            ServeConfig(num_pages=8, kv_dtype="fp8")
        with pytest.raises(ValueError, match="serve_mesh"):
            ServeConfig(num_pages=8, serve_mesh="ring")


class TestFromArgs:
    def _parse(self, argv):
        ap = argparse.ArgumentParser()
        ServeConfig.add_args(ap)
        return ap.parse_args(argv)

    def test_defaults_round_trip(self):
        cfg = ServeConfig.from_args(self._parse([]))
        assert cfg.page_size == 8 and cfg.aot_buckets is None
        assert cfg.kv_dtype == "native" and cfg.serve_mesh == "off"

    def test_bucket_flag_parses_and_off(self):
        cfg = ServeConfig.from_args(
            self._parse(["--aot-buckets", "16,8", "--page-size", "4"]))
        assert cfg.aot_buckets == (8, 16)
        assert ServeConfig.from_args(
            self._parse(["--aot-buckets", "off"])).aot_buckets is None

    def test_overrides_win(self):
        cfg = ServeConfig.from_args(self._parse(["--max-batch", "2"]),
                                    max_batch=7, max_pages_per_seq=5)
        assert cfg.max_batch == 7 and cfg.max_pages_per_seq == 5

    def test_invalid_flag_combo_raises(self):
        with pytest.raises(ValueError, match="multiples of"):
            ServeConfig.from_args(
                self._parse(["--aot-buckets", "6", "--page-size", "4"]))

    def test_describe_names_the_knobs(self):
        cfg = ServeConfig.from_args(
            self._parse(["--aot-buckets", "8", "--page-size", "4",
                         "--kv-dtype", "int8"]))
        d = cfg.describe()
        for needle in ("page_size=4", "int8", "8"):
            assert needle in d


class TestServeResultTiming:
    def test_ttft_tpot_math(self):
        t = RequestTiming(enqueue=1.0, first_token=1.5, last_token=2.5)
        res = ServeResult(req_id=0, tokens=(1, 2, 3, 4, 5), status="done",
                          timing=t, pages_peak=2)
        assert res.ttft == pytest.approx(0.5)
        assert res.tpot == pytest.approx(1.0 / 4)

    def test_single_token_tpot_no_div_zero(self):
        t = RequestTiming(enqueue=0.0, first_token=1.0, last_token=1.0)
        res = ServeResult(req_id=0, tokens=(1,), status="done",
                          timing=t, pages_peak=1)
        assert res.tpot == 0.0


class TestTypedSubmitDrain:
    def test_auto_req_id_and_typed_results(self):
        router, _ = make_router()
        rids = [router.submit(sreq(prompt_len=4 + i, max_new=4))
                for i in range(3)]
        assert rids == [0, 1, 2]
        results = router.drain()
        assert set(results) == {0, 1, 2}
        for rid, res in results.items():
            assert isinstance(res, ServeResult)
            assert res.status == "done"
            assert list(res.tokens) == [int(token_for(rid, j))
                                        for j in range(4)]
            assert res.pages_peak > 0
            assert res.timing.enqueue <= res.timing.first_token \
                <= res.timing.last_token
            assert res.ttft > 0

    def test_explicit_id_advances_allocator(self):
        router, _ = make_router()
        assert router.submit(sreq(req_id=5)) == 5
        assert router.submit(sreq()) == 6      # allocator skipped past 5

    def test_internal_request_is_a_hard_type_error(self):
        """The one-PR deprecation shim is gone: Engine/Router.submit take
        ONLY ServeRequest; scheduler-plane harnesses keep the internal
        type via Scheduler.submit (exercised right after the rejection)."""
        router, _ = make_router()
        internal = Request(req_id=0, prompt=np.arange(1, 6, dtype=np.int32),
                           max_new_tokens=4)
        with pytest.raises(TypeError, match="ServeRequest"):
            router.submit(internal)
        # the scheduler-plane door stays open for harnesses
        router.replicas[0].scheduler.submit(internal)
        results = router.drain()
        assert list(results[0].tokens) == expected_output(internal)


class TestTimerCapturePoint:
    def test_stream_lag_cannot_skew_ttft_tpot(self):
        """The regression this PR's timing satellite exists for: stamps
        are captured by the scheduler at host-visible commit, so a
        stream callback blocked for ~100ms per event must leave
        TTFT/TPOT at fault-plane scale (microseconds), not callback
        scale."""
        gate = threading.Event()

        def blocked(ev):
            gate.wait(timeout=10.0)

        router, _ = make_router()
        n_new = 4
        rid = router.submit(sreq(max_new=n_new, stream_callback=blocked))
        t0 = time.perf_counter()
        # drive to completion while the detokenizer is wedged: run() does
        # not touch the stream thread
        router.run()
        elapsed = time.perf_counter() - t0
        req = router.done[rid]
        span = req.t_last_token - req.t_first_token
        assert span <= elapsed            # stamped during the run, pre-drain
        gate.set()
        results = router.drain()          # delivery happens ONLY now
        assert results[rid].tpot * (n_new - 1) == pytest.approx(span)

    def test_enqueue_stamped_at_router_entry(self):
        """Global-queue wait is part of TTFT: the router stamps
        t_enqueue at submit, before any replica sees the request."""
        router, _ = make_router()
        rid = router.submit(sreq())
        t_submitted = time.perf_counter()
        queued = router.replicas[0].scheduler.queue[0]
        assert queued.req_id == rid
        assert 0.0 < queued.t_enqueue <= t_submitted
        time.sleep(0.02)                  # queue wait before any step
        results = router.drain()
        assert results[rid].ttft >= 0.02
