"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the split serving engine (host Scheduler = policy plane, device
Executor = data plane; see ``repro/serve/engine.py``) on a reduced config
and reports the paper-aligned statistics: translation bursts, page faults,
context-switch bytes/cycles, page-table delta uploads, tokens/s.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=64,
                    help="small pools force preemption (context switches)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="preload a shared prefix; requests fork from it "
                         "(continuation prefill through the Executor)")
    ap.add_argument("--max-horizon", type=int, default=8,
                    help="fused decode horizon cap: up to K chained decode "
                         "steps per dispatch with on-device sampling "
                         "(1 disables fusion)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas behind the ReplicaRouter: N "
                         "independent Scheduler+Executor pairs (each with "
                         "its own KV pools / page table) fed from one "
                         "global admission queue; 1 = the plain engine")
    ap.add_argument("--route-policy", default="least_loaded",
                    choices=("least_loaded", "round_robin"),
                    help="replica placement policy (fork affinity is "
                         "always enforced on top: COW forks stay on a "
                         "prefix-holding replica)")
    ap.add_argument("--serve-mesh", default="off",
                    help="shard the executor's KV pools over a ('kv','hd') "
                         "serve mesh: 'auto' factors all visible devices "
                         "(force some on CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8), an "
                         "integer caps the device count, 'off' (default) "
                         "keeps single-device placement; Pallas kernels "
                         "stay LIVE on the mesh via shard_map")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the radix prefix cache: admissions whose "
                         "prompts share leading whole pages with a resident "
                         "run no longer COW-map them automatically (explicit "
                         "--prefix-len forking still works)")
    ap.add_argument("--no-kernels", action="store_true",
                    help="explicit escape hatch: dispatch every compute "
                         "step through the jnp reference twin instead of "
                         "the Pallas kernels (counted as "
                         "ref_path_dispatches in the final stats)")
    ap.add_argument("--kv-dtype", choices=("native", "int8"),
                    default="native",
                    help="KV pool storage dtype: int8 stores quantized "
                         "pages (doubling+ effective pool reach, shrinking "
                         "spill bytes by the itemsize ratio); the paged-"
                         "attention kernels dequantize in VMEM, so the "
                         "kernel path stays live (quant_dispatches)")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.family in ("rwkv6", "hybrid_rglru"):
        raise SystemExit(
            f"{args.arch}: engine drives paged-KV transformers; recurrent "
            "families decode via model.decode_step (see examples/)"
        )
    # kernels are the default serving path everywhere (single device AND
    # mesh); --no-kernels flips the executor onto the jnp twin instead of
    # rebuilding a kernel-free model, so the hatch is visible in counters
    model = build_model(cfg, remat=False, use_kernels=True)
    params = model.init(jax.random.PRNGKey(args.seed))
    mesh = None
    if args.serve_mesh != "off":
        from repro.launch.mesh import make_host_serve_mesh
        n_dev = None if args.serve_mesh == "auto" else int(args.serve_mesh)
        mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim, n_dev)
        print(f"serve mesh: {dict(mesh.shape)} over {mesh.size} of "
              f"{jax.device_count()} visible devices (KV pools sharded, "
              "page table replicated)")
    serve_cfg = ServeConfig(
        page_size=args.page_size, num_pages=args.num_pages,
        max_pages_per_seq=max(
            4, (args.prefix_len + args.prompt_len + args.max_new_tokens)
            // args.page_size + 2
        ),
        max_batch=args.max_batch,
        max_horizon=args.max_horizon,
        use_ref_path=args.no_kernels,
        prefix_cache=not args.no_prefix_cache,
        kv_dtype=args.kv_dtype,
    )
    engines = [Engine(model, params, serve_cfg, mesh=mesh)
               for _ in range(max(1, args.replicas))]
    eng = engines[0]
    router = None
    if args.replicas > 1:
        from repro.serve import ReplicaRouter
        router = ReplicaRouter(
            [e.as_replica(i) for i, e in enumerate(engines)],
            policy=args.route_policy,
        )
        print(f"replica router: {args.replicas} replicas "
              f"({args.route_policy}; each {args.num_pages} frames, "
              f"max_batch {args.max_batch})")
    rng = np.random.default_rng(args.seed)
    share = args.prefix_len > 0
    if share:
        prefix = rng.integers(0, cfg.vocab_size,
                              size=args.prefix_len).astype(np.int32)
        for e in engines:     # every replica can parent COW forks
            e.preload_prefix(prefix)
    front = router if router is not None else eng
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        shape = (plen, cfg.num_codebooks) if (
            cfg.family == "audio" and cfg.num_codebooks > 1
        ) else (plen,)
        front.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32),
            max_new_tokens=args.max_new_tokens,
            share_prefix=share,
        ))
    t0 = time.perf_counter()
    done = front.run()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    total_tokens = sum(len(r.output) for r in done.values())
    n_done = sum(1 for r in done.values() if r.status == "done")
    n_failed = sum(1 for r in done.values() if r.status == "failed")
    print(f"completed {n_done}/{args.requests} requests "
          f"({n_failed} failed reach checks), "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU interpret)")
    if router is not None:
        r = router.counters
        print(f"router: {r.get('placements')} placements "
              f"({', '.join(str(r.get(f'placements_replica{i}')) for i in range(args.replicas))} per replica), "
              f"{r.get('migrations_declined')} migrations declined, "
              f"{r.get('cross_replica_queue_waits')} queue-wait steps")
        print("router global counters:", dict(router.global_counters()))
        print("router global pages:", router.global_page_report())
        router.check_invariants()
        print("-- replica 0 detail --")
    print("scheduler (policy plane) counters:", stats["counters"])
    print("executor (data plane): context switches:", stats["switch_stats"])
    print(f"  page-table delta uploads: "
          f"{stats['counters'].get('ptab_rows_uploaded', 0)} rows in "
          f"{stats['counters'].get('ptab_syncs', 0)} syncs over "
          f"{eng.scheduler.step_i} steps "
          f"(seed engine: {eng.scheduler.step_i * eng.cfg.max_batch} rows)")
    c = eng.counters
    print(f"  kernel dispatch: {c.get('kernel_dispatches')} kernel / "
          f"{c.get('ref_path_dispatches')} ref-path compute steps, "
          f"{c.get('prefill_bytes_gathered')} B continuation-prefill KV "
          f"gathered")
    kp, vp = eng.kv.k_pools, eng.kv.v_pools
    per_page = (int(kp.nbytes) + int(vp.nbytes)) // kp.shape[1]
    print(f"  kv pools: dtype={kp.dtype} ({args.kv_dtype}), "
          f"{per_page} B/page across {kp.shape[1]} frames, "
          f"{c.get('quant_dispatches')} quantized compute steps")
    print(f"  fused decode horizon: mean "
          f"{c.get('decode_horizon') / max(c.get('decode_dispatches'), 1):.2f}"
          f" over {c.get('decode_dispatches')} dispatches, "
          f"{c.ratio('host_syncs', 'decode_tokens'):.3f} host syncs/token, "
          f"{c.get('horizon_collapses')} pool-pressure collapses")
    print(f"  radix prefix cache: {c.get('prefix_hits')} hits, "
          f"{c.get('pages_reused')} pages reused, "
          f"{c.get('prefill_tokens_skipped')} prefill tokens skipped, "
          f"{c.get('shared_restores')} shared restores")
    print("pool:", stats["pool"])


if __name__ == "__main__":
    main()
