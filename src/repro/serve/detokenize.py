"""Background detokenize/stream thread — host post-processing off the hot
path.

The scheduler commits tokens at host-visible points (``finish_prefill`` /
``commit_decode``); detokenization and the per-request stream callbacks
are *host* work that would otherwise sit between two device dispatches.
:class:`AsyncDetokenizer` moves it onto a single background consumer
thread so it overlaps device execution:

  * the scheduler ``push``es ``(request, token, final)`` at each commit —
    a queue append, nothing else, so the policy loop never blocks on a
    slow callback;
  * ONE consumer thread drains the queue in FIFO order, detokenizes and
    invokes the request's ``stream_callback`` with a
    :class:`~repro.serve.api.StreamEvent` — a single consumer makes the
    delivery order exactly the global commit order, per request and
    across requests;
  * ``drain()`` blocks until every pushed event has been delivered and
    then re-raises the first callback/detokenizer exception, so errors
    surface at a deterministic point instead of dying on a daemon
    thread (the scheduler's commit loop is never unwound mid-batch);
  * timing is NOT captured here: TTFT/TPOT stamps live on the request,
    written by the scheduler at commit (see
    :class:`~repro.serve.api.RequestTiming`), so stream lag cannot skew
    SLO numbers;
  * ``detok_backlog_peak`` records the deepest the queue ever got — the
    observable for "host post-processing is falling behind the device".

The thread starts lazily on the first push (engines that never stream
never spawn it) and is a daemon, so an abandoned engine cannot hang
interpreter shutdown; ``close()`` retires it deterministically.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable

import numpy as np

from repro.serve.api import StreamEvent

__all__ = ["AsyncDetokenizer", "default_detokenize"]

_SENTINEL = object()


def default_detokenize(token: Any) -> str:
    """Placeholder vocabulary-free detokenizer: the token id as text.

    Real deployments pass a tokenizer's ``decode``; the serving stack
    only needs *some* token->text function to exercise the streaming
    pipeline (ordering, backlog, drain semantics are tokenizer-blind).
    """
    if token is None:
        return ""
    if np.ndim(token) == 0:
        return f"<{int(token)}>"
    return "<" + ",".join(str(int(t)) for t in np.ravel(token)) + ">"


class AsyncDetokenizer:
    """Ordered background detokenize + stream-callback delivery."""

    def __init__(self, detokenize: Callable[[Any], str] | None = None,
                 counters=None):
        self._detok = detokenize or default_detokenize
        self._counters = counters
        self._q: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()
        self._exc: BaseException | None = None
        self._next_index: dict[int, int] = {}
        self._closed = False

    # ------------------------------------------------------------------
    # producer side (the scheduler's commit points)
    # ------------------------------------------------------------------

    def push(self, req, token: Any, final: bool) -> None:
        """Enqueue one committed token for ``req`` (no-op for requests
        without a ``stream_callback``).  Called by the scheduler at the
        commit point; must never block or raise on the policy path —
        callback exceptions surface on :meth:`drain`/:meth:`close`."""
        cb = getattr(req, "stream_callback", None)
        if cb is None:
            return
        if self._closed:
            raise RuntimeError("AsyncDetokenizer is closed")
        self._ensure_thread()
        idx = self._next_index.get(req.req_id, 0)
        self._next_index[req.req_id] = idx + 1
        self._q.put((req.req_id, idx, token, cb, final,
                     getattr(req, "t_last_token", 0.0)))
        if self._counters is not None:
            depth = self._q.qsize()
            if depth > self._counters.get("detok_backlog_peak"):
                # a peak, not an increment — written directly (the
                # counter dict is open-vocabulary)
                self._counters.counters["detok_backlog_peak"] = depth

    # ------------------------------------------------------------------
    # consumer thread
    # ------------------------------------------------------------------

    def _ensure_thread(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        with self._lock:
            if self._thread is None or not self._thread.is_alive():
                self._thread = threading.Thread(
                    target=self._worker, name="serve-detokenize",
                    daemon=True,
                )
                self._thread.start()

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _SENTINEL:
                    return
                req_id, idx, token, cb, final, t_commit = item
                try:
                    text = self._detok(token)
                    cb(StreamEvent(req_id=req_id, index=idx, token=token,
                                   text=text, final=final,
                                   t_commit=t_commit))
                except BaseException as e:   # noqa: BLE001 — surfaced on drain
                    if self._exc is None:
                        self._exc = e
            finally:
                self._q.task_done()

    # ------------------------------------------------------------------
    # shutdown / synchronization
    # ------------------------------------------------------------------

    @property
    def backlog(self) -> int:
        return self._q.qsize()

    def drain(self) -> None:
        """Block until every pushed event has been delivered; re-raise
        the first exception a callback (or the detokenizer) raised."""
        if self._thread is not None:
            self._q.join()
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def close(self) -> None:
        """Drain, stop the consumer thread, and refuse further pushes.
        Idempotent; re-raises like :meth:`drain`."""
        if self._closed:
            return
        self._closed = True
        if self._thread is not None:
            self._q.join()
            self._q.put(_SENTINEL)
            self._thread.join(timeout=10.0)
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc
