"""Global radix prefix cache — implicit COW page reuse for the host plane.

AraOS's result is that virtual-memory overhead stays negligible only when
translation state is *reused* rather than re-derived per access; the
serving analogue at page granularity is KV-prefix reuse.  COW fork sharing
(``VirtualMemory.fork_seq`` + the router's fork affinity) only shares
prefixes along **explicit** fork edges — a request must say
``share_prefix=True`` and name no prefix but the engine-resident one.  At
millions of users most shared prefixes are *implicit*: system prompts,
few-shot templates, multi-turn chat histories resubmitted verbatim.

This module is the index that makes the implicit case automatic: a
page-granularity radix trie over the **token content of resident mapped
page runs**.  Each edge is one whole page of tokens (``page_size`` of
them); each node records the set of resident sequences whose mapped pages
spell that token path.  An admission probes the trie with its prompt
(:meth:`PrefixCache.match`); on a hit the scheduler COW-maps the matched
whole pages from the owner via the *existing* ``fork_seq`` refcount
machinery — no new sharing mechanism, no fork API on the request — and
prefill starts at the first divergent page through the continuation
(``prefill_continue``) path.

Correctness rests on two invariants the scheduler maintains:

* **Registration happens only after KV commit.**  A sequence enters the
  trie (:meth:`register`) only once its prompt KV is actually written on
  the data plane (``finish_prefill`` / ``_flush_forked`` /
  ``preload_prefix``) — never at map time.  Causal attention makes page
  KV content a pure function of the token prefix, so a token-path match
  implies bit-identical committed pages.
* **Eviction is tied to residency.**  ``VirtualMemory`` fires an unmap
  hook on ``unmap_seq``/``spill_seq`` (retirement, preemption, rollback)
  and the scheduler wires it to :meth:`release`, so the trie never
  advertises pages whose frames have been freed.  Spilled sequences are
  simply dropped from the index (their restored frames would be valid
  again, but re-registration after restore is intentionally not done —
  the swap round-trip already paid the copy, and keeping the rule
  "resident == registered" keeps the trie trivially sound).

Only *committed prompt* tokens are indexed — whole pages of them; decode
appends are never registered (their tail pages mutate).  All state here is
pure Python/NumPy: this is scheduler (CVA6/OS-plane) state and must stay
importable without JAX (see ``test_scheduler_imports_no_jax_arrays``).
"""

from __future__ import annotations

import numpy as np

__all__ = ["PrefixCache"]


def _page_key(chunk: np.ndarray) -> tuple:
    """Hashable trie-edge key for one whole page of tokens."""
    return tuple(np.asarray(chunk).ravel().tolist())


class _Node:
    __slots__ = ("children", "owners")

    def __init__(self) -> None:
        self.children: dict[tuple, "_Node"] = {}
        self.owners: set[int] = set()


class PrefixCache:
    """Page-granularity radix trie over resident token runs.

    ``register(seq_id, tokens)`` indexes the whole pages of ``tokens``;
    ``match(tokens)`` returns the longest resident whole-page prefix and a
    sequence that owns it; ``release(seq_id)`` evicts a sequence's run
    (wired to the ``VirtualMemory`` unmap hook, so eviction tracks
    refcount drops automatically).
    """

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = int(page_size)
        self._root = _Node()
        #: seq_id -> list of page keys (the trie path), for O(path) release
        self._paths: dict[int, list[tuple]] = {}
        #: seq_id -> the full registered token array (lets fork children be
        #: registered with prefix+prompt content without re-reading pages)
        self._tokens: dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    def register(self, seq_id: int, tokens: np.ndarray) -> int:
        """Index ``seq_id``'s committed tokens; returns whole pages indexed.

        Re-registering a live seq_id replaces its previous run (sequences
        only ever re-register with a superset after growth, but replace
        semantics keep the call idempotent).  Runs shorter than one page
        are not indexed (nothing whole-page to share).
        """
        tokens = np.asarray(tokens)
        if seq_id in self._paths:
            self.release(seq_id)
        ps = self.page_size
        whole = len(tokens) // ps
        node = self._root
        keys: list[tuple] = []
        for p in range(whole):
            key = _page_key(tokens[p * ps:(p + 1) * ps])
            node = node.children.setdefault(key, _Node())
            node.owners.add(seq_id)
            keys.append(key)
        if keys:
            self._paths[seq_id] = keys
            self._tokens[seq_id] = tokens
        return whole

    def release(self, seq_id: int) -> None:
        """Evict ``seq_id``'s run; prunes ownerless leaf-ward nodes.
        No-op for unregistered ids (the unmap hook fires for every
        sequence, registered or not)."""
        keys = self._paths.pop(seq_id, None)
        self._tokens.pop(seq_id, None)
        if keys is None:
            return
        node = self._root
        chain: list[tuple[_Node, tuple, _Node]] = []
        for key in keys:
            child = node.children.get(key)
            if child is None:       # defensive: never happens if register/
                break               # release stay symmetric
            chain.append((node, key, child))
            node = child
        for parent, key, child in reversed(chain):
            child.owners.discard(seq_id)
            if not child.owners and not child.children:
                del parent.children[key]

    # ------------------------------------------------------------------
    # probe
    # ------------------------------------------------------------------

    def match(self, tokens: np.ndarray) -> tuple[int, int | None]:
        """Longest resident whole-page prefix of ``tokens``.

        Returns ``(matched_tokens, owner_seq_id)`` with ``matched_tokens``
        a multiple of ``page_size`` (0 with owner ``None`` on a miss).
        The owner is any sequence registered through the deepest matched
        node — its first ``matched_tokens // page_size`` mapped pages
        spell exactly this token path (ties break to the smallest id, so
        a pinned engine prefix, conventionally id -1, wins).
        """
        tokens = np.asarray(tokens)
        ps = self.page_size
        node = self._root
        depth = 0
        owner: int | None = None
        for p in range(len(tokens) // ps):
            child = node.children.get(_page_key(tokens[p * ps:(p + 1) * ps]))
            if child is None or not child.owners:
                break
            node = child
            depth = p + 1
            owner = min(child.owners)
        return depth * ps, owner

    # ------------------------------------------------------------------
    # queries / invariants
    # ------------------------------------------------------------------

    def tokens_of(self, seq_id: int) -> np.ndarray | None:
        """The token array ``seq_id`` was registered with (None if not
        registered)."""
        return self._tokens.get(seq_id)

    def __contains__(self, seq_id: int) -> bool:
        return seq_id in self._paths

    @property
    def num_runs(self) -> int:
        return len(self._paths)

    def check_invariants(self) -> None:
        """Trie/bookkeeping consistency (property-tested):

        * every registered run's path is walkable and owned at each node;
        * every owner recorded anywhere in the trie is a registered run;
        * no ownerless leaf survives a release (no leaks).
        """
        for seq_id, keys in self._paths.items():
            node = self._root
            for key in keys:
                assert key in node.children, f"broken path for {seq_id}"
                node = node.children[key]
                assert seq_id in node.owners, f"unowned node for {seq_id}"

        def walk(node: _Node) -> None:
            for key, child in node.children.items():
                assert child.owners or child.children, "leaked empty node"
                for owner in child.owners:
                    assert owner in self._paths, f"stale owner {owner}"
                walk(child)

        walk(self._root)
