"""Qwen2-72B — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", family="dense", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
    head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen2-72b-reduced", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
    head_dim=16, qkv_bias=True, param_dtype="float32",
)
