"""Substrate tests: optimizer, checkpointing, data pipeline, compression,
fault-tolerant trainer."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLMStream
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
)
from repro.optim.compression import compress_decompress, init_error_state
from repro.train import Trainer

KEY = jax.random.PRNGKey(0)


class TestOptimizer:
    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(base_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lrs = [float(cosine_schedule(jnp.int32(s), cfg)) for s in
               (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert 0.1 < lrs[3] < 1.0
        assert lrs[4] == pytest.approx(0.1)

    def test_clipping(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_adamw_moves_toward_minimum(self):
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(base_lr=0.5, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
        for _ in range(100):
            grads = {"w": params["w"]}  # d/dw of w^2/2
            params, state, _ = adamw_update(grads, state, params, cfg)
        assert np.abs(np.asarray(params["w"])).max() < 0.5

    @settings(max_examples=20, deadline=None)
    @given(st.floats(0.01, 10.0))
    def test_bias_correction_first_step(self, g0):
        """After one step from zero moments, update ~ lr (sign descent)."""
        params = {"w": jnp.array([0.0])}
        state = adamw_init(params)
        cfg = AdamWConfig(base_lr=1e-2, warmup_steps=0, total_steps=100_000,
                          weight_decay=0.0)
        params, _, _ = adamw_update({"w": jnp.array([g0])}, state, params, cfg)
        assert float(params["w"][0]) == pytest.approx(-1e-2, rel=1e-2)


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 1000))
    def test_error_feedback_unbiased_over_time(self, seed):
        """Accumulated compressed updates converge to accumulated true."""
        rng = np.random.default_rng(seed)
        x_true = jnp.zeros((64,))
        err = jnp.zeros((64,))
        acc_hat = np.zeros((64,))
        acc_true = np.zeros((64,))
        for _ in range(20):
            g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
            g_hat, err = compress_decompress(g, err)
            acc_hat += np.asarray(g_hat)
            acc_true += np.asarray(g)
        # residual bounded by one quantization step, not accumulated
        resid = np.abs(acc_hat - acc_true).max()
        assert resid <= np.abs(acc_true).max() * 0.2 + 0.2

    def test_wire_format_is_int8(self):
        from repro.optim.compression import quantize_int8
        q, scale = quantize_int8(jnp.asarray(np.random.randn(128) * 3))
        assert q.dtype == jnp.int8
        assert float(scale) > 0


class TestCheckpoint:
    def test_atomic_roundtrip(self):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "nested": {"b": jnp.ones((2,), jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 7, tree)
            assert ckpt.latest_step(d) == 7
            out = ckpt.restore(d, 7, jax.eval_shape(lambda: tree))
            np.testing.assert_array_equal(np.asarray(out["a"]),
                                          np.asarray(tree["a"]))
            np.testing.assert_array_equal(np.asarray(out["nested"]["b"]),
                                          np.asarray(tree["nested"]["b"]))

    def test_garbage_collection_keeps_newest(self):
        tree = {"x": jnp.zeros((2,))}
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3, 4):
                ckpt.save(d, s, tree)
            ckpt.garbage_collect(d, keep=2)
            steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                           if n.startswith("step_"))
            assert steps == [3, 4]

    def test_async_checkpointer(self):
        tree = {"x": jnp.arange(4.0)}
        with tempfile.TemporaryDirectory() as d:
            ac = ckpt.AsyncCheckpointer(d, keep=2)
            ac.save_async(1, tree)
            ac.wait()
            assert ckpt.latest_step(d) == 1

    def test_missing_leaf_is_loud(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"a": jnp.zeros((2,))})
            with pytest.raises(ValueError, match="missing leaves"):
                ckpt.restore(d, 1, {"a": jnp.zeros((2,)),
                                    "b": jnp.zeros((3,))})


class TestDataPipeline:
    def test_deterministic_by_step(self):
        cfg = get_config("granite-8b", reduced=True)
        shape = ShapeConfig("t", 16, 4, "train")
        s1 = SyntheticLMStream(cfg, shape)
        s2 = SyntheticLMStream(cfg, shape)
        b1, b2 = s1.batch(5), s2.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = s1.batch(6)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_shapes_and_mask(self):
        cfg = get_config("qwen2-vl-7b", reduced=True)
        shape = ShapeConfig("t", 32, 4, "train")
        b = SyntheticLMStream(cfg, shape).batch(0)
        assert b["tokens"].shape == (4, 32)
        assert b["positions"].shape == (3, 4, 32)
        assert b["vision_embeds"].shape[0] == 4
        assert set(np.unique(b["mask"])) <= {0.0, 1.0}
        assert (b["mask"] == 0).any()  # document boundaries exist

    def test_audio_batch_has_codebooks(self):
        cfg = get_config("musicgen-large", reduced=True)
        shape = ShapeConfig("t", 16, 2, "train")
        b = SyntheticLMStream(cfg, shape).batch(0)
        assert b["tokens"].shape == (2, 16, cfg.num_codebooks)


class TestTrainerFaultTolerance:
    def test_resume_is_bit_identical(self):
        """20 straight steps == 10 steps + crash + resume + 10 steps."""
        cfg = get_config("granite-8b", reduced=True)
        model = build_model(cfg, remat=False)
        shape = ShapeConfig("t", 16, 4, "train")
        stream = SyntheticLMStream(cfg, shape)
        opt = AdamWConfig(base_lr=1e-3, warmup_steps=2, total_steps=30)
        batch_fn = lambda s: {k: jnp.asarray(v)
                              for k, v in stream.batch(s).items()}

        with tempfile.TemporaryDirectory() as d1:
            tr = Trainer(model, opt, ckpt_dir=d1, ckpt_every=100)
            p, o, s0 = tr.init_or_restore(KEY)
            p_straight, _, _ = tr.run(p, o, batch_fn, s0, 20)

        with tempfile.TemporaryDirectory() as d2:
            tr1 = Trainer(model, opt, ckpt_dir=d2, ckpt_every=10)
            p, o, s0 = tr1.init_or_restore(KEY)
            tr1.run(p, o, batch_fn, s0, 10)
            # "crash": new trainer object resumes from disk
            tr2 = Trainer(model, opt, ckpt_dir=d2, ckpt_every=10)
            p2, o2, s2 = tr2.init_or_restore(jax.random.PRNGKey(999))
            assert s2 == 10
            p_resumed, _, _ = tr2.run(p2, o2, batch_fn, s2, 20)

        for a, b in zip(jax.tree.leaves(p_straight),
                        jax.tree.leaves(p_resumed)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-6, atol=1e-6,
            )


class TestStragglerMonitor:
    def test_flags_slow_step_and_ewma_excludes_it(self):
        from repro.train import StragglerMonitor

        fired = []
        mon = StragglerMonitor(threshold=3.0, warmup_steps=3,
                               on_straggler=fired.append)
        for step in range(10):
            assert not mon.heartbeat(step, 0.1)
        assert mon.heartbeat(10, 1.0)          # 10x the EWMA
        assert fired and fired[0].ratio > 3
        # the outlier must not be absorbed into the EWMA
        assert abs(mon.ewma - 0.1) < 0.02
        assert mon.heartbeat(11, 1.0)          # persistent straggler refires

    def test_warmup_suppresses(self):
        from repro.train import StragglerMonitor

        mon = StragglerMonitor(threshold=2.0, warmup_steps=5)
        assert not mon.heartbeat(0, 0.1)
        assert not mon.heartbeat(1, 10.0)      # within warmup

    def test_gradual_drift_adapts(self):
        from repro.train import StragglerMonitor

        mon = StragglerMonitor(threshold=3.0, alpha=0.5, warmup_steps=2)
        t = 0.1
        for step in range(30):
            flagged = mon.heartbeat(step, t)
            assert not flagged, (step, t, mon.ewma)
            t *= 1.2                            # slow drift, never 3x EWMA


class TestPageSizeSweep:
    def test_tradeoff_monotonicity(self):
        from benchmarks.bench_page_size import run_trace

        r8, r64 = run_trace(8), run_trace(64)
        assert r8["tx_per_token"] > r64["tx_per_token"]       # more bursts
        assert r8["fragmentation"] < r64["fragmentation"]     # less waste
