"""Sharding-rule unit tests + an end-to-end mini dry-run on 8 host devices.

The mini dry-run executes in a subprocess (jax locks the device count at
first init, and the main test process must keep seeing 1 device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_spec_rules_tp_and_fsdp():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import _spec_for

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    key = lambda *names: tuple(
        type("K", (), {"key": n})() for n in names
    )
    # attention projection: TP on output dim, FSDP on input dim
    assert _spec_for(key("blocks", "sub0", "attn", "wq"),
                     (28, 4096, 8192), m) == P(None, "data", "model")
    # row-parallel output projection
    assert _spec_for(key("blocks", "sub0", "attn", "wo"),
                     (28, 8192, 4096), m) == P(None, "model", "data")
    # embedding: vocab-parallel
    assert _spec_for(key("embed",), (152064, 8192), m) == P("model", "data")
    # norm scales replicated
    assert _spec_for(key("blocks", "ln1", "scale"), (28, 4096), m) == P(
        None, None
    )
    # indivisible vocab degrades gracefully (49155 % 16 != 0)
    spec = _spec_for(key("embed",), (49155, 1024), m)
    assert spec[0] is None
    # moe experts: expert-parallel
    assert _spec_for(key("blocks", "sub0", "mlp", "w_up"),
                     (24, 32, 1024, 512), m)[1] == "model"
    # serving: no fsdp
    assert _spec_for(key("blocks", "sub0", "attn", "wq"),
                     (28, 4096, 8192), m, use_fsdp=False) == P(
        None, None, "model"
    )
    # 2-D serve view
    assert _spec_for(key("blocks", "sub0", "attn", "wk"),
                     (28, 4096, 512), m2d := type("M", (), {
                         "shape": {"data": 16, "kv": 4, "hd": 4},
                         "axis_names": ("data", "kv", "hd")})(),
                     use_fsdp=False, model_axes=("kv", "hd")) == P(
        None, None, ("kv", "hd")
    )


def test_skip_reasons():
    from repro.launch.specs import skip_reason

    assert skip_reason("qwen2-72b", "long_500k") is not None
    assert skip_reason("rwkv6-7b", "long_500k") is None
    assert skip_reason("recurrentgemma-9b", "long_500k") is None
    assert skip_reason("qwen2-72b", "train_4k") is None


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import compat_make_mesh, use_mesh
    from repro.launch.sharding import (batch_shardings, make_shard_hook,
                                       opt_shardings, param_shardings)
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    from repro.train import make_train_step

    mesh = compat_make_mesh((4, 2), ("data", "model"))
    cfg = get_config("{arch}", reduced=True)
    model = build_model(cfg, remat=True, shard=make_shard_hook(mesh))
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    b, s = 8, 16
    batch = {{
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }}
    step = make_train_step(model, AdamWConfig(), donate=True)
    with use_mesh(mesh):
        fn = jax.jit(step.__wrapped__,
                     in_shardings=(param_shardings(params_shape, mesh),
                                   opt_shardings(params_shape, mesh),
                                   batch_shardings(batch, mesh)),
                     donate_argnums=(0, 1))
        compiled = fn.lower(params_shape, opt_shape, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0]
    print(json.dumps({{"flops": cost.get("flops", 0.0), "ok": True}}))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["granite-8b", "granite-moe-1b-a400m",
                                  "rwkv6-7b"])
def test_mini_dryrun_compiles_on_8_devices(arch):
    """lower+compile of the sharded train step on a 4x2 host mesh."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN.format(arch=arch)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["ok"] and result["flops"] > 0


ELASTIC_RESHARD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import checkpoint as ckpt
    from repro.configs import get_config
    from repro.launch.mesh import compat_make_mesh
    from repro.launch.sharding import param_shardings
    from repro.models import build_model

    cfg = get_config("granite-8b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    def mk(shape):
        return compat_make_mesh(shape, ("data", "model"))

    mesh_a, mesh_b = mk((4, 2)), mk((2, 4))   # elastic: 4x2 -> 2x4
    sh_a = param_shardings(params, mesh_a)
    sh_b = param_shardings(params, mesh_b)
    p_a = jax.tree.map(jax.device_put, params, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, p_a)
        # restore ONTO THE OTHER MESH (reshard-on-load)
        p_b = ckpt.restore(d, 3, jax.eval_shape(lambda: params), sh_b)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually carry the target sharding
        lb = jax.tree.leaves(p_b)[1]
        assert len(lb.sharding.device_set) in (1, 2, 4, 8)
    print(json.dumps({"ok": True}))
""")


@pytest.mark.slow
def test_elastic_reshard_on_load():
    """A checkpoint written on a 4x2 mesh restores onto a 2x4 mesh with
    identical values and target shardings (the elastic-scaling path)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_RESHARD],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
