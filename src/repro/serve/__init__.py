"""Serving: continuous batching over paged virtual memory (the "OS")."""
from repro.serve.engine import Engine, Request, ServeConfig

__all__ = ["Engine", "Request", "ServeConfig"]
