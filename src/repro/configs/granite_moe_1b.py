"""Granite-3.0-1B-A400M — MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    head_dim=64, num_experts=32, experts_per_token=8, moe_d_ff=512,
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="granite-moe-1b-a400m-reduced", family="moe", num_layers=2,
    d_model=64, num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=131,
    head_dim=16, num_experts=4, experts_per_token=2, moe_d_ff=64,
    param_dtype="float32",
)
