"""Open-loop serving harness properties (``benchmarks/bench_serve_slo``):
seeded-Poisson arrival determinism and open-vs-closed-loop scheduling
transparency over the fault-plane router, with streaming attached.

The SLO bench gates real engines on these properties; this suite pins
them on host-only planes where the token streams have a closed form
(``token_for``), so a violation localizes to the harness/scheduling
logic instead of surfacing as a device-level token diff.
"""

import numpy as np
import pytest

from benchmarks.bench_serve_slo import _drive_open_loop, poisson_arrival_steps
from tests._fault_plane import make_replica, token_for
from repro.serve import AsyncDetokenizer, Replica, ReplicaRouter, ServeRequest

pytestmark = pytest.mark.slo


def make_router(n=1, **kw):
    replicas = []
    for r in range(n):
        sched, plane = make_replica(replica_id=r, **kw)
        sched.attach_stream(AsyncDetokenizer(counters=sched.counters))
        replicas.append(Replica(replica_id=r, scheduler=sched, plane=plane))
    return ReplicaRouter(replicas)


def _requests(n, sink=None, max_new=5, plen=5):
    rng = np.random.default_rng(3)
    return [
        ServeRequest(
            prompt=rng.integers(1, 1000, size=plen).astype(np.int32),
            max_new_tokens=max_new, req_id=i, stream_callback=sink,
        )
        for i in range(n)
    ]


class TestPoissonDeterminism:
    def test_same_seed_same_schedule(self):
        a = poisson_arrival_steps(4.0, 32, seed=9)
        b = poisson_arrival_steps(4.0, 32, seed=9)
        np.testing.assert_array_equal(a, b)

    def test_different_seed_differs(self):
        a = poisson_arrival_steps(4.0, 32, seed=9)
        b = poisson_arrival_steps(4.0, 32, seed=10)
        assert not np.array_equal(a, b)

    def test_shape_and_monotonicity(self):
        a = poisson_arrival_steps(2.0, 16, seed=0)
        assert a.shape == (16,) and a.dtype == np.int64
        assert (np.diff(a) >= 0).all() and a[0] >= 0

    def test_rate_scales_the_schedule(self):
        # 10x the rate => arrivals land ~10x earlier on the step clock
        slow = poisson_arrival_steps(1.0, 64, seed=1)
        fast = poisson_arrival_steps(10.0, 64, seed=1)
        assert fast[-1] < slow[-1]


class TestOpenLoopTransparency:
    @pytest.mark.parametrize("n_replicas", [1, 2])
    def test_open_vs_closed_token_identity_with_streaming(self, n_replicas):
        """Per-request streams must be independent of WHEN requests
        arrive (open-loop Poisson vs all-up-front) and of the replica
        count — and the streamed events must equal the drained results,
        in per-request index order."""
        n, max_new = 6, 5
        closed = make_router(n_replicas)
        for r in _requests(n):
            closed.submit(r)
        want = {rid: [int(t) for t in res.tokens]
                for rid, res in closed.drain().items()}
        # the fault-plane closed form: identity holds against it too
        assert want == {i: [int(token_for(i, j)) for j in range(max_new)]
                        for i in range(n)}

        streamed: dict[int, list] = {}

        def sink(ev):
            streamed.setdefault(ev.req_id, []).append(ev)

        router = make_router(n_replicas)
        arrivals = poisson_arrival_steps(3.0, n, seed=21)
        depths = _drive_open_loop(router, _requests(n, sink), arrivals)
        got = {rid: [int(t) for t in res.tokens]
               for rid, res in router.drain().items()}
        assert got == want
        assert {rid: [int(e.token) for e in evs]
                for rid, evs in streamed.items()} == want
        for rid, evs in streamed.items():
            assert [e.index for e in evs] == list(range(max_new))
            assert [e.final for e in evs] == [False] * (max_new - 1) + [True]
        assert len(depths) >= int(arrivals[-1])  # ran through the last arrival

    def test_queue_depth_trace_sees_the_backlog(self):
        """A burst arriving at step 0 against a 3-slot replica must show
        up in the depth trace (the SLO bench's queue observable)."""
        router = make_router(1)
        arrivals = np.zeros(6, np.int64)
        depths = _drive_open_loop(router, _requests(6), arrivals)
        assert max(depths) >= 3               # more work than slots
        assert depths[-1] == 0                # drained

    def test_undrained_run_raises(self):
        # one fused-horizon step delivers at most max_horizon tokens, so
        # a 25-token budget cannot drain within 2 steps — the guard must
        # fire rather than loop forever
        router = make_router(1)
        with pytest.raises(RuntimeError, match="drain"):
            _drive_open_loop(router, _requests(2, max_new=25),
                             np.zeros(2, np.int64), max_steps=2)
