"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across JAX versions.

    Newer JAX (>= 0.6) wants explicit ``axis_types``; 0.4.x has neither the
    kwarg nor ``jax.sharding.AxisType``.  Auto axes are the 0.4.x default,
    so falling back to the bare call is semantically identical.
    """
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager setting the ambient mesh across JAX versions.

    ``jax.set_mesh`` (>= 0.6) or the Mesh's own context manager (0.4.x).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh  # 0.4.x: Mesh is its own context manager


def _mk(shape, axes) -> jax.sharding.Mesh:
    return compat_make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the locally available devices (tests, examples)."""
    return _mk((data, model), ("data", "model"))


def make_serve_mesh(
    num_kv_heads: int, head_dim: int, *, multi_pod: bool = False,
    model_size: int = 16,
) -> jax.sharding.Mesh:
    """Serving mesh: the production topology with the model axis viewed as
    a 2-D ('kv', 'hd') tile.

    Same devices, same order, same physical 16x16(x2) topology as
    ``make_production_mesh`` — only the *logical* factorization of the
    model axis changes, so KV pools can shard jointly over KV heads and
    head_dim without GSPMD's "involuntary full rematerialization" (it
    cannot reshard a 1-D hd-sharding into the (kv x hd) tiling attention
    needs; see EXPERIMENTS.md §Perf iteration 1).
    """
    kv = 1
    for cand in (16, 8, 4, 2, 1):
        if cand <= model_size and num_kv_heads % cand == 0:
            kv = cand
            break
    hd = model_size // kv
    if head_dim % hd != 0:  # degrade: replicate the remainder onto kv
        kv, hd = 1, model_size
        if head_dim % hd != 0:
            raise ValueError(
                f"cannot factor model axis for Hkv={num_kv_heads}, "
                f"head_dim={head_dim}"
            )
    shape = (2, 16, kv, hd) if multi_pod else (16, kv, hd)
    axes = (("pod",) if multi_pod else ()) + ("data", "kv", "hd")
    return _mk(shape, axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh: ('pod', 'data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axis(mesh: jax.sharding.Mesh) -> str | None:
    """Axis used for parameter sharding (FSDP): intra-pod 'data' only —
    cross-pod parameter all-gathers would traverse DCI every layer."""
    return "data" if "data" in mesh.axis_names else None
