"""Unit + property tests for the paged virtual-memory core (DESIGN.md §3)."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro.core import (
    INVALID_PAGE,
    OutOfPagesError,
    PageFault,
    PagePool,
    VMemConfig,
    VirtualMemory,
    burst_trace,
    element_trace,
    logical_to_physical,
)


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------


class TestPagePool:
    def test_alloc_free_roundtrip(self):
        pool = PagePool(8)
        pages = pool.alloc(5)
        assert len(set(pages)) == 5
        assert pool.num_free == 3
        pool.free(pages)
        assert pool.num_free == 8
        pool.check_invariants()

    def test_oom_raises_and_leaves_state(self):
        pool = PagePool(4)
        pool.alloc(3)
        with pytest.raises(OutOfPagesError):
            pool.alloc(2)
        assert pool.num_free == 1
        pool.check_invariants()

    def test_double_free_detected(self):
        pool = PagePool(4)
        (p,) = pool.alloc(1)
        pool.free([p])
        with pytest.raises(ValueError, match="double free"):
            pool.free([p])

    def test_share_refcounting(self):
        pool = PagePool(4)
        (p,) = pool.alloc(1)
        pool.share(p)
        pool.free([p])
        assert pool.num_free == 3  # still referenced
        pool.free([p])
        assert pool.num_free == 4
        pool.check_invariants()

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 2), min_size=1, max_size=60))
    def test_random_ops_keep_invariants(self, ops):
        """Allocator invariants hold under arbitrary alloc/free/share streams."""
        pool = PagePool(16)
        live: list[int] = []
        for op in ops:
            if op == 0 and pool.num_free:
                live += pool.alloc(1)
            elif op == 1 and live:
                p = live.pop()
                pool.free([p])
            elif op == 2 and live:
                live.append(pool.share(live[-1]))
            pool.check_invariants()


# ---------------------------------------------------------------------------
# VirtualMemory: mapping, translation, faults
# ---------------------------------------------------------------------------


CFG = VMemConfig(page_size=16, num_pages=64, max_pages_per_seq=16, max_seqs=4)


class TestVirtualMemory:
    def test_map_translate_unmap(self):
        vm = VirtualMemory(CFG)
        vm.map_seq(7, 40)
        phys = vm.translate(7, np.arange(40))
        # within each 16-token page, offsets are contiguous
        offs = phys % CFG.page_size
        np.testing.assert_array_equal(offs, np.arange(40) % 16)
        vm.unmap_seq(7)
        assert vm.pool.num_free == CFG.num_pages
        vm.check_invariants()

    def test_translation_matches_device_function(self):
        import jax.numpy as jnp

        vm = VirtualMemory(CFG)
        vm.map_seq(1, 50)
        pos = np.arange(50)
        host = vm.translate(1, pos)
        row = vm.device_page_table()[vm.seq(1).slot]
        dev = logical_to_physical(jnp.asarray(pos), row, CFG.page_size)
        np.testing.assert_array_equal(host, np.asarray(dev))

    def test_fault_vstart_is_first_bad_element(self):
        vm = VirtualMemory(CFG)
        vm.map_seq(0, 10)
        with pytest.raises(PageFault) as ei:
            vm.translate(0, np.array([3, 9, 10, 11]))
        assert ei.value.vstart == 2  # elements [0,2) committed

    def test_append_faults_on_page_crossing(self):
        vm = VirtualMemory(CFG)
        vm.map_seq(0, 16)  # exactly one full page
        faults = vm.append_tokens(0, 1)
        assert len(faults) == 1 and faults[0].logical_page == 1
        assert vm.append_tokens(0, 14) == []  # room in tail page
        assert len(vm.append_tokens(0, 2)) == 1
        vm.check_invariants()

    def test_append_oom_is_precise(self):
        """OOM during append leaves the sequence unmodified (C5 semantics)."""
        vm = VirtualMemory(VMemConfig(page_size=4, num_pages=2, max_pages_per_seq=8, max_seqs=2))
        vm.map_seq(0, 8)  # uses both pages
        with pytest.raises(OutOfPagesError):
            vm.append_tokens(0, 4)
        assert vm.seq_len(0) == 8
        vm.check_invariants()

    def test_no_aliasing_across_sequences(self):
        """Distinct (seq, position) never map to the same physical slot."""
        vm = VirtualMemory(CFG)
        vm.map_seq(0, 33)
        vm.map_seq(1, 50)
        a = vm.translate(0, np.arange(33))
        b = vm.translate(1, np.arange(50))
        assert not set(a.tolist()) & set(b.tolist())

    def test_fork_shares_whole_pages_only(self):
        vm = VirtualMemory(CFG)
        vm.map_seq(0, 40)  # 3 pages (2 full + 1 partial)
        vm.fork_seq(0, 1, 40)
        parent, child = vm.seq(0), vm.seq(1)
        assert child.pages[:2] == parent.pages[:2]      # shared full pages
        assert child.pages[2] != parent.pages[2]        # copied tail
        assert vm.pool.refcount(parent.pages[0]) == 2
        vm.unmap_seq(0)
        assert vm.pool.refcount(child.pages[0]) == 1    # survives parent
        vm.check_invariants()

    def test_slot_exhaustion(self):
        vm = VirtualMemory(CFG)
        for i in range(CFG.max_seqs):
            vm.map_seq(i, 4)
        with pytest.raises(OutOfPagesError, match="slots"):
            vm.map_seq(99, 4)

    @settings(max_examples=40, deadline=None)
    @given(
        st.lists(
            st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(1, 24)),
            min_size=1,
            max_size=40,
        )
    )
    def test_random_lifecycle_keeps_invariants(self, ops):
        """map/append/unmap streams preserve all vmem invariants."""
        vm = VirtualMemory(CFG)
        for kind, seq_id, n in ops:
            try:
                if kind == 0 and not vm.has_seq(seq_id):
                    vm.map_seq(seq_id, n)
                elif kind == 1 and vm.has_seq(seq_id):
                    vm.append_tokens(seq_id, n)
                elif kind == 2 and vm.has_seq(seq_id):
                    vm.unmap_seq(seq_id)
                elif kind == 3 and vm.has_seq(seq_id):
                    length = vm.seq_len(seq_id)
                    phys = vm.translate(seq_id, np.arange(length))
                    assert len(set(phys.tolist())) == length
            except (OutOfPagesError, ValueError):
                pass
            vm.check_invariants()


# ---------------------------------------------------------------------------
# Address traces (C2: burst vs element translation)
# ---------------------------------------------------------------------------


class TestTraces:
    def test_burst_one_translation_per_page(self):
        tr = burst_trace(np.arange(64), page_size=16)
        np.testing.assert_array_equal(tr, [0, 1, 2, 3])

    def test_burst_non_contiguous_runs(self):
        tr = burst_trace(np.array([0, 1, 40, 41, 42, 5]), page_size=16)
        np.testing.assert_array_equal(tr, [0, 2, 0])

    def test_element_translates_everything(self):
        pos = np.array([0, 1, 2, 17, 17, 40])
        tr = element_trace(pos, page_size=16)
        assert tr.shape == pos.shape
        np.testing.assert_array_equal(tr, [0, 0, 0, 1, 1, 2])

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(0, 10_000), min_size=1, max_size=200))
    def test_burst_never_more_translations_than_element(self, positions):
        pos = np.asarray(positions)
        assert burst_trace(pos, 16).size <= element_trace(pos, 16).size

    @settings(max_examples=50, deadline=None)
    @given(st.integers(1, 512), st.integers(0, 1000))
    def test_unit_stride_burst_count_is_pages_touched(self, n, start):
        pos = np.arange(start, start + n)
        expected = len(np.unique(pos // 16))
        assert burst_trace(pos, 16).size == expected


# ---------------------------------------------------------------------------
# dirty-row drain (the executor's incremental page-table sync contract)
# ---------------------------------------------------------------------------


class TestAppendTokensBatch:
    """All-or-nothing multi-sequence growth (the fused decode horizon's
    pre-fault path): either every sequence in the batch grows, or NONE
    does — batch-wide precise-exception semantics."""

    def mk(self, num_pages=12):
        return VirtualMemory(VMemConfig(
            page_size=4, num_pages=num_pages, max_pages_per_seq=8,
            max_seqs=4))

    def test_batch_matches_individual_appends(self):
        vm = self.mk()
        vm.map_seq(0, 4)
        vm.map_seq(1, 6)
        faults = vm.append_tokens_batch([(0, 8), (1, 2)])
        # seq 0: 4 -> 12 tokens crosses into pages 1 and 2; seq 1: 6 -> 8
        # fits its tail page
        assert sorted((f.seq_id, f.logical_page) for f in faults) == [
            (0, 1), (0, 2)]
        assert vm.seq_len(0) == 12 and vm.seq_len(1) == 8
        vm.check_invariants()

    def test_all_or_nothing_on_pool_exhaustion(self):
        vm = self.mk(num_pages=4)
        vm.map_seq(0, 4)
        vm.map_seq(1, 4)
        # 2 frames free; the batch wants 2 + 2.  A sequential grow would
        # have satisfied seq 0 before failing on seq 1 — the batch must
        # leave BOTH untouched instead.
        with pytest.raises(OutOfPagesError):
            vm.append_tokens_batch([(0, 8), (1, 8)])
        assert vm.seq_len(0) == 4 and vm.seq_len(1) == 4
        assert len(vm.seq(0).pages) == 1 and len(vm.seq(1).pages) == 1
        assert vm.pool.num_free == 2
        assert vm.pool.fault_count == 0
        vm.check_invariants()

    def test_reach_violation_raises_before_any_mutation(self):
        vm = self.mk()
        vm.map_seq(0, 4)
        vm.map_seq(1, 4)
        # max_pages_per_seq=8, page 4 -> 32-token reach; 4 + 30 exceeds it
        with pytest.raises(ValueError):
            vm.append_tokens_batch([(0, 2), (1, 30)])
        assert vm.seq_len(0) == 4 and vm.seq_len(1) == 4
        vm.check_invariants()

    def test_empty_and_zero_growth_are_noops(self):
        vm = self.mk()
        vm.map_seq(0, 4)
        assert vm.append_tokens_batch([]) == []
        assert vm.append_tokens_batch([(0, 0)]) == []
        assert vm.seq_len(0) == 4
        vm.check_invariants()


class TestDrainDirtyRows:
    """The dirty set must be EXACT: every mutated row, only mutated rows,
    and empty after a drain — the serving executor applies these deltas to
    its persistent device table instead of re-uploading the whole satp."""

    def mk(self, **kw):
        cfg = dict(page_size=4, num_pages=32, max_pages_per_seq=8,
                   max_seqs=4)
        cfg.update(kw)
        vm = VirtualMemory(VMemConfig(**cfg))
        vm.drain_dirty_rows()               # discard construction state
        return vm

    def drain(self, vm):
        rows, vals = vm.drain_dirty_rows()
        return list(rows), vals

    def test_map_dirties_exactly_one_row(self):
        vm = self.mk()
        s = vm.map_seq(0, 6)
        rows, vals = self.drain(vm)
        assert rows == [s.slot]
        np.testing.assert_array_equal(vals[0][:2], s.pages)
        assert (vals[0][2:] == INVALID_PAGE).all()

    def test_drain_resets_and_is_empty_when_clean(self):
        vm = self.mk()
        vm.map_seq(0, 6)
        assert self.drain(vm)[0] != []
        rows, vals = self.drain(vm)         # second drain: nothing dirty
        assert rows == [] and vals.shape == (0, 8)

    def test_tail_append_without_fault_stays_clean(self):
        vm = self.mk()
        vm.map_seq(0, 5)                    # page 1 holds tokens 4..7
        self.drain(vm)
        assert vm.append_tokens(0, 2) == [] # fits in the tail page
        assert self.drain(vm)[0] == []      # no PTE changed
        assert vm.append_tokens(0, 4) != [] # crosses into page 2
        assert self.drain(vm)[0] == [vm.seq(0).slot]

    def test_multi_seq_ops_dirty_exactly_their_rows(self):
        vm = self.mk()
        s0, s1, s2 = vm.map_seq(0, 4), vm.map_seq(1, 4), vm.map_seq(2, 4)
        self.drain(vm)
        vm.append_tokens(1, 4)              # faults a page
        vm.unmap_seq(2)
        rows, _ = self.drain(vm)
        assert rows == sorted([s1.slot, s2.slot])

    def test_spill_restore_fork_sequence_matches_full_rebuild(self):
        """Replaying every drained delta from scratch must reconstruct the
        host table exactly — after an arbitrary map/fork/spill/restore/
        unmap sequence (the executor-side equivalence lives in
        tests/test_serve_executor.py on real device state)."""
        vm = self.mk(num_pages=16)
        shadow = np.full((4, 8), INVALID_PAGE, np.int32)

        def apply_delta():
            rows, vals = vm.drain_dirty_rows()
            if len(rows):
                shadow[rows] = vals

        vm.map_seq(-1, 6)                   # prefix
        apply_delta()
        vm.fork_seq(-1, 0, 6)               # COW fork (1 whole + tail)
        vm.append_tokens(0, 5)
        apply_delta()
        vm.map_seq(1, 9)
        apply_delta()
        vm.spill_seq(1)
        apply_delta()
        vm.append_tokens(0, 3)
        vm.restore_seq(1, 9)
        apply_delta()
        vm.unmap_seq(0)
        apply_delta()
        np.testing.assert_array_equal(shadow, vm.device_page_table())
        vm.check_invariants()

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 3), min_size=1, max_size=40))
    def test_random_op_stream_deltas_rebuild_table(self, ops):
        """Property: under a random map/append/spill/restore stream the
        drained deltas always rebuild the table, and a clean vmem drains
        empty."""
        vm = self.mk(num_pages=24)
        shadow = np.full((4, 8), INVALID_PAGE, np.int32)
        live, swapped, next_id = [], [], 0
        for op in ops:
            try:
                if op == 0:                           # map a new seq
                    vm.map_seq(next_id, 5)
                    live.append(next_id)
                    next_id += 1
                elif op == 1 and live:                # grow the oldest
                    vm.append_tokens(live[0], 3)
                elif op == 2 and live:                # spill the newest
                    sid = live.pop()
                    vm.spill_seq(sid)
                    swapped.append(sid)
                elif op == 3 and swapped:             # restore FIFO
                    sid = swapped.pop(0)
                    vm.restore_seq(sid, 5)
                    live.append(sid)
            except (OutOfPagesError, ValueError):
                pass                                  # stream may overflow
            rows, vals = vm.drain_dirty_rows()
            if len(rows):
                shadow[rows] = vals
        np.testing.assert_array_equal(shadow, vm.device_page_table())
        rows, _ = vm.drain_dirty_rows()
        assert len(rows) == 0
        vm.check_invariants()
