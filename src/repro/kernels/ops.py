"""Public jit'd wrappers for the kernel package.

These handle shape padding to block multiples, block-size selection, and
(for the gather path) the beyond-paper burst-coalescing optimization, so the
rest of the framework never deals with tiling details.  Every wrapper
dispatches to the Pallas kernel (``use_kernel=True``, default) or the pure
jnp oracle (``use_kernel=False`` — the XLA-native path used by dry-runs).

Dispatch contract: ``use_kernel`` is the ONLY thing that routes to the
oracle.  In particular int8 pools (a non-``None`` ``kv_scale``) no longer
force the ref path — the paged-attention kernels take the scale as a
third scalar-prefetch operand and dequantize each K/V tile in VMEM after
its burst lands, so quantized serving keeps the page-streaming bytes win
(and the ``*_sharded`` wrappers thread ``kv_scale`` through their shard
bodies, so it survives the ('kv', 'hd') mesh too).  The paged copies are
dtype-agnostic: they move whatever element type the pool holds, so a
quantized write is the same burst at the narrow itemsize.

The Pallas kernels assume a single device's pool view (scalar-prefetched
page tables index local frames; no partitioning annotations), so they must
not be traced BARE into a computation laid out over a >1-device mesh.  On
a ('kv', 'hd') serve mesh the ``*_sharded`` wrappers below close that gap
with ``shard_map``: each device runs the unmodified single-device kernel
on exactly its local slice of the KV pools, with per-operand specs derived
from ``launch.mesh.kv_partition_axes`` (the same degradation rule as the
executor's committed pool layout, so the shard a kernel sees IS the shard
the executor placed there):

  * pools ``[P, page, Hkv, hd]`` shard ``P(None, None, kv, hd)``;
  * the page table and every scalar-prefetch operand (lens/starts/
    seq_lens) pass through replicated — the satp analogue every shard
    reads coherently, so page-table translation needs no communication;
  * KV-head ('kv') sharding is embarrassingly parallel: paged attention
    runs an independent online softmax per KV head, so each device
    attends its local heads end to end and the outputs merely concatenate
    along Hkv — no cross-shard reduction, no collective;
  * head_dim ('hd') sharding cuts the QK contraction axis, so the paged
    attention bodies ``all_gather`` K/V pool slices to full head_dim
    (tiled, one concat-sized collective per call) and then claim the
    replicated output every shard computed identically.  The paged copies
    never contract: they stay collective-free even under 'hd'.

The sharded serving executor dispatches through a mesh-bound model twin
(``serve.executor._mesh_kernel_model``) that routes the serve-path ops to
these wrappers, so the kernels stay LIVE under a multi-device mesh; the
old jnp ref-path twin survives only as the explicit ``--no-kernels``
escape hatch, counted by ``ref_path_dispatches``.  Single-device callers
(the kernel differential grids, engines without a mesh) keep the plain
kernel paths regardless of how many devices the process can see.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import round_up
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.matmul import matmul as _matmul_kernel
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_attn_kernel,
)
from repro.kernels.paged_prefill_attention import (
    paged_prefill_attention as _paged_prefill_kernel,
)
from repro.kernels.paged_copy import paged_copy as _paged_copy_kernel
from repro.kernels.paged_copy import paged_copy_at as _paged_copy_at_kernel
from repro.kernels.paged_gather import paged_gather as _paged_gather_kernel
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "use_kernel")
)
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype: jnp.dtype | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """``x @ y`` for arbitrary shapes (pads to MXU-aligned blocks)."""
    if not use_kernel:
        return ref.matmul_ref(x, y, out_dtype)
    m, k = x.shape
    _, n = y.shape
    bm_, bn_, bk_ = min(bm, round_up(m, 8)), min(bn, round_up(n, 128)), min(
        bk, round_up(k, 128)
    )
    mp, np_, kp = round_up(m, bm_), round_up(n, bn_), round_up(k, bk_)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _matmul_kernel(xp, yp, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "scale", "use_kernel")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Blockwise attention; pads sequence lengths to block multiples.

    Padding is appended at the *end* of both Q and KV.  For causal
    attention padded KV tokens sit above every real query's diagonal, so
    they are masked structurally; padded Q rows are sliced off.
    """
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    bq_, bk_ = min(bq, round_up(sq, 8)), min(bk, round_up(sk, 128))
    sqp, skp = round_up(sq, bq_), round_up(sk, bk_)
    if not causal and (sqp != sq or skp != sk):
        raise ValueError("non-causal flash requires block-aligned shapes")
    scale = scale if scale is not None else d ** -0.5
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    # keep the causal diagonal anchored at the *end*: pad Q and KV equally
    out = _flash_kernel(
        qp, kp_, vp, causal=causal, bq=bq_, bk=bk_, scale=scale
    )
    return out[:, :, :sq]


paged_decode_attention = jax.jit(
    lambda q, k_pool, v_pool, page_table, seq_lens, *, page_size,
    scale=None, window=None, use_kernel=True, kv_scale=None: (
        _paged_attn_kernel(
            q, k_pool, v_pool, page_table, seq_lens,
            page_size=page_size, scale=scale, window=window,
            kv_scale=kv_scale,
        )
        if use_kernel
        else ref.paged_decode_attention_ref(
            q, k_pool, v_pool, page_table, seq_lens,
            page_size=page_size, scale=scale, window=window,
            kv_scale=kv_scale,
        )
    ),
    static_argnames=("page_size", "scale", "window", "use_kernel",
                     "kv_scale"),
)


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "scale", "bq", "use_kernel", "kv_scale"),
)
def paged_prefill_attention(
    q: jax.Array,            # [B, S, Hkv, G, D] chunk queries
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32
    *,
    page_size: int,
    scale: float | None = None,
    bq: int = 32,
    use_kernel: bool = True,
    kv_scale: float | None = None,
) -> jax.Array:
    """Continuation-chunk attention through the page table.

    Kernel path streams KV pages per query block (one translation per
    page-bounded burst, pages above the causal diagonal skipped); the ref
    path gathers the whole logical prefix (the pre-kernel hot path, kept
    as the differential oracle).  int8 pools (``kv_scale``) dequantize
    INSIDE the kernel — the scale rides in the scalar-prefetch plane and
    tiles upcast in VMEM after the burst, so quantization keeps the
    page-streaming bytes win instead of forcing the gather path.
    """
    if use_kernel:
        return _paged_prefill_kernel(
            q, k_pool, v_pool, page_table, starts,
            page_size=page_size, scale=scale, bq=bq, kv_scale=kv_scale,
        )
    return ref.paged_prefill_attention_ref(
        q, k_pool, v_pool, page_table, starts,
        page_size=page_size, scale=scale, kv_scale=kv_scale,
    )


# ---------------------------------------------------------------------------
# paged memory movement
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_copy(
    src: jax.Array,
    pool: jax.Array,
    page_table: jax.Array,
    lens: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    if use_kernel:
        return _paged_copy_kernel(
            src, pool, page_table, lens, page_size=page_size
        )
    return ref.paged_copy_ref(src, pool, page_table, lens, page_size=page_size)


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_copy_at(
    src: jax.Array,
    pool: jax.Array,
    page_table: jax.Array,
    starts: jax.Array,
    lens: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    """Burst copy at arbitrary logical start offsets (continuation prefill)."""
    if use_kernel:
        return _paged_copy_at_kernel(
            src, pool, page_table, starts, lens, page_size=page_size
        )
    return ref.paged_copy_at_ref(
        src, pool, page_table, starts, lens, page_size=page_size
    )


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_gather(
    pool: jax.Array,
    page_table_row: jax.Array,
    positions: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    """Indexed gather, one translation per element (the paper's C2 cost)."""
    if use_kernel:
        return _paged_gather_kernel(
            pool, page_table_row, positions, page_size=page_size
        )
    return ref.paged_gather_ref(
        pool, page_table_row, positions, page_size=page_size
    )


@functools.partial(jax.jit, static_argnames=("page_size",))
def paged_gather_coalesced(
    pool: jax.Array,
    page_table_row: jax.Array,
    positions: jax.Array,
    *,
    page_size: int,
) -> jax.Array:
    """Beyond-paper: sort-coalesced indexed gather (per-PAGE translation).

    AraOS translates indexed accesses per element; sorting the indices first
    turns runs within a page into single bursts — the translation count
    drops from N to the number of *distinct pages touched* at the cost of a
    sort and an unpermute.  `benchmarks/bench_translation.py` quantifies the
    crossover.  Functionally identical to :func:`paged_gather`.
    """
    order = jnp.argsort(positions)
    sorted_pos = positions[order]
    gathered = ref.paged_gather_ref(
        pool, page_table_row, sorted_pos, page_size=page_size
    )
    inverse = jnp.argsort(order)
    return gathered[inverse]


# ---------------------------------------------------------------------------
# shard_map kernel dispatch over a ('kv', 'hd') serve mesh
#
# Natural-layout (4-D pool) entry points: the serve paths keep pools as
# [P, page, Hkv, hd] and K/V activations as [B, S, Hkv, hd] all the way to
# the shard_map boundary, because a (kv, hd)-sharded 4-D pool flattened to
# the kernels' merged [P, page, W=Hkv*hd] layout is NOT expressible as a
# PartitionSpec on W (the per-device slice is strided).  The merge to W
# happens INSIDE the shard body, on the local slice, where it is a plain
# local reshape.
# ---------------------------------------------------------------------------


def _shard_map(f, mesh, in_specs, out_specs):
    """``shard_map`` across JAX versions, replication checks off.

    The bodies below contain Pallas calls (opaque to the replication
    checker) and claim replicated outputs the checker cannot verify, so
    the check is disabled — correctness of the claimed specs is what the
    sharded differential grids (tests/test_kernels_sharded.py) pin down.
    """
    try:
        from jax.experimental.shard_map import shard_map
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except (ImportError, TypeError):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)


def _kv_axes(mesh, num_kv_heads: int, head_dim: int):
    # deferred import: repro.launch.__init__ -> launch.mesh only (light),
    # and only sharded callers ever need it
    from repro.launch.mesh import kv_partition_axes
    return kv_partition_axes(mesh, num_kv_heads, head_dim)


def paged_copy_sharded(
    src: jax.Array,          # [B, S, Hkv, hd]
    pool: jax.Array,         # [P, page, Hkv, hd]
    page_table: jax.Array,   # [B, max_pages] int32
    lens: jax.Array,         # [B] int32
    *,
    page_size: int,
    mesh,
    use_kernel: bool = True,
) -> jax.Array:
    """:func:`paged_copy` with each device bursting into its pool slice.

    Specs: src/pool ``P(None, None, kv, hd)``, page table + lens
    replicated.  A copy never mixes heads or head_dim lanes, so both mesh
    axes are embarrassingly parallel — no collective on any axis.
    """
    if mesh is None or mesh.size == 1:
        b, s, hkv, hd = src.shape
        return paged_copy(
            src.reshape(b, s, hkv * hd),
            pool.reshape(pool.shape[0], page_size, hkv * hd),
            page_table, lens, page_size=page_size, use_kernel=use_kernel,
        ).reshape(pool.shape)
    kv_ax, hd_ax = _kv_axes(mesh, src.shape[2], src.shape[3])
    spec = jax.sharding.PartitionSpec(None, None, kv_ax, hd_ax)
    rep = jax.sharding.PartitionSpec()

    def body(src_l, pool_l, pt, ln):
        b, s, hk, dd = src_l.shape
        out = paged_copy(
            src_l.reshape(b, s, hk * dd),
            pool_l.reshape(pool_l.shape[0], page_size, hk * dd),
            pt, ln, page_size=page_size, use_kernel=use_kernel,
        )
        return out.reshape(pool_l.shape)

    return _shard_map(body, mesh, (spec, spec, rep, rep), spec)(
        src, pool, page_table, lens
    )


def paged_copy_at_sharded(
    src: jax.Array,          # [B, S, Hkv, hd]
    pool: jax.Array,         # [P, page, Hkv, hd]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32
    lens: jax.Array,         # [B] int32
    *,
    page_size: int,
    mesh,
    use_kernel: bool = True,
) -> jax.Array:
    """:func:`paged_copy_at` over the mesh (same specs as
    :func:`paged_copy_sharded`; offsets live on the replicated scalar
    plane, so the shard bodies burst independently)."""
    if mesh is None or mesh.size == 1:
        b, s, hkv, hd = src.shape
        return paged_copy_at(
            src.reshape(b, s, hkv * hd),
            pool.reshape(pool.shape[0], page_size, hkv * hd),
            page_table, starts, lens,
            page_size=page_size, use_kernel=use_kernel,
        ).reshape(pool.shape)
    kv_ax, hd_ax = _kv_axes(mesh, src.shape[2], src.shape[3])
    spec = jax.sharding.PartitionSpec(None, None, kv_ax, hd_ax)
    rep = jax.sharding.PartitionSpec()

    def body(src_l, pool_l, pt, st, ln):
        b, s, hk, dd = src_l.shape
        out = paged_copy_at(
            src_l.reshape(b, s, hk * dd),
            pool_l.reshape(pool_l.shape[0], page_size, hk * dd),
            pt, st, ln, page_size=page_size, use_kernel=use_kernel,
        )
        return out.reshape(pool_l.shape)

    return _shard_map(body, mesh, (spec, spec, rep, rep, rep), spec)(
        src, pool, page_table, starts, lens
    )


def paged_decode_attention_sharded(
    q: jax.Array,            # [B, Hkv, G, D]
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    seq_lens: jax.Array,     # [B] int32
    *,
    page_size: int,
    mesh,
    scale: float | None = None,
    window: int | None = None,
    use_kernel: bool = True,
    kv_scale: float | None = None,
) -> jax.Array:
    """:func:`paged_decode_attention` with per-device local-slice kernels.

    'kv' shards Hkv on q AND the pools — the per-head online softmax makes
    each device's heads fully independent (no collective; outputs
    concatenate along Hkv via the out spec).  'hd' shards only the pools:
    it cuts the QK contraction, so the body all-gathers K/V to full
    head_dim and every 'hd' shard computes the identical (replicated)
    output.  q and the output stay replicated over 'hd'.
    """
    if mesh is None or mesh.size == 1:
        return paged_decode_attention(
            q, k_pool, v_pool, page_table, seq_lens, page_size=page_size,
            scale=scale, window=window, use_kernel=use_kernel,
            kv_scale=kv_scale,
        )
    kv_ax, hd_ax = _kv_axes(mesh, q.shape[1], q.shape[3])
    pool_spec = jax.sharding.PartitionSpec(None, None, kv_ax, hd_ax)
    q_spec = jax.sharding.PartitionSpec(None, kv_ax, None, None)
    rep = jax.sharding.PartitionSpec()
    gather_hd = hd_ax is not None and mesh.shape[hd_ax] > 1

    def body(q_l, kp_l, vp_l, pt, ln):
        if gather_hd:
            kp_l = jax.lax.all_gather(kp_l, hd_ax, axis=-1, tiled=True)
            vp_l = jax.lax.all_gather(vp_l, hd_ax, axis=-1, tiled=True)
        return paged_decode_attention(
            q_l, kp_l, vp_l, pt, ln, page_size=page_size,
            scale=scale, window=window, use_kernel=use_kernel,
            kv_scale=kv_scale,
        )

    return _shard_map(
        body, mesh, (q_spec, pool_spec, pool_spec, rep, rep), q_spec
    )(q, k_pool, v_pool, page_table, seq_lens)


def paged_prefill_attention_sharded(
    q: jax.Array,            # [B, S, Hkv, G, D]
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32
    *,
    page_size: int,
    mesh,
    scale: float | None = None,
    bq: int = 32,
    use_kernel: bool = True,
    kv_scale: float | None = None,
) -> jax.Array:
    """:func:`paged_prefill_attention` over the mesh (same axis roles as
    :func:`paged_decode_attention_sharded`: 'kv' head-parallel with no
    collective, 'hd' all-gathers K/V pool slices to full head_dim and
    claims the replicated output).  The page-streaming win — touching only
    reachable pages per query block — is per (batch row, KV head, query
    block), so it survives sharding untouched."""
    if mesh is None or mesh.size == 1:
        return paged_prefill_attention(
            q, k_pool, v_pool, page_table, starts, page_size=page_size,
            scale=scale, bq=bq, use_kernel=use_kernel, kv_scale=kv_scale,
        )
    kv_ax, hd_ax = _kv_axes(mesh, q.shape[2], q.shape[4])
    pool_spec = jax.sharding.PartitionSpec(None, None, kv_ax, hd_ax)
    q_spec = jax.sharding.PartitionSpec(None, None, kv_ax, None, None)
    rep = jax.sharding.PartitionSpec()
    gather_hd = hd_ax is not None and mesh.shape[hd_ax] > 1

    def body(q_l, kp_l, vp_l, pt, st):
        if gather_hd:
            kp_l = jax.lax.all_gather(kp_l, hd_ax, axis=-1, tiled=True)
            vp_l = jax.lax.all_gather(vp_l, hd_ax, axis=-1, tiled=True)
        return paged_prefill_attention(
            q_l, kp_l, vp_l, pt, st, page_size=page_size,
            scale=scale, bq=bq, use_kernel=use_kernel, kv_scale=kv_scale,
        )

    return _shard_map(
        body, mesh, (q_spec, pool_spec, pool_spec, rep, rep), q_spec
    )(q, k_pool, v_pool, page_table, starts)


def flash_attention_sharded(
    q: jax.Array,            # [B, Hq, S, D]   (Hq = Hkv * G, kv-major)
    k: jax.Array,            # [B, Hkv, S, D]
    v: jax.Array,            # [B, Hkv, S, D]
    *,
    mesh,
    causal: bool = True,
    scale: float | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """:func:`flash_attention` with heads sharded over 'kv'.

    The prefill chunk attention is not paged, but under a mesh it must
    still not trace a bare Pallas call into the GSPMD computation.  Q
    heads are kv-major (``q.reshape(b, s, Hkv, G, d)`` elsewhere), so
    sharding Hq over 'kv' keeps each device's query heads aligned with its
    KV heads — head-parallel, no collective.  D is the contraction axis
    and stays unsharded; every 'hd' shard computes the identical output
    (claimed replicated)."""
    if mesh is None or mesh.size == 1:
        return flash_attention(
            q, k, v, causal=causal, scale=scale, use_kernel=use_kernel
        )
    kv_ax, _ = _kv_axes(mesh, k.shape[1], q.shape[3])
    spec = jax.sharding.PartitionSpec(None, kv_ax, None, None)

    def body(q_l, k_l, v_l):
        return flash_attention(
            q_l, k_l, v_l, causal=causal, scale=scale, use_kernel=use_kernel
        )

    return _shard_map(body, mesh, (spec, spec, spec), spec)(q, k, v)


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("bt", "use_kernel", "matmul_chunks")
)
def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    initial_state: jax.Array | None = None,
    *,
    bt: int = 128,
    use_kernel: bool = True,
    matmul_chunks: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bh, t, n = r.shape
    if matmul_chunks and use_kernel and t % 32 == 0:
        # chunk-parallel Pallas kernel: the [C,C,N] intra-chunk tensor and
        # the state never leave VMEM (kernels/wkv6_chunked.py)
        from repro.kernels.wkv6_chunked import wkv6_chunked as _wkv6_ck
        return _wkv6_ck(r, k, v, w, u, initial_state, chunk=32)
    if not use_kernel:
        if matmul_chunks:
            # flash-linear-attention formulation: MXU matmuls, state
            # traffic / chunk (EXPERIMENTS.md §Perf cell C)
            return ref.wkv6_chunked_matmul_ref(
                r, k, v, w, u, initial_state, chunk=min(bt, 32)
            )
        return ref.wkv6_chunked_ref(r, k, v, w, u, initial_state, chunk=bt)
    bt_ = min(bt, t)
    tp = round_up(t, bt_)
    if tp != t:
        # pad with identity steps: w=1 (no decay), k=0 (no update), r=0
        pad = ((0, 0), (0, tp - t), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)
    o, s_fin = _wkv6_kernel(r, k, v, w, u, initial_state, bt=bt_)
    return o[:, :t], s_fin
