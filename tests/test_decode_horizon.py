"""Fused multi-step decode horizon acceptance tests.

The tentpole contract of the horizon (engine docstring, "Fused multi-step
decode"): the Scheduler computes a safe horizon K, pre-faults every page K
chained decode steps will touch in ONE batched allocation, and the
Executor runs those K steps in a single dispatch with on-device sampling
and per-lane retire masking.  Three things must hold:

  1. IDENTITY — greedy outputs are token-for-token identical to the frozen
     seed engine for forced horizons K in {1, 2, 4, 8} AND for auto-horizon
     runs that mix preemption, forked admission and restore mid-stream
     (the horizon must collapse to 1 under pressure and re-open afterwards
     without drift).  Temperature sampling is identical too: the fused
     path threads the PRNG key with exactly one split per inner step, the
     same stream the host path consumes.
  2. AMORTIZATION — ``host_syncs`` (forced device->host transfers) per
     decoded token drops strictly below 1.0, and dispatches drop below
     token-steps (``decode_horizon > decode_dispatches`` proves fused
     dispatches actually ran).
  3. PROPERTY — identity holds across page_size x max_new draws
     (``tests/_prop_fallback.py`` shim when hypothesis is absent).
"""

import copy

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, ReferenceEngine, ServeConfig, ServeRequest
from repro.serve.api import to_internal

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    return cfg, model, model.init(KEY)


def workload(cfg, lens_new_fork, seed=29, prefix_len=0):
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(0, cfg.vocab_size, size=prefix_len)
              .astype(np.int32) if prefix_len else None)
    reqs = [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size, size=int(l))
                     .astype(np.int32),
                     max_new_tokens=m, share_prefix=f)
        for i, (l, m, f) in enumerate(lens_new_fork)
    ]
    return prefix, reqs


def run_engine(eng_cls, model, params, serve_cfg, reqs, prefix=None):
    eng = eng_cls(model, params, serve_cfg)
    if prefix is not None:
        eng.preload_prefix(prefix)
    for r in reqs:
        r = copy.deepcopy(r)
        # the frozen seed engine predates the typed surface: lower explicitly
        eng.submit(to_internal(r) if eng_cls is ReferenceEngine else r)
    done = eng.run()
    return eng, done


def outputs(done):
    return {i: [int(x) for x in done[i].output] for i in done}


class TestForcedHorizonIdentity:
    @pytest.mark.parametrize("k", [1, 2, 4, 8])
    def test_token_identical_to_seed(self, model_and_params, k):
        """Roomy pool, batch admitted in one step (queue drains instantly)
        so the horizon engages immediately — every forced cap must
        reproduce the seed engine exactly."""
        cfg, model, params = model_and_params
        _, reqs = workload(cfg, [(5, 12, False), (9, 12, False),
                                 (7, 12, False)], seed=7)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3,
                                max_horizon=k)
        new_eng, done_n = run_engine(Engine, model, params, serve_cfg, reqs)
        ref_eng, done_r = run_engine(
            ReferenceEngine, model, params, serve_cfg, reqs)
        assert outputs(done_n) == outputs(done_r)
        c = new_eng.counters
        assert c.get("decode_horizon") == c.get("decode_tokens") // 3
        if k > 1:
            # fused dispatches actually ran: fewer dispatches than
            # token-steps, and no single dispatch exceeded the cap
            assert c.get("decode_dispatches") < c.get("decode_horizon")
            assert c.get("decode_horizon") <= k * c.get("decode_dispatches")
        else:
            assert c.get("decode_dispatches") == c.get("decode_horizon")
        new_eng.vmem.check_invariants()


class TestAutoHorizonIdentity:
    def test_mixed_preempt_fork_restore_collapses_and_reopens(
            self, model_and_params):
        """Tight pool + shared prefix: forked admissions, preemptions and
        restores all fire mid-stream, including at least one POOL-pressure
        horizon collapse (not just event collapses) — and the horizon
        re-opens afterwards (decode_horizon > decode_dispatches) with
        outputs still token-identical to the seed."""
        cfg, model, params = model_and_params
        prefix, reqs = workload(
            cfg,
            [(5, 16, True), (9, 16, False), (7, 16, True),
             (11, 16, False), (6, 16, True)],
            seed=29, prefix_len=10,
        )
        serve_cfg = ServeConfig(page_size=4, num_pages=15,
                                max_pages_per_seq=16, max_batch=3)
        new_eng, done_n = run_engine(Engine, model, params, serve_cfg, reqs,
                                     prefix=prefix)
        ref_eng, done_r = run_engine(ReferenceEngine, model, params,
                                     serve_cfg, reqs, prefix=prefix)
        c = new_eng.counters
        # the workload must actually exercise every horizon hazard
        assert c.get("preemptions") > 0
        assert c.get("restores") > 0
        assert c.get("forked_admissions") > 0
        assert c.get("horizon_collapses") > 0          # pool pressure hit
        assert c.get("decode_horizon") > c.get("decode_dispatches")  # reopened
        # Shared-page restore re-shares still-resident pinned-prefix
        # frames for spilled fork victims, so restores demand fewer free
        # frames than the seed engine's full re-allocation — fewer
        # preemption cascades, never more, with everything else (page
        # faults, completions, every token) unchanged.
        for name in ("page_faults", "completed"):
            assert c.get(name) == ref_eng.counters.get(name), name
        for name in ("preemptions", "restores"):
            assert 0 < c.get(name) <= ref_eng.counters.get(name), name
        assert c.get("shared_restores") > 0
        assert outputs(done_n) == outputs(done_r)
        new_eng.vmem.check_invariants()

    def test_scheduler_clock_stays_in_token_steps(self, model_and_params):
        """A fused run and a K=1 run of the same workload must read the
        same scheduler time: step_i, ticks and tick cycle accounting are
        per TOKEN-step, not per dispatch."""
        cfg, model, params = model_and_params
        _, reqs = workload(cfg, [(5, 10, False), (8, 10, False)], seed=11)
        clocks = {}
        for mh in (1, 8):
            serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                    max_pages_per_seq=16, max_batch=2,
                                    max_horizon=mh, tick_every_steps=2)
            eng, _ = run_engine(Engine, model, params, serve_cfg, reqs)
            clocks[mh] = (eng.scheduler.step_i,
                          eng.counters.get("ticks"),
                          eng.counters.get("modeled_tick_cycles"))
        assert clocks[1] == clocks[8]


class TestOnDeviceSampling:
    def test_temperature_stream_identical_to_stepwise(self, model_and_params):
        """The fused path splits the PRNG key once per inner step — the
        exact stream the host sampling path consumes — so stochastic
        outputs match a K=1 run bit-for-bit."""
        cfg, model, params = model_and_params
        _, reqs = workload(cfg, [(5, 12, False), (9, 12, False),
                                 (7, 12, False)], seed=7)
        outs = {}
        for mh in (1, 8):
            serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                    max_pages_per_seq=32, max_batch=3,
                                    max_horizon=mh, greedy=False,
                                    temperature=0.8, seed=3)
            eng, done = run_engine(Engine, model, params, serve_cfg, reqs)
            outs[mh] = outputs(done)
        assert outs[1] == outs[8]


class TestAmortization:
    def test_host_syncs_per_token_below_one(self, model_and_params):
        """The acceptance gate's counter contract: at auto-horizon the
        scalar plane intervenes less than once per decoded token, and
        strictly less often than the forced-K=1 engine."""
        cfg, model, params = model_and_params
        _, reqs = workload(cfg, [(5, 12, False), (9, 12, False),
                                 (7, 12, False)], seed=7)
        syncs = {}
        for mh in (1, 8):
            serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                    max_pages_per_seq=32, max_batch=3,
                                    max_horizon=mh)
            eng, done = run_engine(Engine, model, params, serve_cfg, reqs)
            c = eng.counters
            assert c.get("decode_tokens") == 3 * 11
            syncs[mh] = c.get("host_syncs")
            assert c.ratio("host_syncs", "decode_tokens") < 1.0
        assert syncs[8] < syncs[1]

    def test_ptab_sync_once_per_horizon(self, model_and_params):
        """Horizon growth batches all page faults before the dispatch, so
        page-table delta syncs scale with dispatches, not token-steps."""
        cfg, model, params = model_and_params
        _, reqs = workload(cfg, [(5, 12, False), (9, 12, False),
                                 (7, 12, False)], seed=7)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3)
        eng, _ = run_engine(Engine, model, params, serve_cfg, reqs)
        c = eng.counters
        # one sync opportunity per dispatch + one per prefill batch
        assert c.get("ptab_syncs") <= c.get("decode_dispatches") + 1
        assert c.get("decode_dispatches") < c.get("decode_tokens") // 3


@settings(max_examples=5, deadline=None)
@given(page_size=st.sampled_from([2, 4, 8]),
       max_new=st.integers(min_value=1, max_value=10))
def test_horizon_identity_property(model_and_params, page_size, max_new):
    """Property: fused auto-horizon == forced K=1, across page geometry and
    request lifetime (covers the retire-mid-horizon edge at max_new == 1,
    where a satisfied lane still decodes exactly once — seed semantics)."""
    cfg, model, params = model_and_params
    _, reqs = workload(cfg, [(5, max_new, False), (7, max_new, False)],
                       seed=1000 + 31 * page_size + max_new)
    outs = {}
    for mh in (1, 8):
        serve_cfg = ServeConfig(page_size=page_size, num_pages=64,
                                max_pages_per_seq=32, max_batch=2,
                                max_horizon=mh)
        eng, done = run_engine(Engine, model, params, serve_cfg, reqs)
        outs[mh] = outputs(done)
        eng.vmem.check_invariants()
    assert outs[1] == outs[8]
