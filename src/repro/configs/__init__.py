"""Assigned-architecture registry: ``get_config(arch_id)`` / ``--arch``."""

from repro.configs import (
    deepseek_67b,
    granite_8b,
    granite_moe_1b,
    llama4_maverick,
    musicgen_large,
    qwen2_72b,
    qwen2_7b,
    qwen2_vl_7b,
    recurrentgemma_9b,
    rwkv6_7b,
)
from repro.models.config import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "qwen2-72b": qwen2_72b,
    "qwen2-7b": qwen2_7b,
    "granite-8b": granite_8b,
    "deepseek-67b": deepseek_67b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "recurrentgemma-9b": recurrentgemma_9b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "rwkv6-7b": rwkv6_7b,
    "musicgen-large": musicgen_large,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]
