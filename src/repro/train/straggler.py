"""Straggler detection for the fleet-level heartbeat.

On 1000+ hosts, a single slow worker gates every synchronous step.  The
trainer emits (step, seconds) heartbeats; this monitor keeps a robust EWMA
of step time and flags outliers.  On a real cluster the launcher wires
``on_straggler`` to its remediation path (drain + reschedule the worker,
or shrink the mesh via the elastic checkpoint-reshard path); here it feeds
the perf counters and the tests assert the detection semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    seconds: float
    ewma: float
    ratio: float


class StragglerMonitor:
    """EWMA-based step-time outlier detector (the heartbeat consumer)."""

    def __init__(
        self,
        threshold: float = 3.0,
        alpha: float = 0.1,
        warmup_steps: int = 5,
        on_straggler: Callable[[StragglerEvent], None] | None = None,
    ):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup_steps = warmup_steps
        self.on_straggler = on_straggler
        self.ewma: float | None = None
        self.events: list[StragglerEvent] = []
        self._n = 0

    def heartbeat(self, step: int, seconds: float) -> bool:
        """Feed one (step, seconds); returns True if flagged as straggler.

        The EWMA only absorbs non-flagged steps, so a persistent slowdown
        keeps firing instead of being normalized away.
        """
        self._n += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_straggler = (
            self._n > self.warmup_steps
            and seconds > self.threshold * self.ewma
        )
        if is_straggler:
            ev = StragglerEvent(
                step=step, seconds=seconds, ewma=self.ewma,
                ratio=seconds / self.ewma,
            )
            self.events.append(ev)
            if self.on_straggler:
                self.on_straggler(ev)
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return is_straggler
