"""Fault-tolerant training: step factory + auto-resuming loop.

Large-fleet contract (DESIGN.md §3):
  * step function is a pure jitted (params, opt_state, batch) -> (params,
    opt_state, metrics) with optional microbatch gradient accumulation
    (lax.scan over the micro axis — activation memory is bounded by one
    microbatch);
  * the loop auto-resumes from the newest atomic checkpoint, saves async
    every N steps, takes an emergency checkpoint on SIGTERM/KeyboardInterrupt
    (preemption), and re-raises unknown faults after checkpointing — a
    restarted job continues bit-identically (the data stream is keyed by
    step);
  * heartbeat hook: called every step with (step, seconds); cluster-level
    straggler mitigation watches these (the launcher wires it to its own
    monitoring; here it feeds the perf counters).
"""

from __future__ import annotations

import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.core import PerfCounters
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(
    model: Any,
    opt_cfg: AdamWConfig,
    *,
    accum_steps: int = 1,
    donate: bool = True,
    grad_shardings: Any | None = None,
) -> Callable:
    """Build the jitted train step.  With ``accum_steps > 1`` the batch's
    leading dim is split into microbatches and gradients are averaged in f32
    before one optimizer update.

    ``grad_shardings``: pytree of NamedShardings (the param shardings).
    Constraining the gradients to the parameter layout turns GSPMD's
    full-tensor gradient all-reduces into reduce-scatters — each device
    only ever owns the shard it will apply (§Perf cell B iteration 2)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            jax.lax.with_sharding_constraint, grads, grad_shardings
        )

    def single(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        grads = constrain(grads)
        params, opt_state, opt_m = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        return params, opt_state, {**metrics, **opt_m, "loss": loss}

    def accumulated(params, opt_state, batch):
        def micro(batch_i):
            b = jax.tree.map(
                lambda x: x.reshape(
                    (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]
                )[batch_i] if hasattr(x, "shape") and x.ndim >= 1 else x,
                batch,
            )
            return b

        def scan_body(carry, i):
            g_acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, micro(i))
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                g_acc, grads,
            )
            return (g_acc, loss_acc + loss / accum_steps), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss), _ = jax.lax.scan(
            scan_body, (g0, jnp.float32(0.0)), jnp.arange(accum_steps)
        )
        grads = constrain(grads)
        params, opt_state, opt_m = adamw_update(
            grads, opt_state, params, opt_cfg
        )
        return params, opt_state, {**opt_m, "loss": loss}

    fn = single if accum_steps == 1 else accumulated
    donate_argnums = (0, 1) if donate else ()
    return jax.jit(fn, donate_argnums=donate_argnums)


class Trainer:
    """Auto-resuming training loop with preemption-safe checkpointing."""

    def __init__(
        self,
        model: Any,
        opt_cfg: AdamWConfig,
        *,
        ckpt_dir: str,
        ckpt_every: int = 50,
        keep: int = 3,
        accum_steps: int = 1,
        heartbeat: Callable[[int, float], None] | None = None,
    ):
        self.model = model
        self.opt_cfg = opt_cfg
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.accum_steps = accum_steps
        self.heartbeat = heartbeat
        self.counters = PerfCounters()
        self.checkpointer = ckpt.AsyncCheckpointer(ckpt_dir, keep=keep)
        self.step_fn = make_train_step(model, opt_cfg, accum_steps=accum_steps)
        self._preempted = False

    # ------------------------------------------------------------------

    def init_or_restore(self, key) -> tuple[Any, Any, int]:
        """Fresh init, or resume from the newest checkpoint."""
        params = self.model.init(key)
        opt_state = adamw_init(params, self.opt_cfg.moment_dtype)
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        restored = ckpt.restore(self.ckpt_dir, latest, tree)
        self.counters.snapshot("resumed", latest)
        return restored["params"], restored["opt"], latest

    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    # ------------------------------------------------------------------

    def run(
        self,
        params: Any,
        opt_state: Any,
        batches: Callable[[int], dict[str, Any]],
        start_step: int,
        num_steps: int,
        log_every: int = 10,
    ) -> tuple[Any, Any, list[dict[str, float]]]:
        self._install_preemption_handler()
        history: list[dict[str, float]] = []
        step = start_step
        try:
            for step in range(start_step, num_steps):
                t0 = time.perf_counter()
                batch = batches(step)
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch
                )
                if step % log_every == 0 or step == num_steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    history.append(m)
                dt = time.perf_counter() - t0
                self.counters.inc("steps")
                if self.heartbeat:
                    self.heartbeat(step, dt)
                if (step + 1) % self.ckpt_every == 0:
                    self.checkpointer.save_async(
                        step + 1, {"params": params, "opt": opt_state}
                    )
                if self._preempted:
                    raise KeyboardInterrupt("preemption signal")
        except (KeyboardInterrupt, SystemExit):
            # emergency checkpoint, then surface the preemption
            self.checkpointer.wait()
            ckpt.save(self.ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state})
            self.counters.snapshot("preempt_checkpoint", step + 1)
            raise
        self.checkpointer.wait()
        ckpt.save(self.ckpt_dir, num_steps, {"params": params, "opt": opt_state})
        return params, opt_state, history
