"""Device-resident serving executor — the Ara2 data plane of the split.

Everything that touches a device array lives here: the paged KV pools, a
*persistent device page table* (the satp analogue, updated incrementally
from ``VirtualMemory.drain_dirty_rows()`` deltas — never re-uploaded
wholesale), and jitted prefill / continuation-prefill / decode steps whose
KV pools are donated so XLA updates them in place.

Contrast with the seed engine's hot path, which re-uploaded the full page
table every decode step and stacked+reshaped both full KV pools on every
spill/restore.  Here:

  * page-table updates are delta-only (``ptab_rows_uploaded`` counter);
  * spill/restore move only the victim sequence's pages
    (``ContextSwitcher.spill_kv``/``restore_kv`` — page-granular, the
    paper's §3.1 context-switch cost in actually-moved bytes);
  * inactive decode lanes are masked *inside* the jitted step from a [B]
    bool mask, not by rewriting table rows on the host;
  * decode runs in fused K-step horizons (``decode_multi``): one dispatch
    chains K ``decode_step``s with on-device sampling (greedy argmax or
    temperature/categorical with a threaded PRNG key) and per-lane retire
    masking, so the host round-trip — and the page-table delta sync — is
    paid once per horizon, not once per token (``host_syncs`` /
    ``decode_horizon`` counters);
  * with a ('kv', 'hd') serve mesh the whole device state SHARDS: KV
    pools partition jointly over KV heads and head_dim
    (``launch.specs.executor_state_shardings``), the page table and every
    scalar-plane operand replicate, and all jitted dispatches carry
    explicit ``in_shardings``/``out_shardings`` with donated pools so the
    fused decode horizon runs sharded for free — the Ara2 analogue of
    scaling lanes/cores under one shared, coherent translation structure;
  * the Pallas kernels stay LIVE on that mesh: a kernel-built model is
    rebound to a mesh twin (``_mesh_kernel_model``) whose serve paths
    shard_map every paged-attention/paged-copy call onto per-device pool
    slices — KV-head shards attend independently (per-head online
    softmax: no collective), head_dim shards all-gather K/V inside the
    shard body, the replicated page table translates without
    communication (specs per operand: ``kernels/ops.py``).  The jnp twin
    survives only as the explicit ``ServeConfig.use_ref_path`` escape
    hatch; every compute step is tallied as ``kernel_dispatches`` vs
    ``ref_path_dispatches`` so any fallback is loud.

The executor implements the scheduler's :class:`~repro.serve.scheduler.
DataPlane` protocol — both the movement surface (spill/restore/discard/
fork) and the compute surface (prefill/decode/decode_multi) that
``Scheduler.step_plane`` drives — and makes no policy decisions.  One
executor is one replica's data plane: the multi-replica router
(:mod:`repro.serve.router`) runs N of these behind one admission
front-end, each with its own KV pools, page table and page pool (no
cross-replica device state).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ContextSwitcher,
    CostModel,
    INVALID_PAGE,
    PerfCounters,
    VirtualMemory,
)
from repro.models.transformer import PagedKVState, TransformerLM
from repro.serve.scheduler import DecodePlan, Request, ServeConfig


# ---------------------------------------------------------------------------
# device-step bodies
#
# Plain functions, jitted twice below: once at module level for the
# single-device executor (shared cache per model, exactly the pre-mesh
# behavior) and once per (model, mesh) for the sharded executor, with
# explicit ``in_shardings``/``out_shardings`` so the KV pools stay laid
# out over the ('kv', 'hd') serve mesh across donated in-place updates.
# ---------------------------------------------------------------------------


def _ptab_delta_impl(ptab: jax.Array, rows: jax.Array,
                     vals: jax.Array) -> jax.Array:
    """Scatter dirty rows into the persistent device page table."""
    return ptab.at[rows].set(vals)


def _prefill_impl(model: TransformerLM, params: Any, tokens: jax.Array,
                  lens: jax.Array, k_pools: jax.Array, v_pools: jax.Array,
                  pt_rows: jax.Array):
    state = PagedKVState(k_pools, v_pools, pt_rows,
                         jnp.zeros_like(lens))
    logits, ns = model.prefill(params, tokens, lens, state)
    return logits, ns.k_pools, ns.v_pools


def _continue_impl(model: TransformerLM, params: Any, tokens: jax.Array,
                   starts: jax.Array, lens: jax.Array, k_pools: jax.Array,
                   v_pools: jax.Array, pt_rows: jax.Array):
    state = PagedKVState(k_pools, v_pools, pt_rows,
                         jnp.zeros_like(starts))
    logits, ns = model.prefill_continue(params, tokens, starts, lens, state)
    return logits, ns.k_pools, ns.v_pools


def _decode_impl(model: TransformerLM, params: Any, tokens: jax.Array,
                 k_pools: jax.Array, v_pools: jax.Array, ptab: jax.Array,
                 pre_lens: jax.Array, active: jax.Array):
    # mask page-table rows of slots that are NOT decoding this step:
    # mapped-but-idle sequences (e.g. the resident shared prefix) must not
    # receive the inactive-lane scratch writes — with a valid row the guard
    # would route them into a LIVE frame instead of the reserved scratch
    # row.  The mask is applied on device from a [B] bool vector; the table
    # itself is never rewritten.
    masked = jnp.where(active[:, None], ptab, INVALID_PAGE)
    state = PagedKVState(k_pools, v_pools, masked, pre_lens)
    logits, ns = model.decode_step(params, tokens, state)
    return logits, ns.k_pools, ns.v_pools


def _decode_multi_impl(model: TransformerLM, params: Any, tokens: jax.Array,
                       k_pools: jax.Array, v_pools: jax.Array,
                       ptab: jax.Array, pre_lens: jax.Array,
                       steps_left: jax.Array, rng: jax.Array,
                       temperature: jax.Array, horizon: int, greedy: bool):
    """Fused K-step decode horizon with ON-DEVICE sampling.

    One dispatch runs ``horizon`` chained ``model.decode_step`` calls
    (``lax.scan`` inside :meth:`TransformerLM.decode_multi_step`), sampling
    each next token on device and feeding it straight back — the host
    round-trip per token (sample transfer, replan, token re-upload)
    becomes one round-trip per horizon.  Per-lane retirement is masked on
    device from ``steps_left``; the page table is read-only (masking
    happens per inner step, the table itself is never rewritten).
    """
    state = PagedKVState(k_pools, v_pools, ptab, pre_lens)
    block, ns, rng = model.decode_multi_step(
        params, tokens, state, steps_left, rng, temperature,
        horizon=horizon, greedy=greedy,
    )
    return block, ns.k_pools, ns.v_pools, rng


def _copy_pages_impl(k_pools: jax.Array, v_pools: jax.Array, srcs: jax.Array,
                     dsts: jax.Array):
    """COW tail-page copies: all forked frames in each pool, one dispatch."""
    return (k_pools.at[:, dsts].set(k_pools[:, srcs]),
            v_pools.at[:, dsts].set(v_pools[:, srcs]))


# single-device jit cache (module-level so it is shared per model)
_apply_ptab_delta = jax.jit(_ptab_delta_impl, donate_argnums=(0,))
_prefill_step = jax.jit(_prefill_impl, static_argnums=(0,),
                        donate_argnums=(4, 5))
_continue_step = jax.jit(_continue_impl, static_argnums=(0,),
                         donate_argnums=(5, 6))
_decode_step = jax.jit(_decode_impl, static_argnums=(0,),
                       donate_argnums=(3, 4))
_decode_multi_step = jax.jit(_decode_multi_impl, static_argnums=(0, 10, 11),
                             donate_argnums=(3, 4))
_copy_pages = jax.jit(_copy_pages_impl, donate_argnums=(0, 1))


def select_bucket(n: int, buckets: tuple[int, ...] | None) -> int | None:
    """Smallest AOT bucket that can hold an ``n``-token (burst-aligned)
    prompt batch, or ``None`` on a miss — the caller then falls back to
    the shape-keyed jit path.  ``buckets`` is the sorted tuple
    ``ServeConfig.aot_buckets`` normalized to."""
    if not buckets:
        return None
    for b in buckets:
        if b >= n:
            return b
    return None


#: AOT-compiled prefill/continuation executables, keyed by
#: (step-model twin, mesh, kind, bucket, batch/pool geometry).  Module
#: level — mirroring the lru_cached model twins above — so every engine
#: over the same (model twin, mesh, geometry) binds the SAME compiled
#: executable instead of re-lowering at each build.
_AOT_CACHE: dict[tuple, Any] = {}


@functools.lru_cache(maxsize=None)
def _ref_path_model(model: TransformerLM) -> TransformerLM:
    """Explicit jnp escape hatch (``ServeConfig.use_ref_path``).

    A shallow copy with ``use_kernels=False`` — the jnp reference paths,
    which GSPMD partitions freely.  This used to be the *implicit* dispatch
    for every kernel model under a >1-device mesh; the shard_map wrappers
    in ``kernels.ops`` made that fallback unnecessary, so the twin remains
    only behind the explicit config flag (``--no-kernels`` in
    ``launch.serve``), and every compute step through it is counted as
    ``ref_path_dispatches``.  Cached per model so every engine over the
    same model shares the twin's jit traces.
    """
    import copy
    twin = copy.copy(model)
    twin.use_kernels = False
    return twin


@functools.lru_cache(maxsize=None)
def _kv_dtype_model(model: TransformerLM, kv_dtype: str) -> TransformerLM:
    """KV-storage twin (``ServeConfig.kv_dtype``).

    A shallow copy with ``kv_dtype`` rebound: ``init_kv_state`` then
    allocates quantized pools and every serve path quantizes its writes /
    passes ``kv_scale`` into the paged-attention kernels (which dequantize
    in VMEM — int8 no longer routes to the ref path).  Composes with the
    other twins: the ref-path and mesh rebinds below copy whatever model
    they are handed, so the storage dtype survives them.  Cached per
    (model, dtype) so engines over the same pair share jit traces.
    """
    import copy
    twin = copy.copy(model)
    twin.kv_dtype = kv_dtype
    return twin


@functools.lru_cache(maxsize=None)
def _mesh_kernel_model(model: TransformerLM, mesh) -> TransformerLM:
    """Mesh-bound kernel twin: the Pallas paths stay LIVE under sharding.

    A shallow copy with ``kernel_mesh=mesh``: the model's serve paths then
    dispatch paged attention / paged copies through the shard_map wrappers
    in ``kernels.ops``, where each device runs the unmodified kernel on
    its local KV-pool slice (KV-head sharding is collective-free; head_dim
    sharding all-gathers K/V inside the shard body — see the ops module
    docstring for the per-operand specs).  Cached per (model, mesh) so
    engines over the same pair share jit traces, mirroring
    ``_sharded_steps``.
    """
    import copy
    twin = copy.copy(model)
    twin.kernel_mesh = mesh
    return twin


@functools.lru_cache(maxsize=None)
def _executor_shardings(mesh, num_kv_heads: int, head_dim: int):
    """(pool, replicated) NamedShardings for an executor on ``mesh``.

    Imported lazily: ``launch.specs`` pulls the full dry-run surface
    (configs, optimizer, train step), which plain single-device serving
    never needs.
    """
    from repro.launch.specs import executor_state_shardings
    sh = executor_state_shardings(mesh, num_kv_heads, head_dim)
    return sh["pool"], sh["replicated"]


@functools.lru_cache(maxsize=None)
def _sharded_steps(model: TransformerLM, mesh):
    """Per-(model, mesh) jitted steps with explicit sharding contracts.

    The model is bound via ``partial`` (it is a static self argument) so
    ``in_shardings`` maps 1:1 onto the dynamic args.  Pools shard over the
    ('kv', 'hd') mesh axes and are donated — XLA updates them in place,
    shard-local; everything the scalar/OS plane produces or consumes
    (page-table rows, tokens, positions, logits, the sampled block) is
    replicated, the satp analogue every shard reads coherently.
    """
    pool, rep = _executor_shardings(
        mesh, model.cfg.num_kv_heads, model.cfg.head_dim
    )
    return {
        "ptab": jax.jit(_ptab_delta_impl, in_shardings=(rep, rep, rep),
                        out_shardings=rep, donate_argnums=(0,)),
        "prefill": jax.jit(
            functools.partial(_prefill_impl, model),
            in_shardings=(rep, rep, rep, pool, pool, rep),
            out_shardings=(rep, pool, pool), donate_argnums=(3, 4),
        ),
        "continue": jax.jit(
            functools.partial(_continue_impl, model),
            in_shardings=(rep, rep, rep, rep, pool, pool, rep),
            out_shardings=(rep, pool, pool), donate_argnums=(4, 5),
        ),
        "decode": jax.jit(
            functools.partial(_decode_impl, model),
            in_shardings=(rep, rep, pool, pool, rep, rep, rep),
            out_shardings=(rep, pool, pool), donate_argnums=(2, 3),
        ),
        "copy_pages": jax.jit(
            _copy_pages_impl, in_shardings=(pool, pool, rep, rep),
            out_shardings=(pool, pool), donate_argnums=(0, 1),
        ),
    }


@functools.lru_cache(maxsize=None)
def _sharded_decode_multi(model: TransformerLM, mesh, horizon: int,
                          greedy: bool):
    """Sharded fused-horizon dispatch; cached per (model, mesh, K, greedy)
    — the horizon ladder is O(log max_horizon) powers of two, so this
    cache stays as small as the single-device one."""
    pool, rep = _executor_shardings(
        mesh, model.cfg.num_kv_heads, model.cfg.head_dim
    )
    return jax.jit(
        functools.partial(_decode_multi_impl, model, horizon=horizon,
                          greedy=greedy),
        in_shardings=(rep, rep, pool, pool, rep, rep, rep, rep, rep),
        out_shardings=(rep, pool, pool, rep), donate_argnums=(2, 3),
    )


class Executor:
    """Owns KV pools + the device page table; executes scheduler plans.

    With ``mesh`` (a ('kv', 'hd') serve mesh, see
    :func:`repro.launch.mesh.make_host_serve_mesh`) the KV pools shard
    jointly over KV heads and head_dim while the page table and every
    scalar-plane operand replicate — the Scheduler needs no changes, which
    is the point of the split.  All dispatches carry explicit
    ``in_shardings``/``out_shardings`` with donated pools, so spill /
    restore / COW-fork / ptab-delta updates preserve the layout;
    :meth:`check_sharding_invariants` asserts that after every mutation.
    """

    def __init__(self, model: TransformerLM, params: Any, cfg: ServeConfig,
                 vmem: VirtualMemory, cost: CostModel | None = None,
                 counters: PerfCounters | None = None, mesh=None):
        kv_dtype = getattr(cfg, "kv_dtype", "native")
        if kv_dtype != "native" and getattr(
                model, "kv_dtype", "native") != kv_dtype:
            # quantized-pool twin FIRST: pool allocation below and every
            # later twin (ref path, mesh) derive from it, so the storage
            # dtype is a single config knob
            model = _kv_dtype_model(model, kv_dtype)
        self.model = model
        self.params = params
        self.cfg = cfg
        self.vmem = vmem
        self.counters = counters or PerfCounters()
        self.switcher = ContextSwitcher(vmem, cost, page_axis=1)
        # the device pool has num_pages frames; the allocator saw one less
        # (last frame = scratch for masked lanes)
        self.kv = model.init_kv_state(
            cfg.max_batch, cfg.num_pages, cfg.page_size, cfg.max_pages_per_seq
        )
        #: persistent satp: updated by delta scatter, read by every step
        self._ptab = jnp.full(
            (cfg.max_batch, cfg.max_pages_per_seq), INVALID_PAGE, jnp.int32
        )
        self._rng = jax.random.PRNGKey(cfg.seed)
        self.mesh = mesh
        self._pool_sh = self._rep_sh = None
        self._step_model = model
        #: True iff compute steps dispatch through a use_kernels=False
        #: twin of a kernel-built model (the explicit escape hatch) —
        #: counted per dispatch as ``ref_path_dispatches``
        self._ref_path = False
        if getattr(cfg, "use_ref_path", False) and getattr(
                model, "use_kernels", False):
            self._step_model = _ref_path_model(model)
            self._ref_path = True
        if mesh is not None:
            if mesh.size > 1 and getattr(
                    self._step_model, "use_kernels", False):
                # kernels stay LIVE under the mesh: the twin binds the
                # mesh so the serve paths shard_map every Pallas call
                # onto per-device pool slices (kernels/ops.py)
                self._step_model = _mesh_kernel_model(self._step_model,
                                                      mesh)
            self._pool_sh, self._rep_sh = _executor_shardings(
                mesh, model.cfg.num_kv_heads, model.cfg.head_dim
            )
            self._steps = _sharded_steps(self._step_model, mesh)
            # commit the persistent state to its declared layout; params
            # replicate (TP of the weights is the dry-run serving view's
            # job — the executor's contract is the KV/page-table state)
            self.params = jax.device_put(params, self._rep_sh)
            self.kv = self.kv._replace(
                k_pools=jax.device_put(self.kv.k_pools, self._pool_sh),
                v_pools=jax.device_put(self.kv.v_pools, self._pool_sh),
            )
            self._ptab = jax.device_put(self._ptab, self._rep_sh)
        else:
            # same call surface as the sharded table so every dispatch
            # site below is placement-oblivious
            self._steps = {
                "ptab": _apply_ptab_delta,
                "prefill": functools.partial(_prefill_step,
                                             self._step_model),
                "continue": functools.partial(_continue_step,
                                              self._step_model),
                "decode": functools.partial(_decode_step, self._step_model),
                "copy_pages": _copy_pages,
            }
        #: AOT-bucketed prefill/continuation executables for THIS engine,
        #: (kind, bucket) -> compiled; populated at build so no request
        #: ever pays a first-hit jit stall (``ServeConfig.aot_buckets``)
        self._aot: dict[tuple[str, int], Any] = {}
        if getattr(cfg, "aot_buckets", None):
            self._compile_aot()

    # ------------------------------------------------------------------
    # AOT-bucketed prefill (ServeConfig.aot_buckets)
    # ------------------------------------------------------------------

    def _aot_key(self, kind: str, bucket: int) -> tuple:
        """Module-cache key: everything the compiled executable's shapes,
        dtypes and shardings derive from.  The kv-dtype / ref-path / mesh
        twins are all folded into ``self._step_model`` + ``self.mesh``, so
        distinct twins get distinct executables and identical twins share."""
        return (self._step_model, self.mesh, kind, bucket,
                self.cfg.max_batch, self.cfg.num_pages,
                self.cfg.page_size, self.cfg.max_pages_per_seq)

    def _aot_operands(self, kind: str, bucket: int) -> tuple:
        """``ShapeDtypeStruct`` operands of one bucketed dispatch: full
        ``max_batch`` rows, ``bucket``-length prompts, the executor's live
        pool/page-table geometry (quantized pools keep their narrow dtype
        because the SDS is read off the allocated pools)."""
        sds = jax.ShapeDtypeStruct
        b = self.cfg.max_batch
        p_sds = jax.tree_util.tree_map(
            lambda a: sds(jnp.shape(a), a.dtype), self.params
        )
        tok = sds((b, bucket), jnp.int32)
        lens = sds((b,), jnp.int32)
        k = sds(self.kv.k_pools.shape, self.kv.k_pools.dtype)
        v = sds(self.kv.v_pools.shape, self.kv.v_pools.dtype)
        pt = sds((b, self.cfg.max_pages_per_seq), jnp.int32)
        if kind == "continue":
            starts = sds((b,), jnp.int32)
            return (p_sds, tok, starts, lens, k, v, pt)
        return (p_sds, tok, lens, k, v, pt)

    def _compile_aot(self) -> None:
        """Pre-lower and ``aot_compile`` every (kind, bucket) executable
        at engine build.  Single-device lowering goes through the module
        jits (the model is a static argument, baked in at lower time);
        mesh lowering goes through the per-(model, mesh) sharded steps so
        the executables carry the declared in/out shardings."""
        for kind in ("prefill", "continue"):
            for bucket in self.cfg.aot_buckets:
                key = self._aot_key(kind, bucket)
                exe = _AOT_CACHE.get(key)
                if exe is None:
                    ops = self._aot_operands(kind, bucket)
                    if self.mesh is not None:
                        exe = self._steps[kind].lower(*ops).compile()
                    elif kind == "prefill":
                        exe = _prefill_step.lower(
                            self._step_model, *ops).compile()
                    else:
                        exe = _continue_step.lower(
                            self._step_model, *ops).compile()
                    _AOT_CACHE[key] = exe
                self._aot[(kind, bucket)] = exe

    def _select_aot(self, kind: str, reqs: list[Request]):
        """The AOT executable for this batch — ``(compiled, bucket)``, or
        ``(None, None)`` to fall back to the shape-keyed jit.  Hits and
        misses are counted only when bucketing is configured; a miss is a
        batch whose burst-aligned width exceeds every bucket (or a non-1D
        prompt modality the buckets were not compiled for)."""
        if not self._aot:
            return None, None
        page = self.cfg.page_size
        smax = max(len(r.prompt) for r in reqs)
        smax = -(-smax // page) * page
        bucket = None
        if not reqs[0].prompt.shape[1:]:     # 1-D token prompts only
            bucket = select_bucket(smax, self.cfg.aot_buckets)
        exe = self._aot.get((kind, bucket)) if bucket is not None else None
        if exe is None:
            self.counters.inc("aot_misses")
            return None, None
        self.counters.inc("aot_hits")
        return exe, bucket

    # ------------------------------------------------------------------
    # sharding invariants (mesh mode)
    # ------------------------------------------------------------------

    def check_sharding_invariants(self, extra=()) -> None:
        """Mesh mode: every persistent device array must still carry its
        declared layout.  The update paths that could silently reshard it
        — donated step outputs (including the shard_map kernel dispatches,
        whose claimed out specs GSPMD takes on faith with replication
        checks off), the ptab delta scatter, COW tail copies, and
        page-granular spill/restore through ``ContextSwitcher`` — all run
        between two calls of this check, so a drift (which would cost a
        full rematerialization on the next dispatch) fails loudly instead
        of showing up as a perf cliff.  ``extra`` adds transient
        ``(name, array, want)`` triples — the compute steps pass their
        kernel outputs (logits / sampled blocks) with the replicated
        sharding the step declared.  Metadata-only: no device sync."""
        if self.mesh is None:
            return
        for name, arr, want in (
            ("k_pools", self.kv.k_pools, self._pool_sh),
            ("v_pools", self.kv.v_pools, self._pool_sh),
            ("page_table", self._ptab, self._rep_sh),
        ) + tuple(extra):
            if not arr.sharding.is_equivalent_to(want, arr.ndim):
                # a real exception, not `assert`: the guard must survive
                # `python -O`, where asserts are compiled out
                raise RuntimeError(
                    f"executor {name} drifted off its declared layout: "
                    f"{arr.sharding} != {want}"
                )

    # ------------------------------------------------------------------
    # persistent device page table
    # ------------------------------------------------------------------

    def sync_page_table(self) -> None:
        """Apply host page-table deltas (dirty rows only) to the device."""
        rows, vals = self.vmem.drain_dirty_rows()
        if rows.size:
            self._ptab = self._steps["ptab"](
                self._ptab, jnp.asarray(rows), jnp.asarray(vals)
            )
            self.counters.inc("ptab_rows_uploaded", int(rows.size))
            self.counters.inc("ptab_syncs")
            self.check_sharding_invariants()

    @property
    def device_page_table(self) -> jax.Array:
        return self._ptab

    # ------------------------------------------------------------------
    # compute steps
    # ------------------------------------------------------------------

    def _count_dispatch(self) -> None:
        """Kernel-vs-ref observability, once per compute step: the silent
        mesh fallback this counter made loud is gone, so in any gated run
        ``ref_path_dispatches`` must be 0 unless the explicit escape hatch
        (``ServeConfig.use_ref_path``) asked for the jnp twin.  Quantized
        pools dispatch the SAME kernels (dequant-in-kernel), so int8 steps
        count as ``kernel_dispatches`` too; ``quant_dispatches`` tracks
        how many compute steps ran over quantized pools regardless of
        path, making "quantization silently fell back" as observable as
        the mesh fallback was."""
        if self._ref_path:
            self.counters.inc("ref_path_dispatches")
        elif getattr(self._step_model, "use_kernels", False):
            self.counters.inc("kernel_dispatches")
        if getattr(self._step_model, "kv_dtype", "native") != "native":
            self.counters.inc("quant_dispatches")

    def _continuation_gather_bytes(self, start_lens, smax: int,
                                   nrows: int) -> int:
        """Analytical K+V bytes the continuation-prefill attention reads
        per layer stack — the paper's bytes-gathered cost model, scored
        per dispatch so ``bench_serve_sharded`` can gate the kernel's
        page-streaming win ON THE MESH.  Kernel path: only pages reachable
        under the causal clamp per query block (``pages_touched``, the
        same formula the prefill kernel's grid enforces, with the ops
        wrapper's default bq).  Ref path: the jnp oracle gathers every
        row's full table reach."""
        from repro.kernels.paged_prefill_attention import pages_touched
        cfg = self.model.cfg
        per_tok = (2 * cfg.num_kv_heads * cfg.head_dim
                   * jnp.dtype(self.kv.k_pools.dtype).itemsize)
        if getattr(self._step_model, "use_kernels", False):
            pages = sum(
                pages_touched(int(st), smax, self.cfg.max_pages_per_seq,
                              page_size=self.cfg.page_size, bq=32)
                for st in start_lens
            )
            tokens = pages * self.cfg.page_size
        else:
            tokens = nrows * self.cfg.max_pages_per_seq * self.cfg.page_size
        return cfg.num_layers * per_tok * tokens

    def _decode_multi_fn(self, horizon: int):
        """The fused-horizon dispatch for ``horizon`` (statics bound)."""
        if self.mesh is not None:
            return _sharded_decode_multi(
                self._step_model, self.mesh, horizon, self.cfg.greedy
            )
        return functools.partial(
            _decode_multi_step, self._step_model,
            horizon=horizon, greedy=self.cfg.greedy,
        )

    def preload_prefix(self, prefix_tokens: np.ndarray, slot: int,
                       n: int) -> None:
        self.sync_page_table()
        tokens = np.asarray(prefix_tokens, np.int32)[None, :]
        page = self.cfg.page_size
        pad = (-n) % page
        if pad:
            tokens = np.pad(tokens, ((0, 0), (0, pad)))
        pt_rows = jnp.take(self._ptab, jnp.asarray([slot]), axis=0)
        _, k, v = self._steps["prefill"](
            self.params, jnp.asarray(tokens),
            jnp.asarray([n], jnp.int32), self.kv.k_pools, self.kv.v_pools,
            pt_rows,
        )
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._count_dispatch()
        self.counters.inc("prefix_tokens", n)

    def _pad_prompt_batch(self, reqs: list[Request],
                          bucket: int | None = None):
        """Burst-aligned ``[B, smax]`` prompt matrix + true lengths + the
        batch's page-table rows — shared by plain and forked admission so
        padding/slot-lookup policy cannot desynchronize between them.

        With ``bucket`` (an AOT dispatch) the batch is padded to the
        compiled shape — ``max_batch`` rows of ``bucket`` tokens.  The
        padding is numerically inert: pad rows carry ``lens=0`` and
        all-INVALID_PAGE table rows (writes route to the scratch frame),
        pad columns sit beyond every real row's length so causal masking
        excludes them — real-row outputs are bit-identical to the
        unbucketed dispatch.  The pure overhead (padded cells minus what
        the shape-keyed dispatch would have carried) is counted as
        ``bucket_pad_tokens``."""
        page = self.cfg.page_size
        smax = max(len(r.prompt) for r in reqs)
        smax = -(-smax // page) * page            # burst-align (jit reuse)
        nrows = len(reqs)
        rows = nrows
        if bucket is not None:
            self.counters.inc(
                "bucket_pad_tokens",
                self.cfg.max_batch * bucket - nrows * smax,
            )
            smax = bucket
            rows = self.cfg.max_batch
        tok_shape = (rows, smax) + reqs[0].prompt.shape[1:]
        tokens = np.zeros(tok_shape, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : len(r.prompt)] = r.prompt
        lens = np.zeros((rows,), np.int32)
        lens[:nrows] = [len(r.prompt) for r in reqs]
        slots = [self.vmem.seq(r.req_id).slot for r in reqs]
        pt_rows = jnp.take(self._ptab, jnp.asarray(slots), axis=0)
        if rows > nrows:
            pt_rows = jnp.pad(pt_rows, ((0, rows - nrows), (0, 0)),
                              constant_values=INVALID_PAGE)
        return tokens, lens, pt_rows

    def prefill(self, reqs: list[Request]) -> list[np.ndarray]:
        """Batched prefill of freshly admitted requests; returns the first
        sampled token per request (request order)."""
        self.sync_page_table()
        exe, bucket = self._select_aot("prefill", reqs)
        tokens, lens, pt_rows = self._pad_prompt_batch(reqs, bucket=bucket)
        fn = exe if exe is not None else self._steps["prefill"]
        with self.counters.timer("prefill"):
            logits, k, v = fn(
                self.params, jnp.asarray(tokens),
                jnp.asarray(lens), self.kv.k_pools, self.kv.v_pools, pt_rows,
            )
            # async dispatch returns immediately; block so the timer
            # measures execution, not dispatch
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._count_dispatch()
        # kernel outputs must come back on the declared (replicated)
        # layout, not whatever GSPMD inferred through the shard_map
        self.check_sharding_invariants(
            extra=(("prefill_logits", logits, self._rep_sh),)
        )
        first = self.sample(logits)
        return [np.asarray(first[i]) for i in range(len(reqs))]

    def decode(self, tokens: np.ndarray, pre_lens: np.ndarray,
               active: np.ndarray) -> np.ndarray:
        """One full-slot decode step (the horizon's K=1 collapse path);
        returns sampled tokens by slot."""
        self.sync_page_table()
        with self.counters.timer("decode"):
            logits, k, v = self._steps["decode"](
                self.params, jnp.asarray(tokens),
                self.kv.k_pools, self.kv.v_pools, self._ptab,
                jnp.asarray(pre_lens), jnp.asarray(active),
            )
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._count_dispatch()
        self.check_sharding_invariants(
            extra=(("decode_logits", logits, self._rep_sh),)
        )
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon")
        return self.sample(logits)

    def decode_multi(self, plan: DecodePlan) -> np.ndarray:
        """Fused K-step decode horizon: ONE dispatch runs ``plan.horizon``
        chained decode steps with on-device sampling and per-lane retire
        masking, then transfers the whole ``[K, B, ...]`` token block in
        one host sync.  ``Executor.sample``'s per-token host path does not
        run on this path.  The scheduler has already pre-faulted every page
        the horizon touches, so exactly one page-table delta sync happens
        per horizon."""
        self.sync_page_table()
        fused = self._decode_multi_fn(plan.horizon)
        with self.counters.timer("decode"):
            block, k, v, rng = fused(
                self.params, jnp.asarray(plan.tokens),
                self.kv.k_pools, self.kv.v_pools, self._ptab,
                jnp.asarray(plan.pre_lens), jnp.asarray(plan.steps_left),
                # plain float -> weak-typed scalar under jit: logits /
                # temperature keeps the logits dtype, exactly like the
                # host path's division by the Python float
                self._rng, float(self.cfg.temperature),
            )
            jax.block_until_ready(block)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._rng = rng
        self._count_dispatch()
        self.check_sharding_invariants(
            extra=(("decode_block", block, self._rep_sh),)
        )
        self.counters.inc("host_syncs")
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon", plan.horizon)
        return np.asarray(block)

    # ------------------------------------------------------------------
    # DataPlane protocol (driven by the Scheduler)
    # ------------------------------------------------------------------

    def admit_forked_batch(
        self, reqs: list[Request], start_lens: list[int],
        tail_copies: list[tuple[int, int] | None],
    ) -> list[np.ndarray]:
        """COW tail copies + ONE batched continuation prefill for all
        same-step forked admissions (each request's prompt chunk starts at
        its own logical offset) — replaces both the seed's one-token-at-a-
        time teacher forcing and the per-request B=1 continuation calls."""
        self.sync_page_table()
        copies = [tc for tc in tail_copies if tc is not None]
        if copies:
            k, v = self._steps["copy_pages"](
                self.kv.k_pools, self.kv.v_pools,
                jnp.asarray([src for src, _ in copies]),
                jnp.asarray([dst for _, dst in copies]),
            )
            self.kv = self.kv._replace(k_pools=k, v_pools=v)
        exe, bucket = self._select_aot("continue", reqs)
        chunks, lens, pt_rows = self._pad_prompt_batch(reqs, bucket=bucket)
        starts = np.zeros((chunks.shape[0],), np.int32)
        starts[: len(reqs)] = start_lens
        fn = exe if exe is not None else self._steps["continue"]
        with self.counters.timer("prefill"):
            logits, k, v = fn(
                self.params, jnp.asarray(chunks),
                jnp.asarray(starts),
                jnp.asarray(lens),
                self.kv.k_pools, self.kv.v_pools, pt_rows,
            )
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._count_dispatch()
        self.check_sharding_invariants(
            extra=(("continue_logits", logits, self._rep_sh),)
        )
        self.counters.inc("continuation_prefill_tokens", int(lens.sum()))
        self.counters.inc(
            "prefill_bytes_gathered",
            self._continuation_gather_bytes(
                [int(s) for s in start_lens], int(chunks.shape[1]),
                len(reqs),
            ),
        )
        first = self.sample(logits)
        return [np.asarray(first[i]) for i in range(len(reqs))]

    def spill(self, req: Request) -> None:
        """Page-granular spill: only the victim's frames leave the device."""
        self.switcher.spill_kv(req.req_id, self.kv.k_pools, self.kv.v_pools)
        # the spill gather (jnp.take over the page axis of a sharded pool
        # slice) must be read-only w.r.t. layout — symmetric with the
        # restore check below, so a kernel-path mesh run cannot drift
        # between a spill and the next dispatch
        self.check_sharding_invariants()

    def restore(self, req: Request, num_tokens: int,
                shared_pages: list[int] | None = None) -> None:
        """Page-granular restore into freshly allocated frames.

        ``shared_pages``: leading frames the scheduler proved are still
        the pinned prefix's (identical bytes, refcount-held) — re-shared
        by the switcher instead of allocated and scattered.

        ``num_tokens`` may be SHORTER than the spilled length (partial
        restore): the switcher scatters only the leading page-aligned
        portion and drops the record's tail, which the scheduler
        re-prefills through the continuation path."""
        # the DataPlane protocol passes the scheduler's requested restore
        # length; the switcher's own record is authoritative — a request
        # beyond it would silently diverge the re-mapped footprint
        assert num_tokens <= self.switcher.spilled_len(req.req_id), (
            f"restore of req {req.req_id}: scheduler asks {num_tokens} "
            f"tokens, switcher spilled only "
            f"{self.switcher.spilled_len(req.req_id)}"
        )
        k, v, _ = self.switcher.restore_kv(
            req.req_id, self.kv.k_pools, self.kv.v_pools,
            shared_prefix_pages=shared_pages, num_tokens=num_tokens,
        )
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        # the switcher's scatter is layout-oblivious; the pools must come
        # back in the declared mesh layout or every later dispatch pays a
        # full rematerialization
        self.check_sharding_invariants()

    def discard(self, req: Request) -> None:
        """Free a failed request's host-side swap record (never restored)."""
        self.switcher.discard(req.req_id)

    def export_swap(self, req: Request):
        """Detach the victim's portable swap record (host bytes in the
        pool storage dtype — int8 stays narrow) so the router can migrate
        it to another replica's plane."""
        return self.switcher.export_swap(req.req_id)

    def import_swap(self, req: Request, record) -> None:
        """Adopt a swap record exported from another replica's plane; the
        switcher validates the page geometry before anything moves."""
        self.switcher.import_swap(record)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------

    def sample(self, logits: jax.Array) -> np.ndarray:
        """Host-path sampling (prefill boundaries and the K=1 decode
        collapse path); every call forces one device->host sync.  The
        fused multi-step decode path samples on device instead."""
        self.counters.inc("host_syncs")
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._rng, key = jax.random.split(self._rng)
        return np.asarray(
            jax.random.categorical(
                key, logits / self.cfg.temperature, axis=-1
            )
        )
