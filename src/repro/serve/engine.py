"""Continuous-batching serving engine — architecture note.

AraOS's core architectural move is a clean split between the scalar core
that owns translation and OS policy (CVA6) and the decoupled vector
datapath that streams bursts (Ara2): the vector unit only hits peak when
the scalar side stays off its critical path.  The serving engine is that
split restated in JAX:

  **Scheduler  = the CVA6 / OS plane** (:mod:`repro.serve.scheduler`)
      Queues, admission control, victim selection, fork bookkeeping,
      page-table policy.  Pure Python/NumPy — no device arrays — and
      unit-testable without a device.  It drives the data plane through
      the narrow :class:`~repro.serve.scheduler.DataPlane` protocol.

  **Executor  = the Ara2 data plane** (:mod:`repro.serve.executor`)
      All device state: the paged KV pools, a *persistent device page
      table* updated incrementally from ``VirtualMemory`` dirty-row
      deltas (never re-uploaded wholesale), and jitted prefill /
      continuation-prefill / decode steps with donated KV pools (in-place
      updates).  Spill/restore move only the victim sequence's pages
      (``ContextSwitcher.spill_kv`` — the paper's §3.1 context-switch
      cost, measured in actually-moved bytes).

  **Engine  (this module)**
      A thin facade that wires the two together and keeps the seed
      engine's public surface (``submit``/``run``/``step``/``stats``/
      ``preload_prefix``).  Requests flow
      queued -> running -> (swapped <->) running -> done.

Responsibilities mapped from the paper: page-table ownership and
on-demand allocation (MMU + OS kernel); page faults during decode with
precise accounting; preemption when the pool is exhausted (§3.1 context
switch); scheduler quanta and tick accounting (100 Hz analogue); perf
counters + snapshot FIFO (the paper's measurement infrastructure).

**Fused multi-step decode (the amortization contract on the decode
loop).**  AraOS's result is that VM overhead stays under 3.5% only
because translation is paid once per page-bounded burst, not once per
element.  The decode loop restates that per token: instead of one host
round-trip per generated token (dispatch one step, sync the sampled
token to host, replan pages, re-upload the token), the Scheduler
computes a safe horizon K — collapsed to 1 whenever a queued admission
or restore could become due mid-horizon, or when the pool cannot
pre-fault all K steps of growth — pre-faults every page the horizon will
touch in ONE batched allocation (``VirtualMemory.append_tokens_batch``,
one dirty-row flush), and the Executor runs K chained decode steps in a
single dispatch with ON-DEVICE sampling and per-lane retire masking
(``Executor.decode_multi``).  The scalar/OS plane intervenes once per
horizon: ``counters["host_syncs"] / counters["decode_tokens"]`` is the
measured amortization (the ``benchmarks/run.py --only serve`` gate
requires it < 1.0).  K=1 reproduces pre-horizon behavior exactly.

**Radix prefix layer** (:mod:`repro.serve.prefix_cache`): the Scheduler
keeps a page-granularity radix trie over the token content of resident
mapped runs — the preloaded prefix, every committed prompt, every fork
child.  A plain admission whose prompt's leading whole pages match a
registered run COW-maps those pages from the owner (the same
``fork_seq`` refcount machinery explicit forks use) and prefills only
the divergent chunk through ``admit_forked_batch``'s batched
continuation dispatch.  Token streams are identical to cold admission —
causal KV content is a pure function of the token prefix — which the
prefix bench gate (``benchmarks/run.py --only prefix``) asserts while
requiring >50% of prefill tokens skipped on a multi-turn chat workload.

The device pool reserves its LAST frame as scratch for masked decode
lanes: the engine hands ``VirtualMemory`` one frame fewer than physically
allocated.  The frozen pre-split implementation lives in
:mod:`repro.serve.reference` for equivalence tests and benchmarks.

**Multi-replica layering.**  One engine is one replica: the
:class:`~repro.serve.router.ReplicaRouter` places requests from a global
admission queue across N of these (fork affinity, least-loaded-pages or
round-robin) and drives each replica's Scheduler through the same
:meth:`~repro.serve.scheduler.Scheduler.step_plane` loop this engine's
``step`` delegates to.  Replicas share no mutable state — the N=1 router
is call-for-call this engine.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.core import CostModel, PerfCounters, VirtualMemory, VMemConfig
from repro.models.transformer import TransformerLM
from repro.serve.api import ServeRequest, ServeResult, to_internal
from repro.serve.detokenize import AsyncDetokenizer
from repro.serve.executor import Executor
from repro.serve.scheduler import Request, Scheduler, ServeConfig

__all__ = ["Engine", "Request", "ServeConfig", "ServeRequest", "ServeResult"]


def _lower(req: ServeRequest, next_id: Callable[[], int],
           cfg: ServeConfig) -> Request:
    """Lower a client submission to the scheduler-plane :class:`Request`.

    :class:`~repro.serve.api.ServeRequest` is the ONLY accepted public
    type.  The scheduler-plane :class:`Request` stays public for fake-
    plane harnesses — which construct it and call ``Scheduler.submit``
    directly — but submitting one here is a hard :class:`TypeError` (the
    one-PR deprecation shim is gone)."""
    if not isinstance(req, ServeRequest):
        raise TypeError(
            f"Engine/ReplicaRouter.submit takes a repro.serve.api."
            f"ServeRequest, got {type(req).__name__}; scheduler-plane "
            "harnesses submit internal Requests via Scheduler.submit"
        )
    rid = req.req_id if req.req_id is not None else next_id()
    return to_internal(req, req_id=rid, cfg=cfg)


class Engine:
    """Continuous batching over a paged-KV transformer (Scheduler+Executor)."""

    def __init__(self, model: TransformerLM, params: Any, cfg: ServeConfig,
                 cost: CostModel | None = None, mesh=None,
                 detokenize: Callable[[Any], str] | None = None):
        """``mesh``: optional ('kv', 'hd') serve mesh
        (:func:`repro.launch.mesh.make_host_serve_mesh`); when omitted it
        is resolved from ``cfg.serve_mesh`` (:meth:`ServeConfig.build_mesh`).
        Only the Executor's device state shards over it; the Scheduler is
        pure host policy and needs no changes — that was the point of the
        split.  ``detokenize``: token->text hook for the async stream
        thread (defaults to the id-rendering placeholder)."""
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cost = cost or CostModel()
        self.counters = PerfCounters()
        if mesh is None:
            mesh = cfg.build_mesh(model.cfg)
        # the device pool has num_pages frames; the allocator sees one less
        # (last frame = scratch for masked writes)
        self.vmem = VirtualMemory(VMemConfig(
            page_size=cfg.page_size,
            num_pages=cfg.num_pages - 1,
            max_pages_per_seq=cfg.max_pages_per_seq,
            max_seqs=cfg.max_batch,
        ))
        self.scheduler = Scheduler(cfg, self.vmem, self.cost, self.counters)
        self.executor = Executor(model, params, cfg, self.vmem, self.cost,
                                 self.counters, mesh=mesh)
        self.scheduler.attach_plane(self.executor)
        #: async detokenize/stream thread (lazy: requests without a
        #: stream_callback never spawn it)
        self.detok = AsyncDetokenizer(detokenize, counters=self.counters)
        self.scheduler.attach_stream(self.detok)
        self._next_req_id = 0

    # ------------------------------------------------------------------
    # compat surface (seed engine attribute layout)
    # ------------------------------------------------------------------

    @property
    def switcher(self):
        return self.executor.switcher

    @property
    def kv(self):
        return self.executor.kv

    @property
    def queue(self):
        return self.scheduler.queue

    @property
    def running(self):
        return self.scheduler.running

    @property
    def swapped(self):
        return self.scheduler.swapped

    @property
    def done(self):
        return self.scheduler.done

    @property
    def PREFIX_ID(self):
        return self.scheduler.PREFIX_ID

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def preload_prefix(self, prefix_tokens: np.ndarray) -> None:
        """Prefill a resident shared prefix (system-prompt caching).

        Subsequent ``submit(req, share_prefix=True)`` requests fork their
        page tables from it: whole prefix pages are shared by refcount,
        only the partial tail page is copied.  The prefix also enters the
        scheduler's radix cache (AFTER its KV is committed here), so plain
        requests whose prompts merely START with the prefix content share
        its whole pages automatically — no fork API needed.
        """
        assert self.vmem.num_seqs == 0, "preload before serving"
        n = len(prefix_tokens)
        self.vmem.map_seq(self.scheduler.PREFIX_ID, n)
        slot = self.vmem.seq(self.scheduler.PREFIX_ID).slot
        self.executor.preload_prefix(np.asarray(prefix_tokens, np.int32),
                                     slot, n)
        self.scheduler.prefix_len = n
        self.scheduler.register_resident(
            self.scheduler.PREFIX_ID, np.asarray(prefix_tokens, np.int32)
        )

    def _alloc_req_id(self) -> int:
        rid = self._next_req_id
        self._next_req_id += 1
        return rid

    def submit(self, req: ServeRequest) -> int:
        """Enqueue a :class:`~repro.serve.api.ServeRequest` — the one
        public client type (anything else is a ``TypeError``).  Returns
        the request id."""
        internal = _lower(req, self._alloc_req_id, self.cfg)
        self._next_req_id = max(self._next_req_id, internal.req_id + 1)
        self.scheduler.submit(internal)
        return internal.req_id

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        """Drive until all submitted requests complete."""
        while self.scheduler.has_work and self.scheduler.step_i < max_steps:
            self.step()
        return self.scheduler.done

    def drain(self, max_steps: int = 10_000) -> dict[int, ServeResult]:
        """Drive to completion, flush the async stream thread (re-raising
        any callback exception), and return typed
        :class:`~repro.serve.api.ServeResult` records by request id."""
        self.run(max_steps)
        self.detok.drain()
        return {
            rid: ServeResult.from_request(r)
            for rid, r in self.scheduler.done.items()
        }

    def close(self) -> None:
        """Retire the stream thread deterministically (idempotent)."""
        self.detok.close()

    def step(self) -> None:
        # the canonical serving step lives on the Scheduler
        # (``step_plane``): restore -> admit/prefill -> fused-horizon
        # decode -> commit, driven through the DataPlane protocol.  The
        # multi-replica router (repro.serve.router) drives the same loop
        # once per replica — this engine IS its N=1 instance.
        self.scheduler.step_plane()

    def as_replica(self, replica_id: int):
        """This engine as one replica of a
        :class:`~repro.serve.router.ReplicaRouter` (its Scheduler and
        Executor are already wired and share one counter set)."""
        from repro.serve.router import Replica
        return Replica.from_engine(self, replica_id)

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict[str, Any]:
        rep = self.counters.report()
        rep["switch_stats"] = dataclasses.asdict(self.executor.switcher.stats)
        rep["pool"] = {
            "frames": self.vmem.pool.num_pages,
            "free": self.vmem.pool.num_free,
            "faults": self.vmem.pool.fault_count,
        }
        rep["modeled_ctx_switch_seconds"] = (
            self.executor.switcher.stats.modeled_seconds(self.cost)
        )
        return rep
