"""Model zoo: dense / MoE / hybrid RG-LRU / RWKV-6 / VLM / audio backbones."""

from typing import Any

from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.rglru import HybridState, RecurrentGemmaLM
from repro.models.rwkv6 import RecurrentState, RWKV6LM
from repro.models.transformer import PagedKVState, TransformerLM


def build_model(cfg: ModelConfig, **kwargs: Any):
    """Factory: returns the family-appropriate LM with a common API
    (init / loss / prefill / decode_step)."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return TransformerLM(cfg, **kwargs)
    if cfg.family == "rwkv6":
        return RWKV6LM(cfg, **kwargs)
    if cfg.family == "hybrid_rglru":
        return RecurrentGemmaLM(cfg, **kwargs)
    raise ValueError(f"unknown family {cfg.family}")


__all__ = [
    "SHAPES",
    "HybridState",
    "ModelConfig",
    "PagedKVState",
    "RWKV6LM",
    "RecurrentGemmaLM",
    "RecurrentState",
    "ShapeConfig",
    "TransformerLM",
    "build_model",
]
