"""§Roofline report: aggregate the dry-run cells into the per-(arch x shape
x mesh) three-term table (EXPERIMENTS.md §Roofline reads this output).

Terms (per device, v5e constants; conventions in launch/hlo_cost.py):
  compute    = HLO_FLOPs / 197 TFLOP/s
  memory     = HLO_bytes / 819 GB/s
  collective = collective_bytes / 50 GB/s per link
"""

from __future__ import annotations

import json
import os

from repro.launch.hlo_analysis import fmt_seconds

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh_filter: str | None = None) -> list[dict]:
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for name in sorted(os.listdir(DRYRUN_DIR)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, name)) as f:
            r = json.load(f)
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        cells.append(r)
    return cells


def table(cells: list[dict]) -> list[str]:
    lines = []
    hdr = (f"{'arch':26s} {'shape':11s} {'mesh':11s} {'st':4s} "
           f"{'compute':>9s} {'memory':>9s} {'collect':>9s} {'dom':>10s} "
           f"{'MFU@roof':>8s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in cells:
        tag = f"{r['arch']:26s} {r['shape']:11s} {r['mesh']:11s}"
        if r["status"] == "skipped":
            print(f"{tag} skip  ({r['reason'][:50]})")
            lines.append(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,skipped")
            continue
        if r["status"] != "ok":
            print(f"{tag} ERR   {r.get('error', '')[:60]}")
            lines.append(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,ERROR")
            continue
        t = r["roofline"]
        print(f"{tag} ok   {fmt_seconds(t['compute_s']):>9s} "
              f"{fmt_seconds(t['memory_s']):>9s} "
              f"{fmt_seconds(t['collective_s']):>9s} {t['dominant']:>10s} "
              f"{t['roofline_fraction']:8.2%} "
              f"{t['useful_flops_fraction']:7.2f}")
        lines.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,"
            f"dom={t['dominant']} frac={t['roofline_fraction']:.4f}"
        )
    return lines


def main() -> list[str]:
    cells = load_cells()
    if not cells:
        print("no dry-run cells found — run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all")
        return ["roofline_missing,0,run dryrun first"]
    return table(cells)


if __name__ == "__main__":
    main()
