"""Atomic sharded checkpointing with async save + elastic restore."""
from repro.checkpoint.checkpoint import (
    AsyncCheckpointer, garbage_collect, latest_step, restore, save,
)
__all__ = ["AsyncCheckpointer", "garbage_collect", "latest_step", "restore", "save"]
