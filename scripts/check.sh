#!/usr/bin/env bash
# Tier-1 verification without the multi-minute sharding subprocesses:
#   1. byte-compile the whole tree (catches syntax/indent errors fast);
#   2. import the package surface (catches broken module wiring);
#   3. run the `fast` pytest subset (everything not marked `slow`).
# The full gate (including sharding dry-runs) stays:
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== byte-compile"
python -m compileall -q src benchmarks examples tests

echo "== import surface"
python - <<'PY'
import repro.core, repro.kernels.ops, repro.models, repro.serve
import repro.launch.sharding, repro.launch.mesh
print("imports OK")
PY

echo "== kernel differential grids (fail fast on kernel regressions)"
python -m pytest -q -m kernels "$@"

echo "== fast tests"
python -m pytest -q -m "fast and not kernels" "$@"

echo "== serve gate (fused decode horizon must amortize host syncs)"
python -m benchmarks.run --only serve
