"""Multi-replica serving control plane: one admission front-end, N planes.

Ara2 (Perotti et al., 2023) scales the Ara lane datapath to multi-core by
replicating the compute fabric behind one shared front-end; AraOS's claim
is that the shared translation/OS structure stays off the datapath's
critical path while it does.  This module is the serving restatement of
that shape: a :class:`ReplicaRouter` owns the **global admission queue**
and places requests across N model replicas — each a
:class:`~repro.serve.scheduler.Scheduler` (per-replica policy, its own
:class:`~repro.serve.scheduler.ReplicaState`) driving its own
:class:`~repro.serve.scheduler.DataPlane` (a device
:class:`~repro.serve.executor.Executor`, optionally mesh-sharded, or a
test fake).  Replicas share **no mutable state**: page pools, KV pools,
swap records and step clocks are all per-replica, so the router is pure
placement policy on top of N independent single-replica engines — and the
single-replica engine is exactly the ``N=1`` instance of this layering.

Placement policies (``policy=``):

``least_loaded``
    Fewest committed-plus-backlogged pages (frames in use + the page
    demand of requests already queued on the replica); ties break toward
    the lowest replica id.  The default.
``round_robin``
    Cyclic over replicas, skipping ineligible ones.

**Fork affinity** is not a policy but a correctness constraint layered on
both: a ``share_prefix`` request COW-forks the resident prefix's page
table, and those shared pages live in ONE replica's pool — so forks are
only ever placed on a replica holding the prefix (the "parent").  When
the affinity constraint overrides the base policy's unconstrained choice,
the router counts a ``migrations_declined`` (the fork was *not* migrated
to the otherwise-best replica, keeping prefix sharing instead).

**Prefix-aware ranking** generalizes fork affinity into an additive
score: for plain requests each candidate replica is probed
(``Scheduler.probe_prefix``) for the longest radix-cached resident
prefix of the request's prompt.  Under ``least_loaded`` the matched page
count is subtracted from the replica's load (each matched page is one
frame the replica will NOT allocate — plus the skipped prefill compute);
under ``round_robin`` the cycle is restricted to the replicas with the
maximal match whenever any replica matches at all.  It is a *score*, not
a constraint: a heavily loaded prefix holder still loses to an idle cold
replica once the load gap exceeds the matched pages.  Placements where
the prefix score changed the base policy's choice are counted as
``prefix_routed``.

**Cross-replica swap migration** (``migrate=True``, the default; inert
at N=1): swap records are PORTABLE (:class:`~repro.serve.scheduler.
SwapExport` — host bytes in the pool storage dtype plus the pinned-prefix
provenance as a page COUNT), so a spilled victim is no longer welded to
the replica that spilled it.  Once per router step, BEFORE the replicas
run, the router sweeps each replica's swap-FIFO head and migrates it when
(a) it is about to be failed as restore-unreachable at the source but
another replica's pinned-prefix-adjusted demand fits (*rescue* — the
PR 2 "fail as unreachable" verdict now lands only when NO replica can
ever host it), or (b) it has sat capacity-blocked for ``migrate_after``
router steps and another replica can restore it immediately
(*starvation*).  Fork affinity and pinned-prefix re-sharing are
re-resolved against the DESTINATION's prefix mapping — a destination
without the prefix simply restores every page from the record, which is
self-contained.  An import rejected by the destination plane (raised
before side effects, per the DataPlane contract) rolls back with a
front-of-FIFO re-import at the source.  Migration also makes placement
REACH-AWARE: replicas whose attainable pool can never host a request's
lifetime demand are filtered out of the candidate set
(``reach_redirects``), so heterogeneous fleets stop feeding requests to
replicas that must fail them.

Counters (router-global, in ``router.counters``): ``submitted``,
``placements``, ``placements_replica{i}``, ``migrations_declined``,
``prefix_routed``, ``restore_migrations``, ``migration_aborts``,
``reach_redirects``, ``cross_replica_queue_waits`` (request-steps spent
in the global queue while every eligible replica was at its backlog
bound).  Each replica's scheduler/executor counters stay per-replica
(migration adds ``swap_exports``/``swap_imports`` there);
``global_counters()`` merges them, and the test-suite invariant is that
every merged total equals the sum of the per-replica values (no event is
double- or un-counted by adding replicas).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from collections import deque
from typing import Any

from repro.core import PerfCounters
from repro.serve.api import ServeRequest, ServeResult
from repro.serve.scheduler import DataPlane, Request, Scheduler


@dataclasses.dataclass(eq=False)     # identity semantics: list.index / in
class Replica:
    """One model replica: a policy plane bound to its data plane.

    ``scheduler.counters`` must be the SAME object the plane increments
    (the :class:`~repro.serve.engine.Engine` wiring), so per-replica
    accounting covers both planes without double counting.
    """

    replica_id: int
    scheduler: Scheduler
    plane: DataPlane

    @classmethod
    def from_engine(cls, engine: Any, replica_id: int) -> "Replica":
        """Bind a single-replica :class:`~repro.serve.engine.Engine` as
        one replica of a router (its Scheduler/Executor pair is already
        wired and counter-shared)."""
        engine.scheduler.state.replica_id = replica_id
        return cls(replica_id=replica_id, scheduler=engine.scheduler,
                   plane=engine.executor)

    @property
    def has_prefix(self) -> bool:
        """True when this replica holds a resident shared prefix a fork
        could COW from."""
        s = self.scheduler
        return s.prefix_len > 0 and s.vmem.has_seq(s.PREFIX_ID)

    def load_pages(self) -> int:
        """Placement load metric: frames committed in the pool plus the
        page demand of requests already placed but still queued here.
        The backlog term is what spreads a burst submitted before any
        step runs — committed frames alone are all-zero then."""
        s = self.scheduler
        return s.vmem.pool.num_used + sum(
            s.required_pages(r) for r in s.queue
        )

    def page_report(self) -> dict[str, int]:
        pool = self.scheduler.vmem.pool
        return {"frames": pool.num_pages, "free": pool.num_free,
                "used": pool.num_used, "faults": pool.fault_count}


class ReplicaRouter:
    """Places requests from a global admission queue over N replicas and
    drives every busy replica one :meth:`Scheduler.step_plane` per router
    step.  With one replica and the default unbounded backlog this is
    call-for-call the single-replica ``Engine`` loop."""

    POLICIES = ("least_loaded", "round_robin")

    def __init__(self, replicas: list[Replica],
                 policy: str = "least_loaded",
                 counters: PerfCounters | None = None,
                 max_backlog: int | None = None,
                 migrate: bool = True, migrate_after: int = 8):
        """``max_backlog``: per-replica queued-request bound; placement
        defers (requests wait in the global queue, counted as
        ``cross_replica_queue_waits``) while every eligible replica is at
        the bound AND at least one replica is still busy.  ``None``
        (default) places immediately — required for exact N=1
        equivalence with the plain engine.

        ``migrate``: cross-replica swap migration + reach-aware placement
        (see the module docstring); inert at N=1, so the default ``True``
        preserves exact single-replica equivalence.  ``migrate_after``:
        router steps a swap-FIFO head may sit capacity-blocked before a
        starvation migration is attempted (rescue migrations — victims
        the source is about to fail — never wait)."""
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        ids = [rep.replica_id for rep in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids: {ids}")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {self.POLICIES}")
        if migrate_after < 1:
            raise ValueError(f"migrate_after must be >= 1, "
                             f"got {migrate_after}")
        self.replicas = list(replicas)
        self.policy = policy
        self.counters = counters or PerfCounters()
        self.max_backlog = max_backlog
        self.migrate = migrate
        self.migrate_after = migrate_after
        self.queue: deque[Request] = deque()   # global admission queue
        self.step_i = 0                        # router engine-steps
        self._rr_next = 0
        self._next_req_id = 0
        #: router steps each swap-FIFO HEAD victim has sat capacity-
        #: blocked (the starvation clock); entries are pruned the moment
        #: the victim stops being a blocked head anywhere
        self._swap_age: dict[int, int] = {}

    # ------------------------------------------------------------------
    # queue API
    # ------------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(
            rep.scheduler.has_work for rep in self.replicas
        )

    @property
    def done(self) -> dict[int, Request]:
        """Merged done map (per-replica completion order preserved within
        each replica; cross-replica order is replica-major)."""
        merged: dict[int, Request] = {}
        for rep in self.replicas:
            merged.update(rep.scheduler.done)
        return merged

    def submit(self, req: ServeRequest) -> int:
        """Enqueue a :class:`~repro.serve.api.ServeRequest` — the one
        public client type (anything else is a ``TypeError``; scheduler-
        plane harnesses submit internal ``Request`` objects through
        ``Scheduler.submit``).  Returns the request id."""
        from repro.serve.engine import _lower
        internal = _lower(
            req, self._alloc_req_id, self.replicas[0].scheduler.cfg
        )
        self._next_req_id = max(self._next_req_id, internal.req_id + 1)
        # TTFT clock starts at ROUTER entry: global-queue wait (backlog
        # bound) must show up in the SLO numbers, so the stamp cannot wait
        # for replica placement (Scheduler.submit only stamps if unset)
        if internal.t_enqueue == 0.0:
            internal.t_enqueue = time.perf_counter()
        self.counters.inc("submitted")
        self.queue.append(internal)
        self._place_pending()
        return internal.req_id

    def _alloc_req_id(self) -> int:
        rid = self._next_req_id
        self._next_req_id += 1
        return rid

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------

    def _eligible(self, req: Request) -> tuple[list[Replica], bool]:
        """Replicas that can legally host ``req``; second element flags
        the fork-affinity constraint (COW pages cannot cross pools)."""
        if req.share_prefix:
            elig = [rep for rep in self.replicas if rep.has_prefix]
            if not elig:
                raise ValueError(
                    f"request {req.req_id} wants share_prefix but no "
                    "replica holds a resident prefix (preload one first)"
                )
            return elig, len(elig) < len(self.replicas)
        return list(self.replicas), False

    def _match_pages(self, rep: Replica, req: Request | None) -> int:
        """Whole pages of ``req``'s prompt resident in ``rep``'s radix
        cache (0 with no request / no cache / no match — every pre-prefix
        ranking reduces to the base policy then)."""
        if req is None:
            return 0
        matched, _ = rep.scheduler.probe_prefix(req)
        return matched // rep.scheduler.cfg.page_size

    def _rank(self, candidates: list[Replica], advance_rr: bool = False,
              req: Request | None = None) -> Replica:
        """Policy choice among ``candidates`` (never empty): the base
        policy plus the additive prefix score for ``req`` (see the module
        docstring).  ``req=None`` ranks prefix-blind — used to attribute
        ``prefix_routed``/``migrations_declined`` to the constraint that
        actually changed the outcome."""
        if self.policy == "round_robin":
            pool = candidates
            best = max((self._match_pages(rep, req) for rep in candidates),
                       default=0)
            if best > 0:
                pool = [rep for rep in candidates
                        if self._match_pages(rep, req) == best]
            n = len(self.replicas)
            for k in range(n):
                cand = self.replicas[(self._rr_next + k) % n]
                if cand in pool:
                    if advance_rr:
                        self._rr_next = (
                            self.replicas.index(cand) + 1
                        ) % n
                    return cand
            raise AssertionError("unreachable: candidates is non-empty")
        return min(candidates,
                   key=lambda rep: (
                       rep.load_pages() - self._match_pages(rep, req),
                       rep.replica_id,
                   ))

    def _backlog_open(self, reps: list[Replica]) -> list[Replica]:
        if self.max_backlog is None:
            return list(reps)
        return [rep for rep in reps
                if len(rep.scheduler.queue) < self.max_backlog]

    def _can_ever_host(self, rep: Replica, req: Request) -> bool:
        """Whether ``rep``'s attainable pool could EVER run ``req`` mapped
        to completion — the scheduler's own admission reach check, asked
        at placement time so a reach-blind policy stops feeding requests
        to replicas that must fail them."""
        s = rep.scheduler
        matched, owner = s.probe_prefix(req)
        return not s._admission_unreachable(req, matched, owner)

    def _place_one(self, req: Request) -> Replica | None:
        """Choose a replica for ``req`` and commit it there, or return
        ``None`` to keep it waiting in the global queue (backlog bound)."""
        elig, constrained = self._eligible(req)
        if self.migrate and len(self.replicas) > 1:
            # reach-aware placement: drop replicas that would fail the
            # request at admission.  If EVERY eligible replica is
            # unreachable the filter is a no-op — the request then fails
            # at admission, which is the correct global verdict.
            reach = [rep for rep in elig if self._can_ever_host(rep, req)]
            if reach and len(reach) < len(elig):
                elig = reach
                self.counters.inc("reach_redirects")
        open_elig = self._backlog_open(elig)
        if not open_elig:
            if any(rep.scheduler.has_work for rep in self.replicas):
                return None              # wait; retried next router step
            open_elig = elig             # idle fleet: never park forever
        if constrained:
            # what the base policy would do with fork affinity ignored,
            # under the SAME backlog conditions (else a backlog-diverted
            # placement would masquerade as a declined migration).
            # Read-only rank: the round-robin pointer does not advance.
            # Forks rank prefix-blind (affinity already restricted the
            # pool to prefix holders — the score would be a no-op).
            free_pool = self._backlog_open(self.replicas) or open_elig
            free_choice = self._rank(free_pool)
            choice = self._rank(open_elig, advance_rr=True)
            if free_choice.replica_id != choice.replica_id:
                self.counters.inc("migrations_declined")
        else:
            # read-only prefix-blind rank first: a placement the prefix
            # score diverted from the base choice counts as prefix_routed
            blind_choice = self._rank(open_elig)
            choice = self._rank(open_elig, advance_rr=True, req=req)
            if blind_choice.replica_id != choice.replica_id:
                self.counters.inc("prefix_routed")
        choice.scheduler.submit(req)     # stamps arrival in replica time
        choice.scheduler.counters.inc("router_placements")
        self.counters.inc("placements")
        self.counters.inc(f"placements_replica{choice.replica_id}")
        self.counters.snapshot("place", (req.req_id, choice.replica_id))
        return choice

    def _place_pending(self) -> None:
        while self.queue:
            if self._place_one(self.queue[0]) is None:
                break
            self.queue.popleft()

    # ------------------------------------------------------------------
    # cross-replica swap migration
    # ------------------------------------------------------------------

    def _resolve_dest_claim(self, rep: Replica, k: int) -> int:
        """Pinned-prefix pages of ``rep`` a migrated victim with a
        ``k``-page source claim could re-share (the fleet invariant:
        preloaded prefixes are identical, so the destination's first
        ``k`` whole prefix pages hold the same bytes)."""
        d = rep.scheduler
        if k and d.vmem.has_seq(d.PREFIX_ID) and \
                k <= min(len(d.vmem.seq(d.PREFIX_ID).pages),
                         d.prefix_len // d.cfg.page_size):
            return k
        return 0

    def _pick_migration_dest(self, src: Replica, req_id: int,
                             immediate: bool) -> Replica | None:
        """Best destination for ``src``'s swapped victim ``req_id``, or
        ``None``.  The pinned-prefix claim is re-resolved per candidate
        (fork affinity as a *preference*: prefix holders see a smaller
        demand, but the record is self-contained so any replica whose
        attainable pool fits is legal).  ``immediate``: require capacity
        to restore right now (starvation moves), not merely reachability
        (rescue moves — the destination may still need to drain/preempt)."""
        s = src.scheduler
        num_tokens = s._spilled_tokens[req_id]
        k = len(s._restorable_shared(req_id))
        pf = s.vmem.config.pages_for
        best: tuple[tuple[int, int], Replica] | None = None
        for rep in self.replicas:
            if rep is src:
                continue
            d = rep.scheduler
            need = pf(num_tokens) - self._resolve_dest_claim(rep, k)
            if need > d.attainable_pages():
                continue
            if immediate and (need > d.vmem.pool.num_free
                              or d.vmem.num_free_slots <= 0
                              or len(d.running) >= d.cfg.max_batch):
                continue
            key = (-d.vmem.pool.num_free, d.replica_id)
            if best is None or key < best[0]:
                best = (key, rep)
        return None if best is None else best[1]

    def _migrate_starved(self) -> None:
        """Once per router step, BEFORE the replicas run: sweep each
        replica's swap-FIFO head and migrate victims the source is about
        to fail (rescue) or has starved past ``migrate_after`` blocked
        steps (starvation).  Head-only, so per-replica swap-FIFO
        completion order is never reordered by migration."""
        live: set[int] = set()
        for src in self.replicas:
            s = src.scheduler
            if not s.swapped:
                continue
            rid = s.swapped[0]
            shared = s._restorable_shared(rid)
            need = (s.vmem.config.pages_for(s._spilled_tokens[rid])
                    - len(shared))
            rescue = need > s.attainable_pages()
            if not rescue and s.can_restore(rid):
                continue                  # restores at the source this step
            live.add(rid)
            age = self._swap_age.get(rid, 0) + 1
            self._swap_age[rid] = age
            if not rescue and age < self.migrate_after:
                continue
            dest = self._pick_migration_dest(src, rid, immediate=not rescue)
            if dest is None:
                continue                  # no host anywhere: verdict stands
            exp = s.export_swapped(rid)
            try:
                dest.scheduler.import_swapped(exp)
            except Exception:
                # destination plane rejected the record (raised before any
                # side effect, per the DataPlane contract): roll back at
                # the source HEAD so FIFO order is unchanged
                self.counters.inc("migration_aborts")
                s.import_swapped(exp, front=True)
                continue
            live.discard(rid)
            self.counters.inc("restore_migrations")
            self.counters.snapshot(
                "migrate", (rid, src.replica_id, dest.replica_id))
        # prune starvation clocks for victims that restored, retired,
        # migrated or stopped being a blocked head
        for rid in list(self._swap_age):
            if rid not in live:
                del self._swap_age[rid]

    # ------------------------------------------------------------------
    # drive
    # ------------------------------------------------------------------

    def step(self) -> None:
        self.step_i += 1
        if self.migrate and len(self.replicas) > 1:
            self._migrate_starved()
        self._place_pending()
        if self.queue:
            # request-steps spent waiting in the global queue (every
            # eligible replica at its backlog bound)
            self.counters.inc("cross_replica_queue_waits", len(self.queue))
        for rep in self.replicas:
            if rep.scheduler.has_work:
                rep.scheduler.step_plane()
        # retirements may have opened slots/frames for deferred placements
        self._place_pending()

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        """Drive until every placed and pending request completes, or the
        slowest still-busy replica's token-step clock reaches
        ``max_steps`` (the same per-replica budget semantics as
        ``Engine.run``: fused horizons advance a replica's clock in
        token-steps)."""
        while self.has_work and self._clock() < max_steps:
            self.step()
        return self.done

    def drain(self, max_steps: int = 10_000) -> dict[int, ServeResult]:
        """Drive to completion, flush every replica's async stream sink
        (re-raising the first callback exception), and return typed
        :class:`~repro.serve.api.ServeResult` records by request id."""
        self.run(max_steps)
        for rep in self.replicas:
            stream = rep.scheduler.stream
            if stream is not None:
                stream.drain()
        return {
            rid: ServeResult.from_request(r)
            for rid, r in self.done.items()
        }

    def _clock(self) -> int:
        active = [rep.scheduler.step_i for rep in self.replicas
                  if rep.scheduler.has_work]
        if active:
            return min(active)
        return min(rep.scheduler.step_i for rep in self.replicas)

    # ------------------------------------------------------------------
    # accounting / invariants
    # ------------------------------------------------------------------

    def global_counters(self) -> collections.Counter:
        """Router counters + the sum of every replica's counters.  The
        cross-replica invariant the test suite asserts: each merged total
        equals the sum of the per-replica values."""
        merged = PerfCounters.merged(
            rep.scheduler.counters for rep in self.replicas
        )
        merged.update(self.counters.counters)
        return merged

    def global_page_report(self) -> dict[str, int]:
        """Fleet-wide page accounting — by construction the element-wise
        sum of the per-replica reports (asserted in
        :meth:`check_invariants`)."""
        total = collections.Counter()
        for rep in self.replicas:
            total.update(rep.page_report())
        return dict(total)

    def check_invariants(self) -> None:
        """Cross-replica conservation, checked from INDEPENDENT sources
        (``global_page_report``/``global_counters`` are definitionally
        per-replica sums, so comparing them against a re-computed sum
        would be a tautology):

        * every replica's vmem/pool is internally consistent and its
          frame arithmetic closes (used + free == configured frames);
        * request conservation: the router-side ``submitted`` counter
          equals the number of request OBJECTS tracked across the global
          queue and every replica's queued/running/swapped/done;
        * placement accounting across planes: the router-incremented
          ``placements``/``placements_replica{i}`` counters agree with
          each other AND with the replica-side ``router_placements``
          counters (incremented on the replica's own counter object);
        * completion accounting: replica-summed ``completed`` /
          ``failed_unreachable`` counters equal the done/failed statuses
          carried by the merged ``done`` requests themselves.
        """
        for rep in self.replicas:
            rep.scheduler.vmem.check_invariants()
            pool = rep.scheduler.vmem.pool
            if pool.num_used + pool.num_free != pool.num_pages:
                raise AssertionError(
                    f"replica {rep.replica_id} frame arithmetic broken: "
                    f"{pool.num_used} used + {pool.num_free} free != "
                    f"{pool.num_pages} frames"
                )
        tracked = len(self.queue) + sum(
            rep.scheduler.state.num_tracked for rep in self.replicas
        )
        submitted = self.counters.get("submitted")
        if tracked != submitted:
            raise AssertionError(
                f"request conservation broken: {submitted} submitted but "
                f"{tracked} tracked across queue + replicas"
            )
        placed = sum(
            self.counters.get(f"placements_replica{rep.replica_id}")
            for rep in self.replicas
        )
        replica_side = sum(
            rep.scheduler.counters.get("router_placements")
            for rep in self.replicas
        )
        if not (placed == replica_side == self.counters.get("placements")):
            raise AssertionError(
                "placement accounting broken: per-replica counters "
                f"{placed}, replica-side records {replica_side}, global "
                f"{self.counters.get('placements')} disagree"
            )
        done = self.done
        by_status = collections.Counter(r.status for r in done.values())
        counted = PerfCounters.merged(
            rep.scheduler.counters for rep in self.replicas
        )
        if counted["completed"] != by_status["done"] or \
                counted["failed_unreachable"] != by_status["failed"]:
            raise AssertionError(
                f"completion accounting broken: counters say "
                f"{counted['completed']} done / "
                f"{counted['failed_unreachable']} failed, request objects "
                f"say {by_status['done']} / {by_status['failed']}"
            )

    def stats(self) -> dict[str, Any]:
        return {
            "router": self.counters.report(),
            "global_counters": dict(self.global_counters()),
            "global_pages": self.global_page_report(),
            "replicas": {
                rep.replica_id: {
                    "counters": dict(rep.scheduler.counters.counters),
                    "pages": rep.page_report(),
                    "step_i": rep.scheduler.step_i,
                }
                for rep in self.replicas
            },
        }
