"""Serving: continuous batching over paged virtual memory (the "OS").

Split per the AraOS architecture, one layer per plane:

  **Router -> Scheduler(ReplicaState) -> DataPlane.**
  :class:`ReplicaRouter` (:mod:`repro.serve.router`) is the multi-replica
  control plane: it owns the global admission queue and places requests
  over N replicas (fork-affinity keeps COW forks on the prefix-holding
  replica; least-loaded-pages / round-robin rank the rest).  Each replica
  is a :class:`Scheduler` — the host-side CVA6/OS plane (policy, no
  device arrays), with every piece of per-replica mutable state factored
  into :class:`ReplicaState` — driving a :class:`DataPlane`: in
  production the device-resident :class:`Executor` (optionally sharded
  over a ('kv','hd') mesh), in tests a host-only fake.  Replicas share no
  mutable state, and the single-replica :class:`Engine` (the thin
  Scheduler+Executor facade) is exactly the N=1 instance of the layering:
  a one-replica router with the default unbounded backlog is
  call-for-call, token-for-token the plain engine — the equivalence the
  router test suite gates on for N in {1, 2, 4}.

  **Radix prefix layer.**  Each Scheduler carries a
  :class:`PrefixCache` (:mod:`repro.serve.prefix_cache`) — a
  page-granularity radix trie over the token content of resident mapped
  runs.  Admissions whose prompts share leading whole pages with a
  registered run COW-map those pages automatically (no fork API) and
  prefill only the divergent chunk; the router generalizes fork affinity
  into an additive longest-matching-prefix score when ranking replicas.

:class:`ReferenceEngine` is the frozen pre-split seed implementation kept
for equivalence testing and before/after benchmarks.
"""
from repro.serve.engine import Engine
from repro.serve.executor import Executor
from repro.serve.prefix_cache import PrefixCache
from repro.serve.reference import ReferenceEngine
from repro.serve.router import Replica, ReplicaRouter
from repro.serve.scheduler import (
    DataPlane,
    DecodePlan,
    HostOnlyPlane,
    ReplicaState,
    Request,
    RestoreFailure,
    Scheduler,
    ServeConfig,
)

__all__ = [
    "DataPlane",
    "DecodePlan",
    "Engine",
    "Executor",
    "HostOnlyPlane",
    "PrefixCache",
    "ReferenceEngine",
    "Replica",
    "ReplicaRouter",
    "ReplicaState",
    "Request",
    "RestoreFailure",
    "Scheduler",
    "ServeConfig",
]
