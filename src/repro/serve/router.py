"""Multi-replica serving control plane: one admission front-end, N planes.

Ara2 (Perotti et al., 2023) scales the Ara lane datapath to multi-core by
replicating the compute fabric behind one shared front-end; AraOS's claim
is that the shared translation/OS structure stays off the datapath's
critical path while it does.  This module is the serving restatement of
that shape: a :class:`ReplicaRouter` owns the **global admission queue**
and places requests across N model replicas — each a
:class:`~repro.serve.scheduler.Scheduler` (per-replica policy, its own
:class:`~repro.serve.scheduler.ReplicaState`) driving its own
:class:`~repro.serve.scheduler.DataPlane` (a device
:class:`~repro.serve.executor.Executor`, optionally mesh-sharded, or a
test fake).  Replicas share **no mutable state**: page pools, KV pools,
swap records and step clocks are all per-replica, so the router is pure
placement policy on top of N independent single-replica engines — and the
single-replica engine is exactly the ``N=1`` instance of this layering.

Placement policies (``policy=``):

``least_loaded``
    Fewest committed-plus-backlogged pages (frames in use + the page
    demand of requests already queued on the replica); ties break toward
    the lowest replica id.  The default.
``round_robin``
    Cyclic over replicas, skipping ineligible ones.

**Fork affinity** is not a policy but a correctness constraint layered on
both: a ``share_prefix`` request COW-forks the resident prefix's page
table, and those shared pages live in ONE replica's pool — so forks are
only ever placed on a replica holding the prefix (the "parent").  When
the affinity constraint overrides the base policy's unconstrained choice,
the router counts a ``migrations_declined`` (the fork was *not* migrated
to the otherwise-best replica, keeping prefix sharing instead).

**Prefix-aware ranking** generalizes fork affinity into an additive
score: for plain requests each candidate replica is probed
(``Scheduler.probe_prefix``) for the longest radix-cached resident
prefix of the request's prompt.  Under ``least_loaded`` the matched page
count is subtracted from the replica's load (each matched page is one
frame the replica will NOT allocate — plus the skipped prefill compute);
under ``round_robin`` the cycle is restricted to the replicas with the
maximal match whenever any replica matches at all.  It is a *score*, not
a constraint: a heavily loaded prefix holder still loses to an idle cold
replica once the load gap exceeds the matched pages.  Placements where
the prefix score changed the base policy's choice are counted as
``prefix_routed``.

Counters (router-global, in ``router.counters``): ``submitted``,
``placements``, ``placements_replica{i}``, ``migrations_declined``,
``prefix_routed``, ``cross_replica_queue_waits`` (request-steps spent in
the global queue while every eligible replica was at its backlog bound).
Each replica's scheduler/executor counters stay per-replica;
``global_counters()`` merges them, and the test-suite invariant is that
every merged total equals the sum of the per-replica values (no event is
double- or un-counted by adding replicas).
"""

from __future__ import annotations

import collections
import dataclasses
import time
from collections import deque
from typing import Any

from repro.core import PerfCounters
from repro.serve.api import ServeRequest, ServeResult
from repro.serve.scheduler import DataPlane, Request, Scheduler


@dataclasses.dataclass(eq=False)     # identity semantics: list.index / in
class Replica:
    """One model replica: a policy plane bound to its data plane.

    ``scheduler.counters`` must be the SAME object the plane increments
    (the :class:`~repro.serve.engine.Engine` wiring), so per-replica
    accounting covers both planes without double counting.
    """

    replica_id: int
    scheduler: Scheduler
    plane: DataPlane

    @classmethod
    def from_engine(cls, engine: Any, replica_id: int) -> "Replica":
        """Bind a single-replica :class:`~repro.serve.engine.Engine` as
        one replica of a router (its Scheduler/Executor pair is already
        wired and counter-shared)."""
        engine.scheduler.state.replica_id = replica_id
        return cls(replica_id=replica_id, scheduler=engine.scheduler,
                   plane=engine.executor)

    @property
    def has_prefix(self) -> bool:
        """True when this replica holds a resident shared prefix a fork
        could COW from."""
        s = self.scheduler
        return s.prefix_len > 0 and s.vmem.has_seq(s.PREFIX_ID)

    def load_pages(self) -> int:
        """Placement load metric: frames committed in the pool plus the
        page demand of requests already placed but still queued here.
        The backlog term is what spreads a burst submitted before any
        step runs — committed frames alone are all-zero then."""
        s = self.scheduler
        return s.vmem.pool.num_used + sum(
            s.required_pages(r) for r in s.queue
        )

    def page_report(self) -> dict[str, int]:
        pool = self.scheduler.vmem.pool
        return {"frames": pool.num_pages, "free": pool.num_free,
                "used": pool.num_used, "faults": pool.fault_count}


class ReplicaRouter:
    """Places requests from a global admission queue over N replicas and
    drives every busy replica one :meth:`Scheduler.step_plane` per router
    step.  With one replica and the default unbounded backlog this is
    call-for-call the single-replica ``Engine`` loop."""

    POLICIES = ("least_loaded", "round_robin")

    def __init__(self, replicas: list[Replica],
                 policy: str = "least_loaded",
                 counters: PerfCounters | None = None,
                 max_backlog: int | None = None):
        """``max_backlog``: per-replica queued-request bound; placement
        defers (requests wait in the global queue, counted as
        ``cross_replica_queue_waits``) while every eligible replica is at
        the bound AND at least one replica is still busy.  ``None``
        (default) places immediately — required for exact N=1
        equivalence with the plain engine."""
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        ids = [rep.replica_id for rep in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids: {ids}")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {self.POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self.counters = counters or PerfCounters()
        self.max_backlog = max_backlog
        self.queue: deque[Request] = deque()   # global admission queue
        self.step_i = 0                        # router engine-steps
        self._rr_next = 0
        self._next_req_id = 0

    # ------------------------------------------------------------------
    # queue API
    # ------------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(
            rep.scheduler.has_work for rep in self.replicas
        )

    @property
    def done(self) -> dict[int, Request]:
        """Merged done map (per-replica completion order preserved within
        each replica; cross-replica order is replica-major)."""
        merged: dict[int, Request] = {}
        for rep in self.replicas:
            merged.update(rep.scheduler.done)
        return merged

    def submit(self, req: ServeRequest | Request) -> int:
        """Enqueue a :class:`~repro.serve.api.ServeRequest` (the supported
        client type; internal ``Request`` accepted for one PR behind a
        DeprecationWarning).  Returns the request id."""
        from repro.serve.engine import _coerce
        internal = _coerce(
            req, self._alloc_req_id, self.replicas[0].scheduler.cfg
        )
        self._next_req_id = max(self._next_req_id, internal.req_id + 1)
        # TTFT clock starts at ROUTER entry: global-queue wait (backlog
        # bound) must show up in the SLO numbers, so the stamp cannot wait
        # for replica placement (Scheduler.submit only stamps if unset)
        if internal.t_enqueue == 0.0:
            internal.t_enqueue = time.perf_counter()
        self.counters.inc("submitted")
        self.queue.append(internal)
        self._place_pending()
        return internal.req_id

    def _alloc_req_id(self) -> int:
        rid = self._next_req_id
        self._next_req_id += 1
        return rid

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------

    def _eligible(self, req: Request) -> tuple[list[Replica], bool]:
        """Replicas that can legally host ``req``; second element flags
        the fork-affinity constraint (COW pages cannot cross pools)."""
        if req.share_prefix:
            elig = [rep for rep in self.replicas if rep.has_prefix]
            if not elig:
                raise ValueError(
                    f"request {req.req_id} wants share_prefix but no "
                    "replica holds a resident prefix (preload one first)"
                )
            return elig, len(elig) < len(self.replicas)
        return list(self.replicas), False

    def _match_pages(self, rep: Replica, req: Request | None) -> int:
        """Whole pages of ``req``'s prompt resident in ``rep``'s radix
        cache (0 with no request / no cache / no match — every pre-prefix
        ranking reduces to the base policy then)."""
        if req is None:
            return 0
        matched, _ = rep.scheduler.probe_prefix(req)
        return matched // rep.scheduler.cfg.page_size

    def _rank(self, candidates: list[Replica], advance_rr: bool = False,
              req: Request | None = None) -> Replica:
        """Policy choice among ``candidates`` (never empty): the base
        policy plus the additive prefix score for ``req`` (see the module
        docstring).  ``req=None`` ranks prefix-blind — used to attribute
        ``prefix_routed``/``migrations_declined`` to the constraint that
        actually changed the outcome."""
        if self.policy == "round_robin":
            pool = candidates
            best = max((self._match_pages(rep, req) for rep in candidates),
                       default=0)
            if best > 0:
                pool = [rep for rep in candidates
                        if self._match_pages(rep, req) == best]
            n = len(self.replicas)
            for k in range(n):
                cand = self.replicas[(self._rr_next + k) % n]
                if cand in pool:
                    if advance_rr:
                        self._rr_next = (
                            self.replicas.index(cand) + 1
                        ) % n
                    return cand
            raise AssertionError("unreachable: candidates is non-empty")
        return min(candidates,
                   key=lambda rep: (
                       rep.load_pages() - self._match_pages(rep, req),
                       rep.replica_id,
                   ))

    def _backlog_open(self, reps: list[Replica]) -> list[Replica]:
        if self.max_backlog is None:
            return list(reps)
        return [rep for rep in reps
                if len(rep.scheduler.queue) < self.max_backlog]

    def _place_one(self, req: Request) -> Replica | None:
        """Choose a replica for ``req`` and commit it there, or return
        ``None`` to keep it waiting in the global queue (backlog bound)."""
        elig, constrained = self._eligible(req)
        open_elig = self._backlog_open(elig)
        if not open_elig:
            if any(rep.scheduler.has_work for rep in self.replicas):
                return None              # wait; retried next router step
            open_elig = elig             # idle fleet: never park forever
        if constrained:
            # what the base policy would do with fork affinity ignored,
            # under the SAME backlog conditions (else a backlog-diverted
            # placement would masquerade as a declined migration).
            # Read-only rank: the round-robin pointer does not advance.
            # Forks rank prefix-blind (affinity already restricted the
            # pool to prefix holders — the score would be a no-op).
            free_pool = self._backlog_open(self.replicas) or open_elig
            free_choice = self._rank(free_pool)
            choice = self._rank(open_elig, advance_rr=True)
            if free_choice.replica_id != choice.replica_id:
                self.counters.inc("migrations_declined")
        else:
            # read-only prefix-blind rank first: a placement the prefix
            # score diverted from the base choice counts as prefix_routed
            blind_choice = self._rank(open_elig)
            choice = self._rank(open_elig, advance_rr=True, req=req)
            if blind_choice.replica_id != choice.replica_id:
                self.counters.inc("prefix_routed")
        choice.scheduler.submit(req)     # stamps arrival in replica time
        choice.scheduler.counters.inc("router_placements")
        self.counters.inc("placements")
        self.counters.inc(f"placements_replica{choice.replica_id}")
        self.counters.snapshot("place", (req.req_id, choice.replica_id))
        return choice

    def _place_pending(self) -> None:
        while self.queue:
            if self._place_one(self.queue[0]) is None:
                break
            self.queue.popleft()

    # ------------------------------------------------------------------
    # drive
    # ------------------------------------------------------------------

    def step(self) -> None:
        self.step_i += 1
        self._place_pending()
        if self.queue:
            # request-steps spent waiting in the global queue (every
            # eligible replica at its backlog bound)
            self.counters.inc("cross_replica_queue_waits", len(self.queue))
        for rep in self.replicas:
            if rep.scheduler.has_work:
                rep.scheduler.step_plane()
        # retirements may have opened slots/frames for deferred placements
        self._place_pending()

    def run(self, max_steps: int = 10_000) -> dict[int, Request]:
        """Drive until every placed and pending request completes, or the
        slowest still-busy replica's token-step clock reaches
        ``max_steps`` (the same per-replica budget semantics as
        ``Engine.run``: fused horizons advance a replica's clock in
        token-steps)."""
        while self.has_work and self._clock() < max_steps:
            self.step()
        return self.done

    def drain(self, max_steps: int = 10_000) -> dict[int, ServeResult]:
        """Drive to completion, flush every replica's async stream sink
        (re-raising the first callback exception), and return typed
        :class:`~repro.serve.api.ServeResult` records by request id."""
        self.run(max_steps)
        for rep in self.replicas:
            stream = rep.scheduler.stream
            if stream is not None:
                stream.drain()
        return {
            rid: ServeResult.from_request(r)
            for rid, r in self.done.items()
        }

    def _clock(self) -> int:
        active = [rep.scheduler.step_i for rep in self.replicas
                  if rep.scheduler.has_work]
        if active:
            return min(active)
        return min(rep.scheduler.step_i for rep in self.replicas)

    # ------------------------------------------------------------------
    # accounting / invariants
    # ------------------------------------------------------------------

    def global_counters(self) -> collections.Counter:
        """Router counters + the sum of every replica's counters.  The
        cross-replica invariant the test suite asserts: each merged total
        equals the sum of the per-replica values."""
        merged = PerfCounters.merged(
            rep.scheduler.counters for rep in self.replicas
        )
        merged.update(self.counters.counters)
        return merged

    def global_page_report(self) -> dict[str, int]:
        """Fleet-wide page accounting — by construction the element-wise
        sum of the per-replica reports (asserted in
        :meth:`check_invariants`)."""
        total = collections.Counter()
        for rep in self.replicas:
            total.update(rep.page_report())
        return dict(total)

    def check_invariants(self) -> None:
        """Cross-replica conservation, checked from INDEPENDENT sources
        (``global_page_report``/``global_counters`` are definitionally
        per-replica sums, so comparing them against a re-computed sum
        would be a tautology):

        * every replica's vmem/pool is internally consistent and its
          frame arithmetic closes (used + free == configured frames);
        * request conservation: the router-side ``submitted`` counter
          equals the number of request OBJECTS tracked across the global
          queue and every replica's queued/running/swapped/done;
        * placement accounting across planes: the router-incremented
          ``placements``/``placements_replica{i}`` counters agree with
          each other AND with the replica-side ``router_placements``
          counters (incremented on the replica's own counter object);
        * completion accounting: replica-summed ``completed`` /
          ``failed_unreachable`` counters equal the done/failed statuses
          carried by the merged ``done`` requests themselves.
        """
        for rep in self.replicas:
            rep.scheduler.vmem.check_invariants()
            pool = rep.scheduler.vmem.pool
            if pool.num_used + pool.num_free != pool.num_pages:
                raise AssertionError(
                    f"replica {rep.replica_id} frame arithmetic broken: "
                    f"{pool.num_used} used + {pool.num_free} free != "
                    f"{pool.num_pages} frames"
                )
        tracked = len(self.queue) + sum(
            rep.scheduler.state.num_tracked for rep in self.replicas
        )
        submitted = self.counters.get("submitted")
        if tracked != submitted:
            raise AssertionError(
                f"request conservation broken: {submitted} submitted but "
                f"{tracked} tracked across queue + replicas"
            )
        placed = sum(
            self.counters.get(f"placements_replica{rep.replica_id}")
            for rep in self.replicas
        )
        replica_side = sum(
            rep.scheduler.counters.get("router_placements")
            for rep in self.replicas
        )
        if not (placed == replica_side == self.counters.get("placements")):
            raise AssertionError(
                "placement accounting broken: per-replica counters "
                f"{placed}, replica-side records {replica_side}, global "
                f"{self.counters.get('placements')} disagree"
            )
        done = self.done
        by_status = collections.Counter(r.status for r in done.values())
        counted = PerfCounters.merged(
            rep.scheduler.counters for rep in self.replicas
        )
        if counted["completed"] != by_status["done"] or \
                counted["failed_unreachable"] != by_status["failed"]:
            raise AssertionError(
                f"completion accounting broken: counters say "
                f"{counted['completed']} done / "
                f"{counted['failed_unreachable']} failed, request objects "
                f"say {by_status['done']} / {by_status['failed']}"
            )

    def stats(self) -> dict[str, Any]:
        return {
            "router": self.counters.report(),
            "global_counters": dict(self.global_counters()),
            "global_pages": self.global_page_report(),
            "replicas": {
                rep.replica_id: {
                    "counters": dict(rep.scheduler.counters.counters),
                    "pages": rep.page_report(),
                    "step_i": rep.scheduler.step_i,
                }
                for rep in self.replicas
            },
        }
