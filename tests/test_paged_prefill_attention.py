"""Differential harness for the chunked-prefill paged-attention kernel.

The Pallas kernel (``kernels/paged_prefill_attention.py``) streams KV pages
per query block through the page table; the jnp oracle
(``ref.paged_prefill_attention_ref``) gathers the whole logical prefix.
Both must agree to fp32 tolerance across the full grid of

    page size x chunk length x start offset

including a start offset mid-page, a chunk spanning a page boundary,
chunk=1 (the decode-like degenerate), and a full-prefix chunk (start=0),
plus property-based shape/offset cases and a serving-shaped end-to-end
check against a ``VirtualMemory``-built page table.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro.core import VirtualMemory, VMemConfig
from repro.kernels import ops, ref
from repro.kernels.paged_prefill_attention import pages_touched

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(7)


def make_case(page_size, starts, chunks, *, hkv=2, g=2, d=16, bq=4,
              extra_frames=3, dtype=jnp.float32, seed=0):
    """Random pools + a page table mapping ``pages_for(start + chunk)``
    distinct frames per row (frames deliberately shuffled so logical and
    physical order differ — the translation is load-bearing)."""
    starts = np.asarray(starts, np.int32)
    chunks = np.asarray(chunks, np.int32)
    b = len(starts)
    totals = starts + chunks
    max_pages = int(max(-(-int(t) // page_size) for t in totals))
    n_frames = b * max_pages + extra_frames
    key = jax.random.fold_in(KEY, seed)
    ks = jax.random.split(key, 3)
    k_pool = jax.random.normal(
        ks[0], (n_frames, page_size, hkv, d), jnp.float32).astype(dtype)
    v_pool = jax.random.normal(
        ks[1], (n_frames, page_size, hkv, d), jnp.float32).astype(dtype)
    rng = np.random.default_rng(seed)
    frames = rng.permutation(n_frames)
    table = np.full((b, max_pages), -1, np.int32)
    fi = 0
    for row in range(b):
        need = -(-int(totals[row]) // page_size)
        table[row, :need] = frames[fi: fi + need]
        fi += need
    s = int(chunks.max())
    q = jax.random.normal(
        ks[2], (b, s, hkv, g, d), jnp.float32).astype(dtype)
    return q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(starts), bq


def assert_matches(q, k_pool, v_pool, table, starts, bq, chunks,
                   rtol=2e-5, atol=2e-5):
    out_k = ops.paged_prefill_attention(
        q, k_pool, v_pool, table, starts,
        page_size=k_pool.shape[1], use_kernel=True, bq=bq,
    )
    out_r = ops.paged_prefill_attention(
        q, k_pool, v_pool, table, starts,
        page_size=k_pool.shape[1], use_kernel=False,
    )
    for row, chunk in enumerate(np.asarray(chunks)):
        np.testing.assert_allclose(
            np.asarray(out_k)[row, :chunk], np.asarray(out_r)[row, :chunk],
            rtol=rtol, atol=atol,
            err_msg=f"row {row} (chunk {chunk}) diverged",
        )


class TestDifferentialGrid:
    """The core page-size x chunk x offset sweep (fast: runs in check.sh)."""

    @pytest.mark.parametrize("page_size", [4, 8, 16])
    @pytest.mark.parametrize("chunk", [1, 3, 8, 17])
    @pytest.mark.parametrize("start", [0, 2, 5, 16])
    def test_grid(self, page_size, chunk, start):
        # `start` mid-page (2, 5), page-aligned (0, 16); `chunk` spanning
        # a page boundary (3 @ start 2, 17), chunk=1, full-prefix (start=0)
        q, kp, vp, tab, starts, bq = make_case(
            page_size, [start], [chunk], seed=page_size * 100 + chunk)
        assert_matches(q, kp, vp, tab, starts, bq, [chunk])

    def test_chunk_spans_page_boundary_mid_page_start(self):
        # offset 5 in an 8-page: tokens 5..14 straddle pages 0..1
        q, kp, vp, tab, starts, bq = make_case(8, [5], [10], seed=1)
        assert_matches(q, kp, vp, tab, starts, bq, [10])

    def test_full_prefix_equals_causal_flash(self):
        # start=0, one page-aligned chunk: must equal plain causal
        # attention over the chunk (paged indirection is the identity)
        page = 4
        q, kp, vp, tab, starts, bq = make_case(
            page, [0], [16], hkv=2, g=2, d=16, seed=2)
        out = ops.paged_prefill_attention(
            q, kp, vp, tab, starts, page_size=page, use_kernel=True, bq=bq)
        b, s, hkv, g, d = q.shape
        frames = np.asarray(tab[0, : s // page])
        k_log = np.asarray(kp)[frames].reshape(1, s, hkv, d)
        v_log = np.asarray(vp)[frames].reshape(1, s, hkv, d)
        expect = ref.flash_attention_ref(
            jnp.asarray(q[0]).transpose(1, 2, 0, 3).reshape(1, hkv * g, s, d),
            jnp.asarray(k_log).transpose(0, 2, 1, 3),
            jnp.asarray(v_log).transpose(0, 2, 1, 3),
            causal=True,
        )
        expect = np.asarray(expect).reshape(hkv, g, s, d).transpose(2, 0, 1, 3)
        np.testing.assert_allclose(
            np.asarray(out)[0], expect, rtol=2e-5, atol=2e-5)


class TestBatchAndBlocking:
    def test_batched_rows_with_distinct_offsets(self):
        # same-step forked admissions: one call, per-row starts/chunks
        chunks = [6, 1, 11]
        q, kp, vp, tab, starts, bq = make_case(
            4, [5, 0, 9], chunks, hkv=2, g=3, d=8, seed=3)
        assert_matches(q, kp, vp, tab, starts, bq, chunks)

    @pytest.mark.parametrize("bq", [1, 2, 5, 16, 64])
    def test_query_block_size_sweep(self, bq):
        # bq not dividing the chunk, bq = 1, and bq > chunk all reduce
        # to the same math (padded rows sliced off)
        q, kp, vp, tab, starts, _ = make_case(8, [11], [13], seed=4)
        assert_matches(q, kp, vp, tab, starts, bq, [13])

    def test_gqa_group_sizes(self):
        for g, hkv in [(1, 3), (4, 1), (2, 2)]:
            q, kp, vp, tab, starts, bq = make_case(
                4, [3, 7], [5, 5], hkv=hkv, g=g, d=8, seed=10 + g)
            assert_matches(q, kp, vp, tab, starts, bq, [5, 5])

    def test_bf16_inputs(self):
        q, kp, vp, tab, starts, bq = make_case(
            8, [6], [9], dtype=jnp.bfloat16, seed=5)
        assert_matches(q, kp, vp, tab, starts, bq, [9],
                       rtol=2e-2, atol=2e-2)


class TestPagesTouched:
    """The analytical bytes model must bound-and-beat the gather path."""

    def test_streams_fewer_pages_than_full_gather(self):
        page, start, chunk, max_pages = 4, 6, 8, 32
        nqb = -(-chunk // 4)
        touched = pages_touched(start, chunk, max_pages, page_size=page, bq=4)
        assert touched < nqb * max_pages        # oracle: max_pages per block
        # every block sees at least the pages up to `start`
        assert touched >= nqb * (start // page + 1)

    def test_never_exceeds_table(self):
        assert pages_touched(10_000, 64, 8, page_size=4, bq=8) == 8 * 8


class TestPropertyCases:
    @settings(max_examples=15, deadline=None)
    @given(
        page_size=st.sampled_from([2, 4, 8]),
        start=st.integers(min_value=0, max_value=37),
        chunk=st.integers(min_value=1, max_value=19),
        g=st.sampled_from([1, 2]),
        bq=st.sampled_from([2, 4, 8]),
    )
    def test_random_shapes_and_offsets(self, page_size, start, chunk, g, bq):
        q, kp, vp, tab, starts, _ = make_case(
            page_size, [start], [chunk], hkv=1, g=g, d=8,
            seed=start * 97 + chunk * 13 + page_size)
        assert_matches(q, kp, vp, tab, starts, bq, [chunk])


class TestModelWiring:
    def test_prefill_continue_kernel_path_matches_jnp_path(self):
        """The kernel wired inside the jitted ``prefill_continue`` layer
        scan (with the paged-copy kernels alongside) must produce the same
        logits and KV pools as the gathered-pages jnp path."""
        from repro.configs import get_config
        from repro.models import build_model

        cfg = get_config("qwen2-7b", reduced=True)
        m_ref = build_model(cfg, remat=False, use_kernels=False)
        m_ker = build_model(cfg, remat=False, use_kernels=True)
        params = m_ref.init(jax.random.PRNGKey(1))
        page, n_pages, max_pages = 4, 24, 8
        rng = np.random.default_rng(5)
        b = 2
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, 12)), jnp.int32)
        plens = jnp.asarray([12, 9], jnp.int32)
        state0 = m_ref.init_kv_state(b, n_pages, page, max_pages)
        vmem = VirtualMemory(VMemConfig(
            page_size=page, num_pages=n_pages - 1,
            max_pages_per_seq=max_pages, max_seqs=b))
        vmem.map_seq(0, 12)
        vmem.map_seq(1, 9)
        vmem.append_tokens(0, 5)
        vmem.append_tokens(1, 5)
        table = vmem.device_page_table()
        state0 = state0._replace(page_table=table)
        _, state_r = m_ref.prefill(params, prompts, plens, state0)
        _, state_k = m_ker.prefill(params, prompts, plens, state0)
        chunk = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, 5)), jnp.int32)
        clens = jnp.asarray([5, 3], jnp.int32)
        log_r, out_r = m_ref.prefill_continue(params, chunk, plens, clens,
                                              state_r)
        log_k, out_k = m_ker.prefill_continue(params, chunk, plens, clens,
                                              state_k)
        np.testing.assert_allclose(np.asarray(log_k), np.asarray(log_r),
                                   rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(
            np.asarray(out_k.k_pools), np.asarray(out_r.k_pools),
            rtol=5e-4, atol=5e-4)
        np.testing.assert_array_equal(
            np.asarray(out_k.seq_lens), np.asarray(out_r.seq_lens))


class TestVirtualMemoryEndToEnd:
    def test_kernel_reads_through_vmem_built_table(self):
        """Serving-shaped: map a prefix, fork it, append a chunk through
        VirtualMemory, write KV through paged_copy_at, then attend — the
        kernel must agree with the oracle on the table vmem actually built
        (shared whole pages + copied tail + freshly faulted pages)."""
        page, hkv, d = 4, 2, 8
        vmem = VirtualMemory(VMemConfig(
            page_size=page, num_pages=24, max_pages_per_seq=8, max_seqs=3))
        prefix_len, chunk = 10, 7
        vmem.map_seq(-1, prefix_len)
        vmem.fork_seq(-1, 0, prefix_len)
        vmem.append_tokens(0, chunk)
        table = vmem.device_page_table()          # [3, 8]
        table = table[np.asarray([vmem.seq(0).slot])]
        n_frames = vmem.pool.num_pages
        ks = jax.random.split(KEY, 4)
        k_pool = jax.random.normal(ks[0], (n_frames, page, hkv, d))
        v_pool = jax.random.normal(ks[1], (n_frames, page, hkv, d))
        # write the chunk's own KV through the table at the start offset
        knew = jax.random.normal(ks[2], (1, chunk, hkv * d))
        starts = jnp.asarray([prefix_len], jnp.int32)
        lens = jnp.asarray([chunk], jnp.int32)
        k_pool = ref.paged_copy_at_ref(
            knew, k_pool.reshape(n_frames, page, hkv * d), table, starts,
            lens, page_size=page).reshape(n_frames, page, hkv, d)
        q = jax.random.normal(ks[3], (1, chunk, hkv, 2, d))
        assert_matches(q, k_pool, v_pool, table, starts, 4, [chunk])
