"""Benchmark harness: one section per paper table/figure + the roofline.

Prints a ``name,us_per_call,derived`` CSV block at the end (harness
contract).  Sections (select a subset with ``--only``):
  fig2     — matmul VM overhead vs DTLB size x problem size (bench_tlb_sweep)
  table1   — RiVEC suite scalar vs vector speedups           (bench_rivec)
  s31      — scheduler ticks + context switches              (bench_context_switch)
  serve    — seed vs Scheduler/Executor serving split        (bench_serve_throughput)
  c2       — burst vs element translation (+ coalescing)     (bench_translation)
  prefill  — gathered vs streamed continuation prefill       (bench_prefill_continue)
  pagesize — page-size sweep (TPU dual of the TLB sweep)     (bench_page_size)
  roof     — dry-run roofline table                          (roofline)

``--only prefill`` additionally acts as a CI gate: it exits nonzero if the
chunked-prefill kernel path gathers at least as many bytes as the
gathered-pages reference path.
"""

from __future__ import annotations

import argparse
import sys
import time


def section(title: str):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def _fig2():
    from benchmarks import bench_tlb_sweep
    return bench_tlb_sweep.main()


def _table1():
    from benchmarks import bench_rivec
    return bench_rivec.main()


def _s31():
    from benchmarks import bench_context_switch
    return bench_context_switch.main()


def _serve():
    from benchmarks import bench_serve_throughput
    return bench_serve_throughput.main()


def _c2():
    from benchmarks import bench_translation
    return bench_translation.main()


def _prefill(gate: bool = False):
    from benchmarks import bench_prefill_continue
    csv, metrics = bench_prefill_continue.run()
    if metrics["kernel_bytes"] >= metrics["ref_bytes"]:
        print(f"FAIL: kernel path gathered {metrics['kernel_bytes']} B, "
              f"reference gathered {metrics['ref_bytes']} B — the streamed "
              "path must touch strictly fewer bytes")
        if gate:              # --only prefill: act as a CI gate
            sys.exit(1)
    return csv


def _pagesize():
    from benchmarks import bench_page_size
    return bench_page_size.main()


def _roof():
    from benchmarks import roofline
    return roofline.main()


SECTIONS: list[tuple[str, str, object]] = [
    ("fig2", "Fig. 2(b,c,d): matmul VM overhead vs DTLB size", _fig2),
    ("table1", "Table 1: RiVEC suite (S / V / Vu)", _table1),
    ("s31", "§3.1: scheduler interrupts + context switches", _s31),
    ("serve", "Serving split: seed vs Scheduler/Executor (decode + switches)",
     _serve),
    ("c2", "C2: translation counts (burst / element / coalesced)", _c2),
    ("prefill",
     "Chunked prefill: gathered-pages oracle vs page-streaming kernel",
     _prefill),
    ("pagesize",
     "Beyond-paper: page-size sweep (the TPU dual of the TLB sweep)",
     _pagesize),
    ("roof", "Roofline (from dry-run artifacts)", _roof),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=[k for k, _, _ in SECTIONS],
                    action="append", default=None,
                    help="run only the named section(s); repeatable")
    args = ap.parse_args(argv)
    t0 = time.time()
    csv: list[str] = ["name,us_per_call,derived"]
    for key, title, fn in SECTIONS:
        if args.only is not None and key not in args.only:
            continue
        section(title)
        if key == "prefill":
            # the bytes gate aborts only when explicitly selected; a full
            # run must still emit the complete CSV block
            csv += fn(gate=args.only is not None)
        else:
            csv += fn()
    section(f"CSV (total {time.time() - t0:.0f}s)")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
