"""RWKV-6 (Finch) time-mix recurrence kernel.

Per head of size N, with receptance r_t, key k_t, data-dependent decay w_t
(all [N]), value v_t [N] and bonus u [N]:

    o_t = r_t^T · (diag(u) · k_t v_t^T + S_{t-1})
    S_t = diag(w_t) · S_{t-1} + k_t v_t^T

The [N, N] state S is the vector-register working set: it lives in VMEM
scratch and is carried across the sequential time-block grid dimension —
the recurrence never round-trips to HBM.  Grid ``(B*H, T/bt)``; inside a
block a ``fori_loop`` steps through time (each step is rank-1 update +
matvec, VPU-friendly at N=64).

This is the sub-quadratic serving path for the rwkv6-7b architecture: decode
state is O(N^2) per head regardless of context length (the ``long_500k``
shape runs through it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret
from repro.kernels import common


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 o_ref, s_out_ref, s_ref, *, bt: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_ref[...] = s0_ref[0].astype(s_ref.dtype)

    u = u_ref[0]  # [N]

    def step(t, _):
        r = r_ref[0, t]        # [N]
        k = k_ref[0, t]
        v = v_ref[0, t]
        w = w_ref[0, t]
        s = s_ref[...]         # [N, N]
        kv = k[:, None] * v[None, :]              # rank-1 update [N, N]
        o = (r[:, None] * (u[:, None] * kv + s)).sum(axis=0)  # [N]
        o_ref[0, t] = o.astype(o_ref.dtype)
        s_ref[...] = w[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _store_state():
        s_out_ref[0] = s_ref[...].astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv6(
    r: jax.Array,   # [BH, T, N]
    k: jax.Array,   # [BH, T, N]
    v: jax.Array,   # [BH, T, N]
    w: jax.Array,   # [BH, T, N]  decay in (0, 1), data-dependent
    u: jax.Array,   # [BH, N]     per-head bonus
    initial_state: jax.Array | None = None,  # [BH, N, N] f32
    *,
    bt: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """RWKV-6 recurrence. Returns (o [BH, T, N], final_state [BH, N, N]).

    Supplying ``initial_state`` enables chunked prefill and stateful decode:
    the recurrence continues exactly where the previous chunk stopped.
    """
    if interpret is None:
        interpret = should_interpret()
    bh, t, n = r.shape
    assert t % bt == 0, (t, bt)
    if initial_state is None:
        initial_state = jnp.zeros((bh, n, n), jnp.float32)
    o, s_fin = pl.pallas_call(
        functools.partial(_wkv6_kernel, bt=bt),
        out_shape=(
            jax.ShapeDtypeStruct((bh, t, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ),
        grid=(bh, t // bt),
        in_specs=[
            pl.BlockSpec((1, bt, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n), lambda b, i: (b, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, bt, n), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, n, n), lambda b, i: (b, 0, 0)),
        ),
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u, initial_state)
    return o, s_fin
