"""Device-resident serving executor — the Ara2 data plane of the split.

Everything that touches a device array lives here: the paged KV pools, a
*persistent device page table* (the satp analogue, updated incrementally
from ``VirtualMemory.drain_dirty_rows()`` deltas — never re-uploaded
wholesale), and jitted prefill / continuation-prefill / decode steps whose
KV pools are donated so XLA updates them in place.

Contrast with the seed engine's hot path, which re-uploaded the full page
table every decode step and stacked+reshaped both full KV pools on every
spill/restore.  Here:

  * page-table updates are delta-only (``ptab_rows_uploaded`` counter);
  * spill/restore move only the victim sequence's pages
    (``ContextSwitcher.spill_kv``/``restore_kv`` — page-granular, the
    paper's §3.1 context-switch cost in actually-moved bytes);
  * inactive decode lanes are masked *inside* the jitted step from a [B]
    bool mask, not by rewriting table rows on the host;
  * decode runs in fused K-step horizons (``decode_multi``): one dispatch
    chains K ``decode_step``s with on-device sampling (greedy argmax or
    temperature/categorical with a threaded PRNG key) and per-lane retire
    masking, so the host round-trip — and the page-table delta sync — is
    paid once per horizon, not once per token (``host_syncs`` /
    ``decode_horizon`` counters).

The executor implements the scheduler's :class:`~repro.serve.scheduler.
DataPlane` protocol; it makes no policy decisions.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ContextSwitcher,
    CostModel,
    INVALID_PAGE,
    PerfCounters,
    VirtualMemory,
)
from repro.models.transformer import PagedKVState, TransformerLM
from repro.serve.scheduler import DecodePlan, Request, ServeConfig


# ---------------------------------------------------------------------------
# jitted device steps (module-level so the jit cache is shared per model)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, donate_argnums=(0,))
def _apply_ptab_delta(ptab: jax.Array, rows: jax.Array,
                      vals: jax.Array) -> jax.Array:
    """Scatter dirty rows into the persistent device page table."""
    return ptab.at[rows].set(vals)


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(4, 5))
def _prefill_step(model: TransformerLM, params: Any, tokens: jax.Array,
                  lens: jax.Array, k_pools: jax.Array, v_pools: jax.Array,
                  pt_rows: jax.Array):
    state = PagedKVState(k_pools, v_pools, pt_rows,
                         jnp.zeros_like(lens))
    logits, ns = model.prefill(params, tokens, lens, state)
    return logits, ns.k_pools, ns.v_pools


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(5, 6))
def _continue_step(model: TransformerLM, params: Any, tokens: jax.Array,
                   starts: jax.Array, lens: jax.Array, k_pools: jax.Array,
                   v_pools: jax.Array, pt_rows: jax.Array):
    state = PagedKVState(k_pools, v_pools, pt_rows,
                         jnp.zeros_like(starts))
    logits, ns = model.prefill_continue(params, tokens, starts, lens, state)
    return logits, ns.k_pools, ns.v_pools


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(3, 4))
def _decode_step(model: TransformerLM, params: Any, tokens: jax.Array,
                 k_pools: jax.Array, v_pools: jax.Array, ptab: jax.Array,
                 pre_lens: jax.Array, active: jax.Array):
    # mask page-table rows of slots that are NOT decoding this step:
    # mapped-but-idle sequences (e.g. the resident shared prefix) must not
    # receive the inactive-lane scratch writes — with a valid row the guard
    # would route them into a LIVE frame instead of the reserved scratch
    # row.  The mask is applied on device from a [B] bool vector; the table
    # itself is never rewritten.
    masked = jnp.where(active[:, None], ptab, INVALID_PAGE)
    state = PagedKVState(k_pools, v_pools, masked, pre_lens)
    logits, ns = model.decode_step(params, tokens, state)
    return logits, ns.k_pools, ns.v_pools


@functools.partial(jax.jit, static_argnums=(0, 10, 11), donate_argnums=(3, 4))
def _decode_multi_step(model: TransformerLM, params: Any, tokens: jax.Array,
                       k_pools: jax.Array, v_pools: jax.Array,
                       ptab: jax.Array, pre_lens: jax.Array,
                       steps_left: jax.Array, rng: jax.Array,
                       temperature: jax.Array, horizon: int, greedy: bool):
    """Fused K-step decode horizon with ON-DEVICE sampling.

    One dispatch runs ``horizon`` chained ``model.decode_step`` calls
    (``lax.scan`` inside :meth:`TransformerLM.decode_multi_step`), sampling
    each next token on device and feeding it straight back — the host
    round-trip per token (sample transfer, replan, token re-upload)
    becomes one round-trip per horizon.  Per-lane retirement is masked on
    device from ``steps_left``; the page table is read-only (masking
    happens per inner step, the table itself is never rewritten).
    """
    state = PagedKVState(k_pools, v_pools, ptab, pre_lens)
    block, ns, rng = model.decode_multi_step(
        params, tokens, state, steps_left, rng, temperature,
        horizon=horizon, greedy=greedy,
    )
    return block, ns.k_pools, ns.v_pools, rng


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _copy_pages(k_pools: jax.Array, v_pools: jax.Array, srcs: jax.Array,
                dsts: jax.Array):
    """COW tail-page copies: all forked frames in each pool, one dispatch."""
    return (k_pools.at[:, dsts].set(k_pools[:, srcs]),
            v_pools.at[:, dsts].set(v_pools[:, srcs]))


class Executor:
    """Owns KV pools + the device page table; executes scheduler plans."""

    def __init__(self, model: TransformerLM, params: Any, cfg: ServeConfig,
                 vmem: VirtualMemory, cost: CostModel | None = None,
                 counters: PerfCounters | None = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.vmem = vmem
        self.counters = counters or PerfCounters()
        self.switcher = ContextSwitcher(vmem, cost, page_axis=1)
        # the device pool has num_pages frames; the allocator saw one less
        # (last frame = scratch for masked lanes)
        self.kv = model.init_kv_state(
            cfg.max_batch, cfg.num_pages, cfg.page_size, cfg.max_pages_per_seq
        )
        #: persistent satp: updated by delta scatter, read by every step
        self._ptab = jnp.full(
            (cfg.max_batch, cfg.max_pages_per_seq), INVALID_PAGE, jnp.int32
        )
        self._rng = jax.random.PRNGKey(cfg.seed)

    # ------------------------------------------------------------------
    # persistent device page table
    # ------------------------------------------------------------------

    def sync_page_table(self) -> None:
        """Apply host page-table deltas (dirty rows only) to the device."""
        rows, vals = self.vmem.drain_dirty_rows()
        if rows.size:
            self._ptab = _apply_ptab_delta(
                self._ptab, jnp.asarray(rows), jnp.asarray(vals)
            )
            self.counters.inc("ptab_rows_uploaded", int(rows.size))
            self.counters.inc("ptab_syncs")

    @property
    def device_page_table(self) -> jax.Array:
        return self._ptab

    # ------------------------------------------------------------------
    # compute steps
    # ------------------------------------------------------------------

    def preload_prefix(self, prefix_tokens: np.ndarray, slot: int,
                       n: int) -> None:
        self.sync_page_table()
        tokens = np.asarray(prefix_tokens, np.int32)[None, :]
        page = self.cfg.page_size
        pad = (-n) % page
        if pad:
            tokens = np.pad(tokens, ((0, 0), (0, pad)))
        pt_rows = jnp.take(self._ptab, jnp.asarray([slot]), axis=0)
        _, k, v = _prefill_step(
            self.model, self.params, jnp.asarray(tokens),
            jnp.asarray([n], jnp.int32), self.kv.k_pools, self.kv.v_pools,
            pt_rows,
        )
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self.counters.inc("prefix_tokens", n)

    def _pad_prompt_batch(self, reqs: list[Request]):
        """Burst-aligned ``[B, smax]`` prompt matrix + true lengths + the
        batch's page-table rows — shared by plain and forked admission so
        padding/slot-lookup policy cannot desynchronize between them."""
        page = self.cfg.page_size
        smax = max(len(r.prompt) for r in reqs)
        smax = -(-smax // page) * page            # burst-align (jit reuse)
        tok_shape = (len(reqs), smax) + reqs[0].prompt.shape[1:]
        tokens = np.zeros(tok_shape, np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : len(r.prompt)] = r.prompt
        lens = np.array([len(r.prompt) for r in reqs], np.int32)
        slots = [self.vmem.seq(r.req_id).slot for r in reqs]
        pt_rows = jnp.take(self._ptab, jnp.asarray(slots), axis=0)
        return tokens, lens, pt_rows

    def prefill(self, reqs: list[Request]) -> list[np.ndarray]:
        """Batched prefill of freshly admitted requests; returns the first
        sampled token per request (request order)."""
        self.sync_page_table()
        tokens, lens, pt_rows = self._pad_prompt_batch(reqs)
        with self.counters.timer("prefill"):
            logits, k, v = _prefill_step(
                self.model, self.params, jnp.asarray(tokens),
                jnp.asarray(lens), self.kv.k_pools, self.kv.v_pools, pt_rows,
            )
            # async dispatch returns immediately; block so the timer
            # measures execution, not dispatch
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        first = self.sample(logits)
        return [np.asarray(first[i]) for i in range(len(reqs))]

    def decode(self, tokens: np.ndarray, pre_lens: np.ndarray,
               active: np.ndarray) -> np.ndarray:
        """One full-slot decode step (the horizon's K=1 collapse path);
        returns sampled tokens by slot."""
        self.sync_page_table()
        with self.counters.timer("decode"):
            logits, k, v = _decode_step(
                self.model, self.params, jnp.asarray(tokens),
                self.kv.k_pools, self.kv.v_pools, self._ptab,
                jnp.asarray(pre_lens), jnp.asarray(active),
            )
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon")
        return self.sample(logits)

    def decode_multi(self, plan: DecodePlan) -> np.ndarray:
        """Fused K-step decode horizon: ONE dispatch runs ``plan.horizon``
        chained decode steps with on-device sampling and per-lane retire
        masking, then transfers the whole ``[K, B, ...]`` token block in
        one host sync.  ``Executor.sample``'s per-token host path does not
        run on this path.  The scheduler has already pre-faulted every page
        the horizon touches, so exactly one page-table delta sync happens
        per horizon."""
        self.sync_page_table()
        with self.counters.timer("decode"):
            block, k, v, rng = _decode_multi_step(
                self.model, self.params, jnp.asarray(plan.tokens),
                self.kv.k_pools, self.kv.v_pools, self._ptab,
                jnp.asarray(plan.pre_lens), jnp.asarray(plan.steps_left),
                # plain float -> weak-typed scalar under jit: logits /
                # temperature keeps the logits dtype, exactly like the
                # host path's division by the Python float
                self._rng, float(self.cfg.temperature),
                plan.horizon, self.cfg.greedy,
            )
            jax.block_until_ready(block)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self._rng = rng
        self.counters.inc("host_syncs")
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon", plan.horizon)
        return np.asarray(block)

    # ------------------------------------------------------------------
    # DataPlane protocol (driven by the Scheduler)
    # ------------------------------------------------------------------

    def admit_forked_batch(
        self, reqs: list[Request], start_lens: list[int],
        tail_copies: list[tuple[int, int] | None],
    ) -> list[np.ndarray]:
        """COW tail copies + ONE batched continuation prefill for all
        same-step forked admissions (each request's prompt chunk starts at
        its own logical offset) — replaces both the seed's one-token-at-a-
        time teacher forcing and the per-request B=1 continuation calls."""
        self.sync_page_table()
        copies = [tc for tc in tail_copies if tc is not None]
        if copies:
            k, v = _copy_pages(
                self.kv.k_pools, self.kv.v_pools,
                jnp.asarray([src for src, _ in copies]),
                jnp.asarray([dst for _, dst in copies]),
            )
            self.kv = self.kv._replace(k_pools=k, v_pools=v)
        chunks, lens, pt_rows = self._pad_prompt_batch(reqs)
        with self.counters.timer("prefill"):
            logits, k, v = _continue_step(
                self.model, self.params, jnp.asarray(chunks),
                jnp.asarray(start_lens, jnp.int32),
                jnp.asarray(lens),
                self.kv.k_pools, self.kv.v_pools, pt_rows,
            )
            jax.block_until_ready(logits)
        self.kv = self.kv._replace(k_pools=k, v_pools=v)
        self.counters.inc("continuation_prefill_tokens", int(lens.sum()))
        first = self.sample(logits)
        return [np.asarray(first[i]) for i in range(len(reqs))]

    def spill(self, req: Request) -> None:
        """Page-granular spill: only the victim's frames leave the device."""
        self.switcher.spill_kv(req.req_id, self.kv.k_pools, self.kv.v_pools)

    def restore(self, req: Request, num_tokens: int) -> None:
        """Page-granular restore into freshly allocated frames."""
        # the DataPlane protocol passes the scheduler's recorded spill
        # length; the switcher's own record is authoritative — they must
        # agree or the re-mapped footprint would silently diverge
        assert num_tokens == self.switcher.spilled_len(req.req_id), (
            f"restore of req {req.req_id}: scheduler says {num_tokens} "
            f"tokens, switcher spilled "
            f"{self.switcher.spilled_len(req.req_id)}"
        )
        k, v, _ = self.switcher.restore_kv(
            req.req_id, self.kv.k_pools, self.kv.v_pools
        )
        self.kv = self.kv._replace(k_pools=k, v_pools=v)

    def discard(self, req: Request) -> None:
        """Free a failed request's host-side swap record (never restored)."""
        self.switcher.discard(req.req_id)

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------

    def sample(self, logits: jax.Array) -> np.ndarray:
        """Host-path sampling (prefill boundaries and the K=1 decode
        collapse path); every call forces one device->host sync.  The
        fused multi-step decode path samples on device instead."""
        self.counters.inc("host_syncs")
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._rng, key = jax.random.split(self._rng)
        return np.asarray(
            jax.random.categorical(
                key, logits / self.cfg.temperature, axis=-1
            )
        )
