"""Chunked-prefill paged attention — streaming KV pages per query block.

Continuation prefill extends a sequence that already holds ``start`` tokens
in the paged KV cache by a chunk of new tokens.  The gathered-pages jnp path
(kept as the oracle in :func:`repro.kernels.ref.paged_prefill_attention_ref`)
materializes the *whole* logical prefix — ``max_pages x page_size`` tokens —
per layer per chunk, the software equivalent of taking a TLB miss on every
page regardless of how much of the table is live.  This kernel instead
streams exactly the pages each query block can see, translating each page
through the scalar-prefetched page table immediately before its burst is
fetched — Ara2's ADDRGEN/MMU handshake (one translation per page-bounded
burst), applied to the chunked-prefill hot path.

Grid / blocking scheme
======================
::

    grid = (B, Hkv, S*G // bs, max_pages)           # pages innermost

  * axis 0 — batch row (one forked/continued request per row; same-step
    forked admissions run as ONE batched call, B > 1);
  * axis 1 — KV head; query heads of the same GQA group share the sweep;
  * axis 2 — query block: the chunk's queries, flattened to ``S*G`` rows
    (token-major, group-minor) and tiled ``bs = bq * G`` rows per block so
    one block is ``bq`` whole query tokens;
  * axis 3 — the KV page sweep.  Logical page ``p`` of row ``b`` is
    translated to a physical frame by the BlockSpec index map *reading the
    prefetched page table from SMEM*; the online softmax (running max /
    normalizer / accumulator in VMEM scratch) makes the sweep single-pass.

Pages strictly above the block's causal diagonal — ``p * page_size >
start_b + last_token(block)`` — are skipped twice over: ``pl.when`` elides
their MXU work, and the KV index map clamps their page index to the last
causally reachable page, so consecutive grid steps name the same block and
Pallas elides the DMA (no data burst consumed).  For a continuation chunk
at offset ``start`` this bounds the pages fetched by ``pages(start +
chunk_padded)`` instead of ``max_pages`` (``pages_touched`` is the exact
model), and trailing-block savings grow with the table headroom.

Semantics match the gathered-pages oracle exactly: causal masking on
absolute logical positions (``k_pos <= start_b + q_idx``) across the
page/offset boundary, unmapped page-table entries clamped to frame 0 (their
keys are either causally masked or belong to don't-care padded query rows —
identical don't-care reads to the oracle's ``max(table, 0)`` gather).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, should_interpret

_NEG_INF = -1e30


def _paged_prefill_kernel(
    starts_ref,        # SMEM [B] — tokens already cached per sequence
    page_table_ref,    # SMEM [B, max_pages] (prefetched; used by index maps)
    kv_scale_ref,      # SMEM [1] f32 — dequant scale (1.0 when not quantized)
    q_ref,             # VMEM [1, 1, bs, D]  (bs = bq * G flattened rows)
    k_ref,             # VMEM [1, page, 1, D]  (translated burst)
    v_ref,             # VMEM [1, page, 1, D]
    o_ref,             # VMEM [1, 1, bs, D]
    m_ref, l_ref, acc_ref,
    *,
    page_size: int,
    bq: int,
    group: int,
    scale: float,
    quantized: bool,
):
    del page_table_ref  # translation consumed by the index maps
    b, qb, p = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    start = starts_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Last absolute position any query row of this block occupies; pages
    # starting beyond it are entirely above the causal diagonal.
    last_q_pos = start + (qb + 1) * bq - 1

    @pl.when(p * page_size <= last_q_pos)
    def _body():
        q = q_ref[0, 0]                               # [bs, D]
        k = k_ref[0, :, 0, :]                         # [page, D]
        v = v_ref[0, :, 0, :]                         # [page, D]
        if quantized:
            # int8 burst → upcast in VMEM after the DMA; HBM traffic is
            # the quantized bytes, the MXU computes in the query's dtype.
            k = (k.astype(jnp.float32) * kv_scale_ref[0]).astype(q.dtype)
            v = (v.astype(jnp.float32) * kv_scale_ref[0]).astype(q.dtype)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # [bs, page]
        row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        q_pos = start + qb * bq + row // group        # absolute q position
        k_pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1
        )
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + pexp.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(p == pl.num_programs(3) - 1)
    def _store():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def last_reachable_page(start, qb, *, page_size: int, bq: int):
    """Last KV page query block ``qb`` can causally reach (padded block
    end).  THE shared clamp formula: the kernel's ``kv_index`` map uses it
    on traced scalars, ``pages_touched`` on Python ints — one source of
    truth, so the analytical bytes model cannot desync from what the
    kernel actually fetches."""
    return (start + (qb + 1) * bq - 1) // page_size


def pages_touched(start: int, chunk: int, max_pages: int, *,
                  page_size: int, bq: int) -> int:
    """Pages the kernel fetches for one (start, chunk) row — the analytical
    bytes-gathered model used by ``benchmarks/bench_prefill_continue.py``
    (the gathered-pages oracle always touches ``max_pages``, once per query
    chunk, independent of ``start + chunk``).

    Exact by construction: the per-block fetch count is
    ``last_reachable_page(...) + 1`` capped at the table — the same
    formula the kernel's index map clamps with (the clamp makes Pallas
    elide the DMA for every page beyond it)."""
    if not chunk:
        return 0
    bq = max(1, min(bq, chunk))
    total = 0
    for qb in range(cdiv(chunk, bq)):
        last = last_reachable_page(start, qb, page_size=page_size, bq=bq)
        total += min(last + 1, max_pages)
    return total


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "scale", "bq", "kv_scale", "interpret"),
)
def paged_prefill_attention(
    q: jax.Array,            # [B, S, Hkv, G, D] chunk queries
    k_pool: jax.Array,       # [P, page, Hkv, D]  (model dtype or int8)
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32 — tokens already cached per row
    *,
    page_size: int,
    scale: float | None = None,
    bq: int = 32,
    kv_scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Chunked-prefill attention through the page table.

    Query token ``t`` of row ``b`` sits at absolute position
    ``starts[b] + t`` and attends causally over logical positions
    ``[0, starts[b] + t]`` — cache plus committed chunk prefix (the chunk's
    own KV must already be written through the table, see
    ``ops.paged_copy_at``).  When ``kv_scale`` is given the pools hold
    quantized integers; the scale is scalar-prefetched next to the page
    table and tiles are dequantized in VMEM after each burst lands.
    Returns [B, S, Hkv, G, D].
    """
    if interpret is None:
        interpret = should_interpret()
    b, s, hkv, g, d = q.shape
    n_pages, page, _, _ = k_pool.shape
    assert page == page_size, (page, page_size)
    max_pages = page_table.shape[1]
    scale = scale if scale is not None else d ** -0.5

    bq = max(1, min(bq, s))
    sp = cdiv(s, bq) * bq
    # token-major, group-minor row flattening: [B, Hkv, S*G, D]
    qf = q.transpose(0, 2, 1, 3, 4).reshape(b, hkv, s * g, d)
    if sp != s:
        # padded rows sit beyond the chunk; their outputs are sliced off
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, (sp - s) * g), (0, 0)))
    bs = bq * g

    def kv_index(bi, h, qb, p, starts_ref, page_table_ref, *_):
        # Pages above the block's causal diagonal are clamped to the last
        # reachable page: Pallas elides the DMA when consecutive grid steps
        # name the same block, so skipped pages cost no data burst (the
        # pl.when in the kernel body already skips their compute).
        last_page = last_reachable_page(
            starts_ref[bi], qb, page_size=page_size, bq=bq
        )
        p_eff = jnp.minimum(p, last_page)
        # THE translation: logical page p of row bi -> physical frame.
        # Unmapped entries (-1) clamp to frame 0; causal masking (or the
        # don't-care status of padded rows) keeps their data unused.
        frame = jnp.maximum(page_table_ref[bi, p_eff], 0)
        return (frame, 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, sp // bq, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, bs, d), lambda bi, h, qb, p, *_: (bi, h, qb, 0)),
            pl.BlockSpec((1, page_size, 1, d), kv_index),
            pl.BlockSpec((1, page_size, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bs, d), lambda bi, h, qb, p, *_: (bi, h, qb, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((bs, 1), jnp.float32),   # running max
            pltpu.VMEM((bs, 1), jnp.float32),   # running normalizer
            pltpu.VMEM((bs, d), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_prefill_kernel, page_size=page_size, bq=bq, group=g,
            scale=scale, quantized=kv_scale is not None,
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, sp * g, d), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(starts.astype(jnp.int32), page_table.astype(jnp.int32),
      jnp.full((1,), 1.0 if kv_scale is None else kv_scale, jnp.float32),
      qf, k_pool, v_pool)
    return out[:, :, : s * g].reshape(b, hkv, s, g, d).transpose(0, 2, 1, 3, 4)
