"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the continuous-batching engine (paged virtual memory, preemption,
fault accounting) on a reduced config and reports the paper-aligned
statistics: translation bursts, page faults, context-switch bytes/cycles,
tokens/s.
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=64,
                    help="small pools force preemption (context switches)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.family in ("rwkv6", "hybrid_rglru"):
        raise SystemExit(
            f"{args.arch}: engine drives paged-KV transformers; recurrent "
            "families decode via model.decode_step (see examples/)"
        )
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = Engine(model, params, ServeConfig(
        page_size=args.page_size, num_pages=args.num_pages,
        max_pages_per_seq=max(
            4, (args.prompt_len + args.max_new_tokens) // args.page_size + 2
        ),
        max_batch=args.max_batch,
    ))
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        shape = (plen, cfg.num_codebooks) if (
            cfg.family == "audio" and cfg.num_codebooks > 1
        ) else (plen,)
        eng.submit(Request(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32),
            max_new_tokens=args.max_new_tokens,
        ))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    total_tokens = sum(len(r.output) for r in done.values())
    print(f"completed {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU interpret)")
    print("counters:", stats["counters"])
    print("context switches:", stats["switch_stats"])
    print("pool:", stats["pool"])


if __name__ == "__main__":
    main()
