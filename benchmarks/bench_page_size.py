"""Beyond-paper: the PAGE-SIZE sweep — the TPU-side dual of the TLB sweep.

The paper sweeps the TLB against a fixed 4-KiB page.  On TPU the page size
itself is a design knob with a three-way tradeoff this benchmark
quantifies on real serving traces:

  * translations/token for decode reads (1 per page per step: smaller pages
    => more SMEM lookups and more kernel grid steps);
  * internal fragmentation (the allocated-but-unused tail of each
    sequence's last page: larger pages waste more pool);
  * VMEM burst efficiency (a page of one KV head is a [page, head_dim]
    tile; bursts under the 8-sublane tile height waste MXU/VPU issue).

Driven by a synthetic continuous-batching trace (Zipf-ish request lengths),
using the real VirtualMemory allocator.
"""

from __future__ import annotations

import numpy as np

from repro.core import VMemConfig, VirtualMemory

HEAD_DIM = 128
SUBLANE = 8
POOL_TOKENS = 1 << 16


def run_trace(page_size: int, seed: int = 0, n_req: int = 200):
    rng = np.random.default_rng(seed)
    vm = VirtualMemory(VMemConfig(
        page_size=page_size,
        num_pages=POOL_TOKENS // page_size,
        max_pages_per_seq=(8192 // page_size) + 2,
        max_seqs=64,
    ))
    lens = np.minimum((rng.pareto(1.2, n_req) + 1) * 64, 4096).astype(int)
    outs = rng.integers(16, 256, n_req)
    live: list[tuple[int, int]] = []   # (req_id, remaining)
    translations = 0
    decode_tokens = 0
    frag_samples = []
    util_samples = []
    for i, (plen, olen) in enumerate(zip(lens, outs)):
        # retire the oldest if slots/pages are tight
        while True:
            try:
                vm.map_seq(i, int(plen))
                break
            except Exception:
                if not live:
                    raise
                victim, _ = live.pop(0)
                vm.unmap_seq(victim)
        live.append((i, int(olen)))
        # decode loop for the newest request only (trace compression)
        for t in range(int(olen) // 8):
            vm.append_tokens(i, 8)
            # a decode step reads ceil(len/page) pages per sequence
            translations += -(-vm.seq_len(i) // page_size)
            decode_tokens += 8
        # fragmentation snapshot
        mapped_tokens = sum(vm.seq_len(r) for r, _ in live if vm.has_seq(r))
        mapped_pages = sum(len(vm.seq(r).pages) for r, _ in live
                           if vm.has_seq(r))
        if mapped_pages:
            frag_samples.append(
                1.0 - mapped_tokens / (mapped_pages * page_size)
            )
            util_samples.append(vm.pool.num_used / vm.pool.num_pages)
    vm.check_invariants()
    return {
        "tx_per_token": translations / max(decode_tokens, 1),
        "fragmentation": float(np.mean(frag_samples)),
        "pool_util": float(np.mean(util_samples)),
        "tile_efficiency": min(1.0, page_size / SUBLANE),
    }


def main() -> list[str]:
    lines = []
    print(f"{'page':>5s} {'tx/token':>9s} {'frag%':>7s} {'tile-eff':>9s}")
    for page in (4, 8, 16, 32, 64, 128):
        r = run_trace(page)
        print(f"{page:5d} {r['tx_per_token']:9.2f} "
              f"{r['fragmentation']*100:6.2f}% {r['tile_efficiency']:9.2f}")
        lines.append(
            f"page_sweep_{page},0,"
            f"tx={r['tx_per_token']:.2f} frag={r['fragmentation']*100:.2f}%"
        )
    print("\n16-token pages (= one 4-KiB bf16 burst per KV head, the AXI "
          "granularity restated) balance translation count against "
          "fragmentation — the default (DESIGN.md §6.3).")
    return lines


if __name__ == "__main__":
    main()
