"""Sharded executor over the ('kv', 'hd') serve mesh: single-device parity.

Runs the SAME decode-horizon workload through the split engine twice —
default single-device placement vs the executor's mesh mode
(``launch.mesh.make_host_serve_mesh``: KV pools sharded jointly over KV
heads and head_dim, page table + scalar-plane operands replicated) — and
reports:

  * token identity (greedy, auto horizon): the sharded data plane must
    reproduce the single-device token stream on a preempt/restore
    workload — the executor-level invariant the sharded refactor is
    gated on;
  * the amortization counters per decoded token (host syncs, page-table
    delta syncs) and the mean fused horizon — these must not change under
    sharding, because every one of them is a *scheduler* event and the
    scheduler is untouched (that was the point of the PR 1 split);
  * decode tok/s on both placements — informational only on CPU-forced
    host devices, where per-device collectives are emulation, not speed.

With a single visible device the mesh degrades to 1x1 — the sharded code
path (explicit in/out shardings, donated pools) still runs, which is what
the fast CI job exercises; the ``multidevice`` job forces 8 host devices
via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import functools

# same workload generator, driver and jit-cache warmer as the seed-vs-split
# benchmark: _warm walks the whole power-of-two horizon ladder (max_new=12
# AND 6) so no fused-decode graph compiles inside the timed region
from benchmarks.bench_serve_throughput import _drive, _warm, _workload


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_host_serve_mesh
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)
    print(f"serve mesh {dict(mesh.shape)}: {mesh.size} of "
          f"{jax.device_count()} visible devices")

    # tight pool -> admission queuing, preemption and restore all fire
    # while the horizon opens and collapses; the stress identity workload
    serve_cfg = ServeConfig(page_size=4, num_pages=16, max_pages_per_seq=16,
                            max_batch=3)
    reqs = _workload(cfg)
    results = {}
    outs = {}
    for name, kw in (("single", {}), ("sharded", {"mesh": mesh})):
        eng_cls = functools.partial(Engine, **kw)
        _warm(eng_cls, model, params, cfg, serve_cfg)
        eng = eng_cls(model, params, serve_cfg)
        done, wall = _drive(eng, reqs)
        eng.executor.check_sharding_invariants()
        outs[name] = {i: [int(x) for x in done[i].output] for i in done}
        c = eng.counters
        toks = c.get("decode_tokens")
        results[name] = dict(
            wall=wall,
            decode_tok_per_s=toks / max(c.seconds("decode"), 1e-9),
            host_syncs_per_tok=c.ratio("host_syncs", "decode_tokens"),
            ptab_syncs_per_tok=c.ratio("ptab_syncs", "decode_tokens"),
            mean_horizon=(c.get("decode_horizon")
                          / max(c.get("decode_dispatches"), 1)),
            preemptions=c.get("preemptions"),
            restores=c.get("restores"),
        )
        r = results[name]
        print(f"{name:>8}: {r['decode_tok_per_s']:.1f} decode tok/s, "
              f"{r['host_syncs_per_tok']:.3f} host syncs/tok, "
              f"{r['ptab_syncs_per_tok']:.3f} ptab syncs/tok, "
              f"mean horizon {r['mean_horizon']:.2f}, "
              f"{r['preemptions']} preemptions / {r['restores']} restores")

    token_identical = outs["single"] == outs["sharded"]
    counters_identical = all(
        results["single"][k] == results["sharded"][k]
        for k in ("host_syncs_per_tok", "ptab_syncs_per_tok", "mean_horizon",
                  "preemptions", "restores")
    )
    print(f"sharded outputs token-identical to single-device: "
          f"{token_identical}; scheduler counters identical: "
          f"{counters_identical}")

    metrics = {
        "mesh_devices": int(mesh.size),
        "visible_devices": int(jax.device_count()),
        "token_identical": bool(token_identical),
        "counters_identical": bool(counters_identical),
        "single": results["single"],
        "sharded": results["sharded"],
    }
    csv = [
        f"serve_sharded_mesh_devices,0,{mesh.size}",
        f"serve_sharded_token_identical,0,{int(token_identical)}",
        f"serve_sharded_decode_tok_per_s,0,"
        f"{results['sharded']['decode_tok_per_s']:.2f}",
        f"serve_sharded_host_syncs_per_tok,0,"
        f"{results['sharded']['host_syncs_per_tok']:.4f}",
        f"serve_sharded_ptab_syncs_per_tok,0,"
        f"{results['sharded']['ptab_syncs_per_tok']:.4f}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
