"""Fig. 2(b,c,d) reproduction: matmul VM overhead vs DTLB size x problem size.

Methodology (DESIGN.md §5): the TRACES are real — we enumerate the exact
page-access streams the blocked matmul kernel issues (scalar A-element
loads interleaved with vector B-row bursts and C-row read/write bursts,
the paper's "kernel that heavily requires the cooperation of the scalar
core") — and replay them through the tree-PLRU shared-MMU simulator with
the AraOS cycle constants.  Overhead is reported relative to the bare-metal
baseline (no translation), decomposed exactly as the paper does:
CVA6-side stalls / Ara2-side stalls / mux + pollution.

Paper checkpoints this must land on:
  * >= 16 DTLB entries  ->  total overhead < 3.5 % on all problem sizes;
  * 128 entries         ->  < 1 % residue (PLRU non-optimality);
  * the three problems need 16 / 32 / 128 entries to peak
    (datasets of 6 / 24 / 96 pages);
  * larger problems hide MORE of the CVA6 stalls (longer vectors).
"""

from __future__ import annotations

import numpy as np

from repro.core import CostModel, SharedMMUSimulator
from repro.core.tlb import SCALAR, VECTOR, AccessEvent

PAGE_BYTES = 4096
F32 = 4

# matmul problem sizes chosen so A+B+C datasets span 6 / 24 / 96 pages,
# matching the paper's three workloads
PROBLEMS = {"6p": 45, "24p": 90, "96p": 181}
TLB_SIZES = (2, 4, 8, 16, 32, 64, 128)


def matmul_trace(n: int) -> tuple[list[AccessEvent], float]:
    """Page-access stream of the row-vectorized matmul C[i,:] += A[i,k]*B[k,:].

    Returns (events, baseline_cycles).  Addresses are byte-accurate over a
    contiguous A|B|C layout; one VECTOR event per page-bounded burst of a
    B/C row, one SCALAR event per A-element load (naturally page-local).
    The per-event ``slack`` is the concurrent vector compute available to
    hide a miss: a B-row burst of n f32 runs ~n/4 cycles on 2 lanes.
    """
    a0, b0, c0 = 0, n * n * F32, 2 * n * n * F32
    events: list[AccessEvent] = []
    vec_cycles_per_row = n / 4.0           # 2-lane FPU, f32
    # slack: the previous vector instruction still runs while translations
    # for the next burst are requested; scalar loads of A overlap the
    # row-long vector op (paper: "longer vectors hide CVA6 stalls")
    scalar_slack = max(vec_cycles_per_row - 2.0, 0.0)
    vector_slack = max(vec_cycles_per_row - 4.0, 0.0)

    def bursts(start: int, nbytes: int):
        first = start // PAGE_BYTES
        last = (start + nbytes - 1) // PAGE_BYTES
        return range(first, last + 1)

    for i in range(n):
        for k in range(n):
            # scalar core loads A[i, k]
            addr = a0 + (i * n + k) * F32
            events.append(AccessEvent(
                SCALAR, addr // PAGE_BYTES, slack=scalar_slack))
            # vector unit streams B[k, :] (page-bounded bursts)
            for vpn in bursts(b0 + k * n * F32, n * F32):
                events.append(AccessEvent(VECTOR, vpn, slack=vector_slack))
        # C[i, :] load + store bursts once per row sweep
        for vpn in bursts(c0 + i * n * F32, n * F32):
            events.append(AccessEvent(VECTOR, vpn, slack=vector_slack))
            events.append(AccessEvent(VECTOR, vpn, slack=vector_slack))
    baseline = n * n * vec_cycles_per_row  # FPU-bound bare-metal runtime
    return events, baseline


def sweep() -> dict[str, dict[int, dict[str, float]]]:
    out: dict[str, dict[int, dict[str, float]]] = {}
    for label, n in PROBLEMS.items():
        events, baseline = matmul_trace(n)
        out[label] = {}
        for entries in TLB_SIZES:
            sim = SharedMMUSimulator(entries, CostModel())
            rep = sim.run(events)
            frac = rep.decomposed_fractions(baseline)
            frac["misses"] = rep.misses
            frac["hit_rate"] = rep.hits / max(rep.translations, 1)
            out[label][entries] = frac
    return out


def main() -> list[str]:
    results = sweep()
    lines = []
    print(f"{'problem':8s} {'PTEs':>5s} {'cva6%':>7s} {'ara2%':>7s} "
          f"{'mux%':>7s} {'total%':>7s} {'hit%':>6s}")
    for label, by_size in results.items():
        for entries, f in by_size.items():
            print(f"{label:8s} {entries:5d} {f['cva6']*100:7.2f} "
                  f"{f['ara2']*100:7.2f} {f['mux_pollution']*100:7.2f} "
                  f"{f['total']*100:7.2f} {f['hit_rate']*100:6.1f}")
            lines.append(
                f"tlb_{label}_{entries},0,total={f['total']*100:.2f}%"
            )
    # the paper's claims, checked programmatically
    checks = []
    for label, by in results.items():
        checks.append(("<=3.5% @ >=16 PTEs (" + label + ")",
                       all(by[e]["total"] < 0.035 for e in (16, 32, 64, 128))))
        checks.append(("<1% residue @128 (" + label + ")",
                       by[128]["total"] < 0.01))
    big_hides_more = (
        results["96p"][16]["cva6"] <= results["6p"][16]["cva6"] * 1.5
    )
    checks.append(("larger problems hide CVA6 stalls", big_hides_more))
    print("\npaper-claim validation:")
    for name, ok in checks:
        print(f"  [{'PASS' if ok else 'FAIL'}] {name}")
        lines.append(f"tlb_claim_{name.split(' ')[0]},0,"
                     f"{'pass' if ok else 'FAIL'}")
    return lines


if __name__ == "__main__":
    main()
