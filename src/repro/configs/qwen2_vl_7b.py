"""Qwen2-VL-7B — M-RoPE, dynamic resolution (stub frontend)
[arXiv:2409.12191; hf].  Backbone only per assignment; ``input_specs``
provides precomputed patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24), frontend="vision",
)

REDUCED = ModelConfig(
    name="qwen2-vl-7b-reduced", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
    qkv_bias=True, mrope_sections=(2, 3, 3), frontend="vision",
    param_dtype="float32",
)
