"""Tests for the TLB model, shared-MMU simulator, faults, context switches."""

import numpy as np
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro.core import (
    SCALAR,
    VECTOR,
    AccessEvent,
    ContextSwitcher,
    CostModel,
    PageFault,
    ResumeCursor,
    SharedMMUSimulator,
    TLB,
    VMemConfig,
    VirtualMemory,
    interleave,
)


# ---------------------------------------------------------------------------
# TLB replacement behaviour
# ---------------------------------------------------------------------------


class TestTLB:
    def test_power_of_two_enforced(self):
        with pytest.raises(ValueError):
            TLB(3)

    def test_residency_bounded(self):
        t = TLB(4)
        for v in range(100):
            t.access(v)
        assert len(t.resident) <= 4

    def test_warm_working_set_never_misses(self):
        t = TLB(8)
        ws = list(range(6))
        for v in ws:
            t.access(v)
        h0, m0 = t.hits, t.misses
        for _ in range(10):
            for v in ws:
                assert t.access(v)
        assert t.misses == m0 and t.hits == h0 + 60

    def test_plru_evicts_cold_entry(self):
        """After touching 1,2,3,4 then re-touching 1,2 the victim is 3."""
        t = TLB(4)
        for v in [1, 2, 3, 4, 1, 2]:
            t.access(v)
        t.access(5)
        assert 3 not in t.resident
        assert {1, 2, 4, 5} == t.resident

    def test_flush(self):
        t = TLB(4)
        t.access(1)
        t.flush()
        assert not t.access(1)  # miss again

    def test_pollution_evicts_but_hides_stats(self):
        t = TLB(4)
        for v in range(4):
            t.access(v)
        h, m = t.hits, t.misses
        t.pollute(4, np.random.default_rng(0))
        assert (t.hits, t.misses) == (h, m)
        assert not t.resident & {0, 1, 2, 3}

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 30), min_size=1, max_size=300),
           st.sampled_from([1, 2, 4, 8, 16, 32]))
    def test_hits_plus_misses_is_accesses(self, trace, entries):
        t = TLB(entries)
        for v in trace:
            t.access(v)
        assert t.hits + t.misses == len(trace)
        # cold misses are a lower bound
        assert t.misses >= min(len(set(trace)), 1)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 64))
    def test_full_residency_eliminates_capacity_misses(self, n_pages):
        """Paper: at 128 PTEs all workload pages fit and misses ~vanish."""
        entries = 128
        t = TLB(entries)
        trace = list(range(n_pages)) * 5
        for v in trace:
            t.access(v)
        assert t.misses == n_pages  # compulsory only


# ---------------------------------------------------------------------------
# Shared-MMU simulator (Fig. 2 machinery)
# ---------------------------------------------------------------------------


class TestSharedMMUSimulator:
    def test_slack_hides_vector_stalls(self):
        """Paper C4: enough concurrent compute => no visible Ara2 stall."""
        ev = [AccessEvent(VECTOR, v, slack=10_000) for v in range(50)]
        rep = SharedMMUSimulator(16).run(ev)
        assert rep.ara2_cycles == 0.0
        assert rep.misses > 0  # misses happened, they were just hidden

    def test_no_slack_exposes_stalls(self):
        ev = [AccessEvent(SCALAR, v, slack=0.0) for v in range(50)]
        cost = CostModel()
        rep = SharedMMUSimulator(16, cost).run(ev)
        assert rep.cva6_cycles >= 50 * cost.mmu_hit_cycles

    def test_mux_contention_on_busy_switch_only(self):
        """Arbitration is charged only when the other requester arrives
        while the MMU is mid-walk (previous request missed); pipelined
        hits switch sources for free."""
        cost = CostModel()
        ev = [AccessEvent(SCALAR, 0), AccessEvent(VECTOR, 1),
              AccessEvent(SCALAR, 0), AccessEvent(VECTOR, 1)]
        rep = SharedMMUSimulator(16, cost).run(ev)
        # switches after the two cold misses pay; the hit->switch does not
        assert rep.mux_pollution_cycles == 2 * cost.mux_contention_cycles
        # an all-hit alternating trace pays nothing
        warm = [AccessEvent(SCALAR, 0), AccessEvent(VECTOR, 1)] * 5
        rep2 = SharedMMUSimulator(16, cost).run(ev + warm)
        assert rep2.mux_pollution_cycles == rep.mux_pollution_cycles

    def test_bigger_tlb_helps_cyclic_trace(self):
        """Cyclic working set: misses drop once the TLB holds the set."""
        trace = (list(range(24)) * 20)
        misses = {}
        for entries in (2, 8, 32, 128):
            sim = SharedMMUSimulator(entries)
            rep = sim.run([AccessEvent(VECTOR, v) for v in trace])
            misses[entries] = rep.misses
        assert misses[32] == 24  # working set resident: compulsory only
        assert misses[128] == 24
        # below the working-set size a cyclic trace thrashes (every access
        # misses under [P]LRU) — the paper's "larger problems need more
        # DTLB entries to reach their performance peak"
        assert misses[2] == misses[8] == len(trace)

    def test_interleave_ratio(self):
        ev = list(interleave([1, 2, 3, 4], [10, 11], scalar_slack=0,
                             vector_slack=0, ratio=2))
        kinds = [e.source for e in ev]
        assert kinds == [SCALAR, SCALAR, VECTOR, SCALAR, SCALAR, VECTOR]


# ---------------------------------------------------------------------------
# vstart resume protocol (C5)
# ---------------------------------------------------------------------------


class TestResume:
    def test_cursor_semantics(self):
        c = ResumeCursor(total=100)
        c.advance(40)
        c.record_fault(PageFault(seq_id=0, logical_page=3, vstart=10))
        assert c.committed == 50 and c.faults_taken == 1
        c.advance(50)
        assert c.done
        with pytest.raises(ValueError):
            c.advance(1)

    def test_faulted_resume_equals_uninterrupted(self):
        """C5: a copy that faults mid-way and resumes produces identical
        output to one that never faults."""
        cfg = VMemConfig(page_size=8, num_pages=32, max_pages_per_seq=16, max_seqs=2)
        src = np.arange(64, dtype=np.float32)

        def run_copy(fault_after: int | None) -> np.ndarray:
            vm = VirtualMemory(cfg)
            vm.map_seq(0, 16)  # only first 16 tokens mapped
            pool = np.zeros(cfg.num_pages * cfg.page_size, np.float32)
            cursor = ResumeCursor(total=64)
            while not cursor.done:
                want = np.arange(cursor.committed, 64)
                try:
                    phys = vm.translate(0, want)
                except PageFault as f:
                    # commit the translated prefix, service the fault
                    good = want[: f.vstart]
                    pool[vm.translate(0, good)] = src[good]
                    cursor.record_fault(f)
                    vm.append_tokens(0, min(8, 64 - vm.seq_len(0)))
                    continue
                pool[phys] = src[want]
                cursor.advance(want.size)
            # read back through translation
            return pool[vm.translate(0, np.arange(64))]

        out = run_copy(None)
        np.testing.assert_array_equal(out, src)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 63), st.integers(1, 16))
    def test_resume_any_fault_point(self, initial_tokens, grow):
        """Property: regardless of where faults land, resumed output == source."""
        cfg = VMemConfig(page_size=8, num_pages=64, max_pages_per_seq=16, max_seqs=2)
        src = np.arange(64, dtype=np.float32) * 3.0
        vm = VirtualMemory(cfg)
        vm.map_seq(0, initial_tokens)
        pool = np.zeros(cfg.num_pages * cfg.page_size, np.float32)
        cursor = ResumeCursor(total=64)
        while not cursor.done:
            want = np.arange(cursor.committed, 64)
            try:
                phys = vm.translate(0, want)
            except PageFault as f:
                good = want[: f.vstart]
                if good.size:
                    pool[vm.translate(0, good)] = src[good]
                cursor.record_fault(f)
                vm.append_tokens(0, min(grow, 64 - vm.seq_len(0)))
                continue
            pool[phys] = src[want]
            cursor.advance(want.size)
        np.testing.assert_array_equal(pool[vm.translate(0, np.arange(64))], src)


# ---------------------------------------------------------------------------
# Context switches (§3.1)
# ---------------------------------------------------------------------------


class TestContextSwitch:
    def test_spill_restore_preserves_data_across_reframing(self):
        cfg = VMemConfig(page_size=4, num_pages=8, max_pages_per_seq=4, max_seqs=2)
        vm = VirtualMemory(cfg)
        vm.map_seq(0, 10)
        pool = jnp.zeros((cfg.num_pages, cfg.page_size, 3))
        # write recognizable data through translation
        data = jnp.arange(10 * 3, dtype=jnp.float32).reshape(10, 3)
        phys = vm.translate(0, np.arange(10))
        pool = pool.reshape(-1, 3).at[jnp.asarray(phys)].set(data).reshape(
            cfg.num_pages, cfg.page_size, 3)
        old_pages = list(vm.seq(0).pages)

        cs = ContextSwitcher(vm)
        pool = cs.spill(0, pool, extra_state="sampler")
        # dirty the freed frames, then allocate something else first so the
        # restore lands on different physical pages
        pool = pool.at[:].set(-1.0)
        vm.map_seq(5, 8)
        pool, extra = cs.restore(0, pool)
        assert extra == "sampler"
        assert vm.seq(0).pages != old_pages  # re-framed
        phys2 = vm.translate(0, np.arange(10))
        got = pool.reshape(-1, 3)[jnp.asarray(phys2)]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(data))
        vm.check_invariants()

    def test_modeled_cycles_match_paper(self):
        """8-KiB vector state at 64 bit/cycle => ~3.2 k cycles (paper §3.1)."""
        cost = CostModel()
        cycles = cost.context_switch_cycles(8 * 1024)
        assert cycles == pytest.approx(3200, rel=0.1)

    def test_tick_overhead_matches_paper_envelope(self):
        """100 Hz ticks at ~20 k cycles on 50 MHz: 4 % gross tick time.

        (The paper's < 0.5 % bound is specifically TLB/cache *pollution*,
        not tick handling; VM experiments use a non-preemptive scheduler.)
        """
        cost = CostModel()
        frac = cost.tick_overhead_fraction(runtime_cycles=50e6)  # 1 s run
        assert frac == pytest.approx(100 * 20e3 / 50e6, rel=1e-6)
        assert frac == pytest.approx(0.04, rel=1e-6)


class TestPLRUvsTrueLRU:
    """tree-PLRU approximates true LRU: identical on sizes <= 2, and never
    pathologically worse on random traces (property-based)."""

    @staticmethod
    def _true_lru_misses(trace, entries):
        order: list[int] = []
        misses = 0
        for v in trace:
            if v in order:
                order.remove(v)
            else:
                misses += 1
                if len(order) >= entries:
                    order.pop(0)
            order.append(v)
        return misses

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 6), min_size=1, max_size=120))
    def test_plru_equals_lru_for_two_ways(self, trace):
        t = TLB(2)
        for v in trace:
            t.access(v)
        assert t.misses == self._true_lru_misses(trace, 2)

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 20), min_size=10, max_size=200),
           st.sampled_from([4, 8, 16]))
    def test_plru_within_2x_of_lru(self, trace, entries):
        """PLRU's non-optimality is bounded in practice (the paper's <1 %
        residue at 128 entries relies on this)."""
        t = TLB(entries)
        for v in trace:
            t.access(v)
        lru = self._true_lru_misses(trace, entries)
        compulsory = len(set(trace))
        assert t.misses <= max(2 * lru, compulsory)
