"""Shared kernel infrastructure.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling, MXU-aligned
block shapes) and are *validated* on CPU with ``interpret=True``, which
executes the kernel body in Python per grid step.  ``should_interpret()``
selects interpret mode automatically off-TPU so the same call sites work in
tests, benchmarks, and on real hardware.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental.pallas import tpu as pltpu

#: Pallas-TPU compiler params across JAX versions: ``CompilerParams`` is
#: the current name, ``TPUCompilerParams`` the 0.4.x one.  Fail loudly at
#: import time if neither exists — a None here would only surface as an
#: opaque TypeError deep inside the first pallas_call.
CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)
if CompilerParams is None:  # pragma: no cover - future-jax guard
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; update repro.kernels.common for this JAX version"
    )

#: MXU systolic array dimension — matmul block shapes must be multiples.
MXU_DIM = 128
#: VPU lane count — trailing block dims should be multiples.
LANE_DIM = 128
#: Sublane count for f32 tiles.
SUBLANE_DIM = 8


@functools.cache
def should_interpret() -> bool:
    """True when not running on a real TPU (CPU validation mode)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
