"""Multi-replica router acceptance suite (marker: ``router``).

The contract under test: a :class:`ReplicaRouter` over N data planes is
*semantically invisible* — every request's token stream is identical to
the N=1 reference run, no request starves, global page/counter accounting
equals the sum of the per-replica accounting, and the merged ``done``
statuses are a permutation of the reference run's — for random workloads,
any N in {1, 2, 4}, and ANY deterministic fault schedule (growth-stall
page hogs, forced spills, injected restore failures, delayed
completions) running underneath.  The fake-plane tests here are pure
host policy (no device); :class:`TestRouterRealExecutors` repeats the
identity claim with real (optionally mesh-sharded) Executors and is
additionally marked ``sharded`` where it needs >1 XLA device.
"""

import collections
import copy

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # pragma: no cover
    from _prop_fallback import given, settings, st

from _fault_plane import (
    drive,
    drive_router,
    expected_output,
    make_replica,
)
from repro.serve import Replica, ReplicaRouter, ServeRequest, to_internal

pytestmark = pytest.mark.router


def req(i, plen=6, max_new=8, **kw):
    """Public-surface submission (Engine/Router take ONLY ServeRequest);
    scheduler-plane sites lower it explicitly via ``to_internal``."""
    return ServeRequest(req_id=i, prompt=np.arange(plen, dtype=np.int32),
                        max_new_tokens=max_new, **kw)


def make_router(n, policy="least_loaded", schedules=None, max_backlog=None,
                **kw):
    """N fault-plane replicas behind one router; returns (router, planes)."""
    replicas, planes = [], []
    for r in range(n):
        sched, plane = make_replica(
            replica_id=r, schedule=(schedules or {}).get(r, ()), **kw
        )
        replicas.append(Replica(replica_id=r, scheduler=sched, plane=plane))
        planes.append(plane)
    return ReplicaRouter(replicas, policy=policy,
                         max_backlog=max_backlog), planes


def outputs(done):
    return {rid: [int(x) for x in r.output] for rid, r in done.items()}


def statuses(done):
    return sorted((rid, r.status) for rid, r in done.items())


def preload_fake_prefix(replica, plen):
    """Resident shared prefix on a fake replica: host bookkeeping only."""
    s = replica.scheduler
    s.vmem.map_seq(s.PREFIX_ID, plen)
    s.prefix_len = plen


# ---------------------------------------------------------------------------
# randomized workload / fault-schedule generators (reachable by design:
# every request's unshared lifetime footprint fits one replica's pool, so
# forced spills can delay but never legitimately fail a request — which is
# what makes "statuses are a permutation of the reference" a theorem)
# ---------------------------------------------------------------------------

USABLE_PAGES = 8


def gen_workload(rng):
    n = int(rng.integers(2, 9))
    return [req(i, plen=int(rng.integers(1, 13)),
                max_new=int(rng.integers(1, 11))) for i in range(n)]


def gen_faults(rng, reqs, steps_hi=30):
    events = []
    rids = [r.req_id for r in reqs]
    for _ in range(int(rng.integers(0, 5))):
        kind = ["hog", "force_spill", "fail_restore", "delay_done"][
            int(rng.integers(0, 4))
        ]
        step = int(rng.integers(1, steps_hi))
        rid = int(rng.choice(rids))
        if kind == "hog":
            events.append(("hog", step, int(rng.integers(1, 4)),
                           int(rng.integers(1, 7))))
        elif kind == "force_spill":
            events.append(("force_spill", step, rid))
        elif kind == "fail_restore":
            events.append(("fail_restore", step, rid,
                           int(rng.integers(1, 4))))
        else:
            events.append(("delay_done", step, rid,
                           int(rng.integers(1, 4))))
    return tuple(events)


# ---------------------------------------------------------------------------
# the headline property: fault-injected replica sweep vs N=1 reference
# ---------------------------------------------------------------------------


class TestFaultInjectedReplicaSweep:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_token_identity_no_starvation_and_accounting(self, seed):
        rng = np.random.default_rng(seed)
        reqs = gen_workload(rng)

        # fault-free N=1 reference run
        ref, ref_planes = make_router(1, usable_pages=USABLE_PAGES)
        for r in reqs:
            ref.submit(copy.deepcopy(r))
        assert drive_router(ref, ref_planes) < 500
        ref_done = {rid: r for rid, r in ref.done.items()}
        ref_out = outputs(ref_done)
        # the closed form: the reference itself must deliver the analytic
        # per-request stream in full
        assert ref_out == {r.req_id: expected_output(r) for r in reqs}
        assert all(r.status == "done" for r in ref_done.values())

        for n in (1, 2, 4):
            schedules = {i: gen_faults(rng, reqs) for i in range(n)}
            router, planes = make_router(n, schedules=schedules,
                                         usable_pages=USABLE_PAGES)
            for r in reqs:
                router.submit(copy.deepcopy(r))
            steps = drive_router(router, planes)
            assert steps < 500, f"N={n}: starvation (drive never drained)"
            done = router.done
            # token identity with the N=1 reference, request by request
            assert outputs(done) == ref_out, f"N={n} diverged"
            # done statuses are a permutation of the reference run's
            assert statuses(done) == statuses(ref_done)
            # cross-replica conservation: pages, requests, placements
            router.check_invariants()
            # global accounting equals the sum of replica accounting,
            # recomputed by hand (not via the router's own helper)
            manual = collections.Counter()
            for rep in router.replicas:
                manual.update(rep.scheduler.counters.counters)
            manual.update(router.counters.counters)
            assert router.global_counters() == manual
            pages = collections.Counter()
            for rep in router.replicas:
                pages.update(rep.page_report())
            assert router.global_page_report() == dict(pages)
            # exactly one decode token per request-step actually decoded
            total = router.global_counters()
            assert total["decode_tokens"] == sum(
                max(2, r.max_new_tokens) - 1 for r in reqs
            )
            assert total["completed"] == len(reqs)
            assert total["placements"] == len(reqs)


# ---------------------------------------------------------------------------
# counter invariants (satellite): monotone counters, totals = sum of parts
# ---------------------------------------------------------------------------


WATCHED = ("host_syncs", "ptab_syncs", "ptab_rows_uploaded",
           "decode_horizon", "decode_tokens", "decode_dispatches",
           "preemptions", "restores", "restore_failures", "page_faults",
           "submitted", "completed")


class TestCounterInvariants:
    def test_counters_monotone_across_fault_sequence(self):
        """Every accounting counter is monotone non-decreasing through a
        preempt -> restore-failure -> hog -> restore sequence."""
        sched, plane = make_replica(
            usable_pages=6, max_batch=2,
            schedule=(("force_spill", 4, 0), ("fail_restore", 5, 0, 2),
                      ("hog", 8, 2, 3)),
        )
        for i in range(4):
            sched.submit(to_internal(req(i, plen=6, max_new=8)))
        last = {k: 0 for k in WATCHED}
        steps = 0
        while sched.has_work and steps < 300:
            steps += 1
            plane.tick(steps)
            sched.step_plane()
            for k in WATCHED:
                v = sched.counters.get(k)
                assert v >= last[k], f"{k} went backwards at step {steps}"
                last[k] = v
        assert steps < 300 and not sched.has_work
        assert last["restore_failures"] == 2     # both injected denials
        assert last["preemptions"] >= 1
        assert last["restores"] >= 1
        assert last["completed"] == 4
        sched.vmem.check_invariants()

    def test_totals_equal_replica_sums_across_preempt_fork_restore(self):
        """N=2 with shared prefixes, tight pools and forced spills: every
        merged counter equals the sum of the per-replica values, and the
        preempt/fork/restore machinery all actually fired."""
        router, planes = make_router(
            2, usable_pages=6, max_batch=2,
            schedules={0: (("force_spill", 6, 0),),
                       1: (("force_spill", 7, 1),)},
        )
        for rep in router.replicas:
            preload_fake_prefix(rep, plen=6)
        reqs = [req(i, plen=4, max_new=8, share_prefix=(i % 2 == 0))
                for i in range(6)]
        for r in reqs:
            router.submit(copy.deepcopy(r))
        assert drive_router(router, planes) < 500
        total = router.global_counters()
        for name in set(total) | set(WATCHED):
            parts = sum(rep.scheduler.counters.get(name)
                        for rep in router.replicas)
            parts += router.counters.get(name)
            assert total[name] == parts, name
        assert total["forked_admissions"] > 0
        assert total["preemptions"] >= 2
        assert total["restores"] >= 1
        # both replicas really decoded (per-replica counters all live)
        for rep in router.replicas:
            assert rep.scheduler.counters.get("host_syncs") > 0
            assert rep.scheduler.counters.get("decode_tokens") > 0
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        router.check_invariants()


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------


class TestPlacement:
    def test_round_robin_cycles_over_replicas(self):
        router, planes = make_router(3, policy="round_robin")
        for i in range(6):
            router.submit(req(i))
        order = [p.payload[1] for p in router.counters.events("place")]
        assert order == [0, 1, 2, 0, 1, 2]
        assert drive_router(router, planes) < 500
        for i in range(3):
            assert router.counters.get(f"placements_replica{i}") == 2
        router.check_invariants()

    def test_least_loaded_spreads_a_burst(self):
        """Backlogged page demand counts as load, so a burst submitted
        before any step runs alternates instead of piling on replica 0."""
        router, planes = make_router(2, policy="least_loaded")
        for i in range(4):
            router.submit(req(i, plen=6))
        order = [p.payload[1] for p in router.counters.events("place")]
        assert order == [0, 1, 0, 1]
        assert drive_router(router, planes) < 500
        router.check_invariants()

    def test_fork_affinity_pins_to_prefix_replica_and_counts_declines(self):
        """COW forks land on the (more loaded) prefix-holding replica —
        prefix sharing beats load balance — and each overridden base-
        policy choice is counted as a declined migration."""
        router, planes = make_router(2)
        preload_fake_prefix(router.replicas[1], plen=6)   # 2 pages pinned
        router.submit(req(0, plen=4, share_prefix=True))
        router.submit(req(1, plen=4, share_prefix=True))
        order = [p.payload[1] for p in router.counters.events("place")]
        assert order == [1, 1]                 # affinity, not least-loaded
        assert router.counters.get("migrations_declined") == 2
        router.submit(req(2, plen=4))          # plain: load balance rules
        assert router.counters.events("place")[-1].payload[1] == 0
        assert drive_router(router, planes) < 500
        done = router.done
        assert statuses(done) == [(0, "done"), (1, "done"), (2, "done")]
        assert outputs(done)[0] == expected_output(req(0, 4, 8))
        router.check_invariants()

    def test_backlog_diverted_fork_is_not_a_declined_migration(self):
        """``migrations_declined`` counts only AFFINITY overrides: when a
        backlog bound (not fork affinity) diverts the placement away from
        the unconstrained best replica, the counter must not move —
        the baseline choice is ranked under the same backlog filter."""
        router, planes = make_router(3, max_backlog=1)
        preload_fake_prefix(router.replicas[0], plen=6)
        preload_fake_prefix(router.replicas[1], plen=6)
        # replica 0: prefix (2 pages) + a queued request -> at backlog AND
        # still the overall least-loaded is replica 2 (no prefix, empty)
        router.replicas[0].scheduler.submit(to_internal(req(90, plen=2)))
        router.submit(req(0, plen=4, share_prefix=True))
        # eligible = {0, 1}; 0 is backlog-full -> choice = 1.  The
        # affinity-free baseline under the same backlog filter is
        # replica 2 (empty), so this IS a declined migration...
        assert router.counters.events("place")[-1].payload[1] == 1
        assert router.counters.get("migrations_declined") == 1
        # ...but when affinity and the filtered baseline agree, it is not:
        # replica 1 now carries the fork, replica 0 is still backlog-full,
        # and replica 2 stays the baseline — a second fork landing on 1
        # again declines again, while a PLAIN request diverted by nothing
        # counts nothing.
        before = router.counters.get("migrations_declined")
        router.submit(req(1, plen=4))                  # plain -> replica 2
        assert router.counters.events("place")[-1].payload[1] == 2
        assert router.counters.get("migrations_declined") == before

    def test_share_prefix_without_any_prefix_replica_raises(self):
        router, _ = make_router(2)
        with pytest.raises(ValueError, match="share_prefix"):
            router.submit(req(0, share_prefix=True))

    def test_bounded_backlog_defers_and_counts_queue_waits(self):
        router, planes = make_router(2, max_backlog=1, max_batch=1,
                                     usable_pages=4)
        reqs = [req(i, plen=4, max_new=6) for i in range(5)]
        for r in reqs:
            router.submit(copy.deepcopy(r))
        # two placed immediately (one backlog slot per replica), the rest
        # wait in the global admission queue
        assert router.counters.get("placements") == 2
        assert len(router.queue) == 3
        assert drive_router(router, planes) < 500
        assert router.counters.get("cross_replica_queue_waits") > 0
        assert router.counters.get("placements") == 5
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        router.check_invariants()

    def test_rejects_bad_configurations(self):
        with pytest.raises(ValueError, match="at least one"):
            ReplicaRouter([])
        sched, plane = make_replica()
        rep = Replica(replica_id=0, scheduler=sched, plane=plane)
        with pytest.raises(ValueError, match="duplicate"):
            ReplicaRouter([rep, rep])
        with pytest.raises(ValueError, match="policy"):
            ReplicaRouter([rep], policy="hottest_replica")


# ---------------------------------------------------------------------------
# N=1 equivalence: the router is exactly the single-replica engine loop
# ---------------------------------------------------------------------------


class TestN1Equivalence:
    def test_n1_router_is_callwise_identical_to_bare_scheduler_loop(self):
        reqs = [req(i, plen=5 + i, max_new=6) for i in range(4)]
        sched, plane = make_replica(usable_pages=8, max_batch=2)
        for r in reqs:
            sched.submit(to_internal(copy.deepcopy(r)))
        drive(sched, plane)
        router, planes = make_router(1, usable_pages=8, max_batch=2)
        for r in reqs:
            router.submit(copy.deepcopy(r))
        drive_router(router, planes)
        rsched = router.replicas[0].scheduler
        assert outputs(sched.done) == outputs(router.done)
        assert list(sched.done) == list(router.done)   # completion ORDER
        assert sched.step_i == rsched.step_i
        # identical per-replica counters modulo the router's own placement
        # bookkeeping
        a = dict(sched.counters.counters)
        b = dict(rsched.counters.counters)
        b.pop("router_placements")
        assert a == b
        # the fake planes saw the identical call sequence
        assert plane.events == planes[0].events


# ---------------------------------------------------------------------------
# run-budget boundary (satellite): retire exactly on the last tick
# ---------------------------------------------------------------------------


class TestRouterRealEngines:
    """The identity claim with REAL device executors: N single-device
    Engines behind the router reproduce the plain-engine token stream
    (greedy decoding is per-sequence, so batching/placement must be
    invisible).  Roomy pools keep every replica off the degraded
    growth-stall path, whose scratch-routed writes are the one
    *intentional* token-stream divergence in the engine."""

    @pytest.fixture(scope="class")
    def real_setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import build_model
        from repro.serve import ServeConfig
        cfg = get_config("qwen2-7b", reduced=True)
        model = build_model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        scfg = ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=32,
                           max_batch=3)
        return cfg, model, params, scfg

    @staticmethod
    def _workload(cfg, n, seed, max_new=8):
        rng = np.random.default_rng(seed)
        return [ServeRequest(req_id=i,
                             prompt=rng.integers(0, cfg.vocab_size,
                                                 size=int(rng.integers(5, 12))
                                                 ).astype(np.int32),
                             max_new_tokens=max_new) for i in range(n)]

    def _reference(self, real_setup, reqs):
        from repro.serve import Engine
        cfg, model, params, scfg = real_setup
        ref = Engine(model, params, scfg)
        for r in reqs:
            ref.submit(copy.deepcopy(r))
        return ref.run()

    def _router_over(self, real_setup, n, mesh=None):
        from repro.serve import Engine
        cfg, model, params, scfg = real_setup
        engines = [Engine(model, params, scfg, mesh=mesh) for _ in range(n)]
        router = ReplicaRouter(
            [eng.as_replica(i) for i, eng in enumerate(engines)]
        )
        return router, engines

    def test_n2_token_identity_vs_single_engine(self, real_setup):
        cfg = real_setup[0]
        reqs = self._workload(cfg, n=5, seed=3)
        ref_done = self._reference(real_setup, reqs)
        router, engines = self._router_over(real_setup, n=2)
        for r in reqs:
            router.submit(copy.deepcopy(r))
        done = router.run()
        assert outputs(done) == outputs(ref_done)
        assert statuses(done) == statuses(ref_done)
        # the fleet really load-balanced (both data planes decoded)
        for i in range(2):
            assert router.counters.get(f"placements_replica{i}") > 0
        for eng in engines:
            assert eng.counters.get("decode_tokens") > 0
        router.check_invariants()


@pytest.mark.sharded
class TestRouterRealShardedExecutors(TestRouterRealEngines):
    """ISSUE acceptance: N=2 REAL executors, each sharded over the
    ('kv','hd') serve mesh, behind one router — token-identical to the
    plain single-device engine.  Needs >1 XLA device
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the CI
    multidevice job); skips cleanly otherwise."""

    @pytest.fixture(scope="class")
    def mesh(self):
        import jax
        if jax.device_count() < 2:
            pytest.skip("needs >1 XLA device; set XLA_FLAGS="
                        "--xla_force_host_platform_device_count=8")
        from repro.launch.mesh import make_host_serve_mesh
        from repro.configs import get_config
        cfg = get_config("qwen2-7b", reduced=True)
        return make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)

    # inherited test_n2_token_identity_vs_single_engine runs unsharded as
    # a baseline inside this class too; the sharded variant is the point:
    def test_n2_sharded_token_identity_vs_single_engine(self, real_setup,
                                                        mesh):
        cfg = real_setup[0]
        reqs = self._workload(cfg, n=5, seed=9)
        ref_done = self._reference(real_setup, reqs)
        router, engines = self._router_over(real_setup, n=2, mesh=mesh)
        for r in reqs:
            router.submit(copy.deepcopy(r))
        done = router.run()
        assert outputs(done) == outputs(ref_done)
        assert statuses(done) == statuses(ref_done)
        for eng in engines:
            assert len(eng.executor.kv.k_pools.sharding.device_set) > 1
            eng.executor.check_sharding_invariants()
        for i in range(2):
            assert router.counters.get(f"placements_replica{i}") > 0
        router.check_invariants()


class TestRunBudgetBoundary:
    def _probe(self, max_horizon):
        sched, plane = make_replica(max_horizon=max_horizon)
        sched.submit(to_internal(req(0, plen=6, max_new=5)))
        clocks = [0]
        while sched.has_work and sched.step_i < 100:
            plane.tick(len(clocks))
            sched.step_plane()
            clocks.append(sched.step_i)
        assert not sched.has_work
        return clocks

    @pytest.mark.parametrize("max_horizon", [1, 8])
    def test_retire_on_final_tick_is_reported_in_done(self, max_horizon):
        """``run(max_steps)`` budget boundary: a request retiring exactly
        on the last permitted tick IS in ``done``; one tick less and it
        is not (the budget really binds).  Parametrized over the fused
        horizon because commit_decode advances the clock in token-steps
        mid-engine-step."""
        clocks = self._probe(max_horizon)
        final, before_final = clocks[-1], clocks[-2]
        sched, plane = make_replica(max_horizon=max_horizon)
        sched.submit(to_internal(req(0, plen=6, max_new=5)))
        # Engine.run loop verbatim: budget that admits the final step
        while sched.has_work and sched.step_i < before_final + 1:
            sched.step_plane()
        assert 0 in sched.done and sched.done[0].status == "done"
        assert len(sched.done[0].output) == 5
        assert sched.step_i == final
        # one tick less: the final step must NOT have run
        sched2, plane2 = make_replica(max_horizon=max_horizon)
        sched2.submit(to_internal(req(0, plen=6, max_new=5)))
        while sched2.has_work and sched2.step_i < before_final:
            sched2.step_plane()
        assert 0 not in sched2.done and sched2.has_work

    def test_router_run_budget_boundary(self):
        reqs = [req(i, plen=6, max_new=5) for i in range(3)]
        probe, probe_planes = make_router(2)
        for r in reqs:
            probe.submit(copy.deepcopy(r))
        probe.run(max_steps=10_000)
        final = max(rep.scheduler.step_i for rep in probe.replicas)
        assert not probe.has_work

        router, planes = make_router(2)
        for r in reqs:
            router.submit(copy.deepcopy(r))
        done = router.run(max_steps=final)
        assert statuses(done) == statuses(probe.done)
        assert not router.has_work

        # the budget really binds: with fusion disabled (one token-step
        # per engine step) one step cannot finish a 5-token request
        short, _ = make_router(2, max_horizon=1)
        for r in reqs:
            short.submit(copy.deepcopy(r))
        short.run(max_steps=1)
        assert short.has_work
