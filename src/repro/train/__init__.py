"""Fault-tolerant training loop + step factory + straggler detection."""
from repro.train.loop import Trainer, make_train_step
from repro.train.straggler import StragglerEvent, StragglerMonitor

__all__ = ["StragglerEvent", "StragglerMonitor", "Trainer", "make_train_step"]
