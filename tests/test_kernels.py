"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Every Pallas kernel is exercised in interpret mode across shape and dtype
sweeps, plus hypothesis property tests on the paged-memory kernels'
translation semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic fallback shim (see requirements-dev.txt)
    from _prop_fallback import given, settings, st

from repro.core import VMemConfig, VirtualMemory
from repro.kernels import ops, ref

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(key, shape, jnp.float32) * scale
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (256, 384, 128), (128, 512, 256),
        (100, 70, 50), (1, 128, 128), (8, 1024, 8),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        x = rand(jax.random.fold_in(KEY, m * k), (m, k), dtype)
        y = rand(jax.random.fold_in(KEY, k * n + 1), (k, n), dtype)
        out = ops.matmul(x, y, out_dtype=jnp.float32)
        expect = ref.matmul_ref(x, y, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), **tol(dtype)
        )

    def test_block_shape_sweep(self):
        x = rand(KEY, (256, 256))
        y = rand(jax.random.fold_in(KEY, 1), (256, 256))
        expect = np.asarray(x @ y)
        for bm, bn, bk in [(64, 64, 64), (128, 256, 64), (256, 128, 256)]:
            out = ops.matmul(x, y, bm=bm, bn=bn, bk=bk)
            np.testing.assert_allclose(np.asarray(out), expect,
                                       rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_gqa_and_causal(self, hq, hkv, causal):
        q = rand(KEY, (2, hq, 128, 32))
        k = rand(jax.random.fold_in(KEY, 1), (2, hkv, 128, 32))
        v = rand(jax.random.fold_in(KEY, 2), (2, hkv, 128, 32))
        out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
        expect = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("sq", [64, 100, 192])
    def test_padded_lengths(self, sq):
        q = rand(KEY, (1, 2, sq, 32))
        k = rand(jax.random.fold_in(KEY, 1), (1, 2, sq, 32))
        v = rand(jax.random.fold_in(KEY, 2), (1, 2, sq, 32))
        out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
        expect = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = rand(KEY, (1, 4, 128, 64), jnp.bfloat16)
        k = rand(jax.random.fold_in(KEY, 1), (1, 2, 128, 64), jnp.bfloat16)
        v = rand(jax.random.fold_in(KEY, 2), (1, 2, 128, 64), jnp.bfloat16)
        out = ops.flash_attention(q, k, v)
        expect = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(expect, np.float32),
            **tol(jnp.bfloat16),
        )


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------


def make_vm(page_size=8, num_pages=64, max_pages=8, max_seqs=4):
    return VirtualMemory(VMemConfig(
        page_size=page_size, num_pages=num_pages,
        max_pages_per_seq=max_pages, max_seqs=max_seqs,
    ))


class TestPagedDecodeAttention:
    @pytest.mark.parametrize("lens", [[13, 40, 1], [8, 8, 8], [64, 3, 17]])
    @pytest.mark.parametrize("g", [1, 4])
    def test_vs_ref(self, lens, g):
        vm = make_vm()
        for i, L in enumerate(lens):
            vm.map_seq(i, L)
        b, hkv, d = len(lens), 2, 32
        k_pool = rand(KEY, (64, 8, hkv, d))
        v_pool = rand(jax.random.fold_in(KEY, 1), (64, 8, hkv, d))
        q = rand(jax.random.fold_in(KEY, 2), (b, hkv, g, d))
        pt, sl = vm.device_page_table(), vm.device_seq_lens()
        out = ops.paged_decode_attention(q, k_pool, v_pool, pt, sl, page_size=8)
        expect = ref.paged_decode_attention_ref(
            q, k_pool, v_pool, pt, sl, page_size=8
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_paged_equals_contiguous(self):
        """Attention through scattered physical pages == contiguous KV."""
        vm = make_vm()
        # map/unmap to scramble physical frame order
        vm.map_seq(9, 40)
        vm.unmap_seq(9)
        vm.map_seq(0, 30)
        b, hkv, g, d = 1, 2, 2, 32
        L = 30
        k_lin = rand(KEY, (1, hkv, L, d))
        v_lin = rand(jax.random.fold_in(KEY, 1), (1, hkv, L, d))
        q = rand(jax.random.fold_in(KEY, 2), (b, hkv, g, d))
        # place linear KV into the pool through the page table
        k_pool = np.zeros((64, 8, hkv, d), np.float32)
        v_pool = np.zeros((64, 8, hkv, d), np.float32)
        phys = vm.translate(0, np.arange(L))
        k_pool.reshape(-1, hkv, d)[phys] = np.asarray(k_lin[0].swapaxes(0, 1))
        v_pool.reshape(-1, hkv, d)[phys] = np.asarray(v_lin[0].swapaxes(0, 1))
        out = ops.paged_decode_attention(
            q, jnp.asarray(k_pool), jnp.asarray(v_pool),
            vm.device_page_table(), vm.device_seq_lens(), page_size=8,
        )
        # contiguous oracle: dense attention of q over k_lin
        qf = q.reshape(1, hkv * g, 1, d)
        expect = ref.flash_attention_ref(
            qf, k_lin, v_lin, causal=False
        ).reshape(b, hkv, g, d)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=2e-4, atol=2e-4)

    def test_empty_sequence_outputs_zero(self):
        vm = make_vm()
        vm.map_seq(0, 16)
        pt = vm.device_page_table()
        sl = jnp.array([16, 0, 0, 0], jnp.int32)  # slots 1..3 empty
        k_pool = rand(KEY, (64, 8, 2, 32))
        v_pool = rand(jax.random.fold_in(KEY, 1), (64, 8, 2, 32))
        q = rand(jax.random.fold_in(KEY, 2), (4, 2, 2, 32))
        out = ops.paged_decode_attention(q, k_pool, v_pool, pt, sl, page_size=8)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_array_equal(np.asarray(out[1:]), 0.0)


# ---------------------------------------------------------------------------
# paged copy / gather
# ---------------------------------------------------------------------------


class TestPagedCopyGather:
    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(1, 60), min_size=1, max_size=3))
    def test_copy_roundtrip_gather(self, lens):
        """write-through-translation then read-through-translation == id."""
        vm = make_vm(max_seqs=len(lens))
        for i, L in enumerate(lens):
            vm.map_seq(i, L)
        w = 4
        smax = max(lens)
        src = jnp.asarray(
            np.random.default_rng(0).normal(size=(len(lens), smax, w))
        ).astype(jnp.float32)
        pool = jnp.zeros((64, 8, w))
        pool = ops.paged_copy(
            src, pool, vm.device_page_table(), jnp.asarray(lens),
            page_size=8,
        )
        for i, L in enumerate(lens):
            row = vm.device_page_table()[i]
            got = ops.paged_gather(
                pool, row, jnp.arange(L), page_size=8
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(src[i, :L]), rtol=0, atol=0
            )

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 49), min_size=1, max_size=32))
    def test_gather_arbitrary_order(self, positions):
        vm = make_vm()
        vm.map_seq(0, 50)
        pool = rand(KEY, (64, 8, 4))
        row = vm.device_page_table()[0]
        pos = jnp.asarray(positions, jnp.int32)
        out_k = ops.paged_gather(pool, row, pos, page_size=8)
        out_r = ref.paged_gather_ref(pool, row, pos, page_size=8)
        out_c = ops.paged_gather_coalesced(pool, row, pos, page_size=8)
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_r))

    def test_copy_preserves_unrelated_frames(self):
        vm = make_vm()
        vm.map_seq(0, 20)
        pool = jnp.full((64, 8, 2), 3.0)
        src = jnp.ones((1, 20, 2))
        out = ops.paged_copy(
            src, pool, vm.device_page_table()[:1], jnp.array([20]),
            page_size=8,
        )
        mapped = set(vm.seq(0).pages)
        for f in range(64):
            if f not in mapped:
                assert (np.asarray(out[f]) == 3.0).all()

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 37), st.integers(0, 18)),
                    min_size=1, max_size=3))
    def test_copy_at_offset_kernel_vs_ref(self, windows):
        """Continuation copy at arbitrary (unaligned) starts: the Pallas
        kernel must match the jnp oracle and the oracle must equal a
        hand-placed write; untouched frames keep their bytes."""
        page, w = 8, 4
        vm = make_vm(max_seqs=len(windows))
        starts = [s for s, _ in windows]
        lens = [n for _, n in windows]
        for i, (s, n) in enumerate(windows):
            vm.map_seq(i, max(s + n, 1))
        rng = np.random.default_rng(7)
        smax = max(max(lens), 1)
        src = jnp.asarray(rng.normal(size=(len(windows), smax, w))
                          ).astype(jnp.float32)
        pool0 = jnp.asarray(rng.normal(size=(64, page, w))
                            ).astype(jnp.float32)
        pt = vm.device_page_table()
        out_k = ops.paged_copy_at(
            src, pool0, pt, jnp.asarray(starts, jnp.int32),
            jnp.asarray(lens, jnp.int32), page_size=page, use_kernel=True,
        )
        out_r = ops.paged_copy_at(
            src, pool0, pt, jnp.asarray(starts, jnp.int32),
            jnp.asarray(lens, jnp.int32), page_size=page, use_kernel=False,
        )
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
        expect = np.asarray(pool0).copy()
        table = np.asarray(pt)
        for i, (s, n) in enumerate(windows):
            for t in range(n):
                pos = s + t
                expect[table[i, pos // page], pos % page] = \
                    np.asarray(src[i, t])
        np.testing.assert_array_equal(np.asarray(out_k), expect)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


class TestWKV6:
    @pytest.mark.parametrize("bh,t,n", [(2, 32, 16), (4, 48, 16), (1, 128, 64)])
    def test_vs_ref(self, bh, t, n):
        ks = jax.random.split(jax.random.fold_in(KEY, t * n), 5)
        r = rand(ks[0], (bh, t, n), scale=0.5)
        k = rand(ks[1], (bh, t, n), scale=0.5)
        v = rand(ks[2], (bh, t, n), scale=0.5)
        w = jax.nn.sigmoid(rand(ks[3], (bh, t, n)))
        u = rand(ks[4], (bh, n), scale=0.5)
        o_k, s_k = ops.wkv6(r, k, v, w, u, bt=16)
        o_r, s_r = ref.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-4, atol=1e-4)

    def test_unaligned_t_padding(self):
        ks = jax.random.split(KEY, 5)
        bh, t, n = 2, 27, 8
        r = rand(ks[0], (bh, t, n), scale=0.5)
        k = rand(ks[1], (bh, t, n), scale=0.5)
        v = rand(ks[2], (bh, t, n), scale=0.5)
        w = jax.nn.sigmoid(rand(ks[3], (bh, t, n)))
        u = rand(ks[4], (bh, n), scale=0.5)
        o_k, s_k = ops.wkv6(r, k, v, w, u, bt=8)
        o_r, s_r = ref.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-4, atol=1e-4)
        # padded identity steps must not corrupt the carried state
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-4, atol=1e-4)

    def test_chunked_equals_monolithic(self):
        """State handoff across chunks (the serving decode contract)."""
        ks = jax.random.split(KEY, 5)
        bh, t, n = 2, 64, 16
        r = rand(ks[0], (bh, t, n), scale=0.5)
        k = rand(ks[1], (bh, t, n), scale=0.5)
        v = rand(ks[2], (bh, t, n), scale=0.5)
        w = jax.nn.sigmoid(rand(ks[3], (bh, t, n)))
        u = rand(ks[4], (bh, n), scale=0.5)
        o_full, s_full = ops.wkv6(r, k, v, w, u, bt=16)
        o1, s1 = ops.wkv6(r[:, :40], k[:, :40], v[:, :40], w[:, :40], u, bt=8)
        o2, s2 = ops.wkv6(r[:, 40:], k[:, 40:], v[:, 40:], w[:, 40:], u, s1, bt=8)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([o1, o2], axis=1)),
            np.asarray(o_full), rtol=1e-4, atol=1e-4,
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   rtol=1e-4, atol=1e-4)


class TestWKV6ChunkedKernel:
    """Chunk-parallel WKV Pallas kernel (flash-linear-attention form)."""

    @pytest.mark.parametrize("bh,t,n,chunk", [
        (2, 64, 16, 16), (4, 128, 16, 32), (1, 96, 32, 32),
    ])
    def test_vs_sequential_ref(self, bh, t, n, chunk):
        from repro.kernels.wkv6_chunked import wkv6_chunked

        ks = jax.random.split(jax.random.fold_in(KEY, t * n), 6)
        r = rand(ks[0], (bh, t, n), scale=0.5)
        k = rand(ks[1], (bh, t, n), scale=0.5)
        v = rand(ks[2], (bh, t, n), scale=0.5)
        w = jax.nn.sigmoid(rand(ks[3], (bh, t, n)) - 1.0)
        u = rand(ks[4], (bh, n), scale=0.5)
        s0 = rand(ks[5], (bh, n, n), scale=0.1).astype(jnp.float32)
        o_k, s_k = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
        o_r, s_r = ref.wkv6_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=3e-4, atol=3e-4)

    def test_extreme_decay_is_stable(self):
        """Near-zero decay underflows gracefully (exponents <= 0)."""
        from repro.kernels.wkv6_chunked import wkv6_chunked

        ks = jax.random.split(KEY, 5)
        bh, t, n = 2, 64, 16
        r = rand(ks[0], (bh, t, n), scale=0.5)
        k = rand(ks[1], (bh, t, n), scale=0.5)
        v = rand(ks[2], (bh, t, n), scale=0.5)
        w = jnp.full((bh, t, n), 1e-6)  # catastrophic decay
        u = rand(ks[4], (bh, n), scale=0.5)
        o_k, s_k = wkv6_chunked(r, k, v, w, u, chunk=16)
        assert np.isfinite(np.asarray(o_k)).all()
        assert np.isfinite(np.asarray(s_k)).all()
        o_r, s_r = ref.wkv6_ref(r, k, v, w, u)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-3, atol=1e-3)
