"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Spins up the split serving engine (host Scheduler = policy plane, device
Executor = data plane; see ``repro/serve/engine.py``) on a reduced config
and reports the paper-aligned statistics: translation bursts, page faults,
context-switch bytes/cycles, page-table delta uploads, tokens/s.

All serving flags come from ``ServeConfig.add_args`` — the single flag
surface shared with the benchmarks — and the config header is
``ServeConfig.describe()``.  This driver adds only workload shape
(--requests/--prompt-len/...) and fleet shape (--replicas/--route-policy).
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models import build_model
from repro.serve import Engine, ServeConfig, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="preload a shared prefix; requests fork from it "
                         "(continuation prefill through the Executor)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="model replicas behind the ReplicaRouter: N "
                         "independent Scheduler+Executor pairs (each with "
                         "its own KV pools / page table) fed from one "
                         "global admission queue; 1 = the plain engine")
    ap.add_argument("--route-policy", default="least_loaded",
                    choices=("least_loaded", "round_robin"),
                    help="replica placement policy (fork affinity is "
                         "always enforced on top: COW forks stay on a "
                         "prefix-holding replica)")
    ap.add_argument("--stream", action="store_true",
                    help="attach a per-request stream callback: tokens are "
                         "detokenized and delivered by the background "
                         "AsyncDetokenizer thread in commit order")
    ServeConfig.add_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    if cfg.family in ("rwkv6", "hybrid_rglru"):
        raise SystemExit(
            f"{args.arch}: engine drives paged-KV transformers; recurrent "
            "families decode via model.decode_step (see examples/)"
        )
    # kernels are the default serving path everywhere (single device AND
    # mesh); --no-kernels flips the executor onto the jnp twin instead of
    # rebuilding a kernel-free model, so the hatch is visible in counters
    model = build_model(cfg, remat=False, use_kernels=True)
    params = model.init(jax.random.PRNGKey(args.seed))
    serve_cfg = ServeConfig.from_args(args, max_pages_per_seq=max(
        4, (args.prefix_len + args.prompt_len + args.max_new_tokens)
        // args.page_size + 2
    ))
    print(serve_cfg.describe())
    mesh = serve_cfg.build_mesh(cfg)
    if mesh is not None:
        print(f"serve mesh: {dict(mesh.shape)} over {mesh.size} of "
              f"{jax.device_count()} visible devices (KV pools sharded, "
              "page table replicated)")
    engines = [Engine(model, params, serve_cfg, mesh=mesh)
               for _ in range(max(1, args.replicas))]
    eng = engines[0]
    router = None
    if args.replicas > 1:
        from repro.serve import ReplicaRouter
        router = ReplicaRouter(
            [e.as_replica(i) for i, e in enumerate(engines)],
            policy=args.route_policy,
        )
        print(f"replica router: {args.replicas} replicas "
              f"({args.route_policy}; each {args.num_pages} frames, "
              f"max_batch {args.max_batch})")
    rng = np.random.default_rng(args.seed)
    share = args.prefix_len > 0
    if share:
        prefix = rng.integers(0, cfg.vocab_size,
                              size=args.prefix_len).astype(np.int32)
        for e in engines:     # every replica can parent COW forks
            e.preload_prefix(prefix)
    front = router if router is not None else eng
    streamed: list = []
    callback = streamed.append if args.stream else None
    for _ in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        shape = (plen, cfg.num_codebooks) if (
            cfg.family == "audio" and cfg.num_codebooks > 1
        ) else (plen,)
        front.submit(ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32),
            max_new_tokens=args.max_new_tokens,
            share_prefix=share,
            stream_callback=callback,
        ))
    t0 = time.perf_counter()
    results = front.drain()
    dt = time.perf_counter() - t0
    stats = eng.stats()
    total_tokens = sum(len(r.tokens) for r in results.values())
    n_done = sum(1 for r in results.values() if r.status == "done")
    n_failed = sum(1 for r in results.values() if r.status == "failed")
    print(f"completed {n_done}/{args.requests} requests "
          f"({n_failed} failed reach checks), "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU interpret)")
    finished = [r for r in results.values() if r.status == "done"]
    if finished:
        ttfts = sorted(r.ttft for r in finished)
        tpots = sorted(r.tpot for r in finished)
        mid = len(finished) // 2
        print(f"  latency: TTFT p50 {ttfts[mid] * 1e3:.1f} ms / "
              f"max {ttfts[-1] * 1e3:.1f} ms, TPOT p50 "
              f"{tpots[mid] * 1e3:.1f} ms (commit-point stamps), peak "
              f"{max(r.pages_peak for r in finished)} pages/request")
    if args.stream:
        print(f"  streamed {len(streamed)} events via AsyncDetokenizer "
              f"(backlog peak {eng.counters.get('detok_backlog_peak')})")
    if router is not None:
        r = router.counters
        print(f"router: {r.get('placements')} placements "
              f"({', '.join(str(r.get(f'placements_replica{i}')) for i in range(args.replicas))} per replica), "
              f"{r.get('migrations_declined')} migrations declined, "
              f"{r.get('cross_replica_queue_waits')} queue-wait steps")
        print("router global counters:", dict(router.global_counters()))
        print("router global pages:", router.global_page_report())
        router.check_invariants()
        print("-- replica 0 detail --")
    print("scheduler (policy plane) counters:", stats["counters"])
    print("executor (data plane): context switches:", stats["switch_stats"])
    print(f"  page-table delta uploads: "
          f"{stats['counters'].get('ptab_rows_uploaded', 0)} rows in "
          f"{stats['counters'].get('ptab_syncs', 0)} syncs over "
          f"{eng.scheduler.step_i} steps "
          f"(seed engine: {eng.scheduler.step_i * eng.cfg.max_batch} rows)")
    c = eng.counters
    print(f"  kernel dispatch: {c.get('kernel_dispatches')} kernel / "
          f"{c.get('ref_path_dispatches')} ref-path compute steps, "
          f"{c.get('prefill_bytes_gathered')} B continuation-prefill KV "
          f"gathered")
    if serve_cfg.aot_buckets:
        print(f"  aot prefill: {c.get('aot_hits')} hits / "
              f"{c.get('aot_misses')} misses, "
              f"{c.get('bucket_pad_tokens')} pad tokens")
    kp, vp = eng.kv.k_pools, eng.kv.v_pools
    per_page = (int(kp.nbytes) + int(vp.nbytes)) // kp.shape[1]
    print(f"  kv pools: dtype={kp.dtype} ({args.kv_dtype}), "
          f"{per_page} B/page across {kp.shape[1]} frames, "
          f"{c.get('quant_dispatches')} quantized compute steps")
    print(f"  fused decode horizon: mean "
          f"{c.get('decode_horizon') / max(c.get('decode_dispatches'), 1):.2f}"
          f" over {c.get('decode_dispatches')} dispatches, "
          f"{c.ratio('host_syncs', 'decode_tokens'):.3f} host syncs/token, "
          f"{c.get('horizon_collapses')} pool-pressure collapses")
    print(f"  radix prefix cache: {c.get('prefix_hits')} hits, "
          f"{c.get('pages_reused')} pages reused, "
          f"{c.get('prefill_tokens_skipped')} prefill tokens skipped, "
          f"{c.get('shared_restores')} shared restores")
    print("pool:", stats["pool"])
    for e in engines:
        e.close()


if __name__ == "__main__":
    main()
