"""Int8 gradient compression with error feedback (DP all-reduce trick).

On a 1000+-node fabric the data-parallel gradient reduction is often the
dominant collective.  This module implements the standard mitigation:
per-tensor int8 quantization with error feedback (the quantization residual
is carried into the next step, so the *accumulated* update is unbiased), and
a shard_map'd all-reduce that moves 1/4 of the bf16 bytes across the `data`
axis.

Used by ``train.make_train_step(compress_grads=True)``; the collective-bytes
delta is one of the §Perf iterations.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(x: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize (x + err) to int8 and back; return (x_hat, new_err).

    Error feedback: the residual is fed into the next step's gradient, so
    quantization noise does not accumulate as bias.
    """
    target = x.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    x_hat = dequantize_int8(q, scale)
    return x_hat.astype(x.dtype), target - x_hat


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum_tree(
    grads: Any, err: Any, mesh: jax.sharding.Mesh, axis: str = "data"
) -> tuple[Any, Any]:
    """All-reduce `grads` over `axis` in int8 with error feedback.

    Each participant quantizes its local shard-contribution, the int8 payload
    is summed (psum of int32 accumulations to avoid overflow), and the result
    is dequantized — the wire format is 1 byte/element instead of 2 (bf16) or
    4 (f32).  Implemented with shard_map so the collective is explicit in the
    lowered HLO (visible to the roofline parser).
    """

    def one(g, e):
        spec = P()  # grads enter replicated per data-shard (vmapped batch)

        @partial(
            jax.shard_map, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
        )
        def body(gl, el):
            target = gl.astype(jnp.float32) + el
            q, scale = quantize_int8(target)
            # sum int8 payloads in int32; scales via f32 psum (tiny)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.psum(scale, axis) / jax.lax.psum(1.0, axis)
            mean = qsum.astype(jnp.float32) * ssum / jax.lax.psum(1.0, axis)
            e_new = target - dequantize_int8(q, scale)
            return mean.astype(gl.dtype), e_new

        return body(g, e)

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten([o[1] for o in outs])
