"""Per-element translated gather — the indexed-access path (C2-indexed).

AraOS pays "the latency of a dedicated address translation on each vector
element" for indexed memory operations, to keep exceptions precise — the
reason spmv and canneal underperform (§3.2).  This kernel reproduces that
contract on TPU: an arbitrary-order gather through the page table where every
element is its own grid step, its own SMEM translation, and its own one-row
burst.  The translation-count asymmetry vs :mod:`paged_copy` (per-burst) is
measured by ``benchmarks/bench_translation.py``.

``ops.paged_gather`` also exposes ``coalesced=True`` — a beyond-paper
optimization (EXPERIMENTS.md §Perf) that sorts indices, gathers whole pages
once, and scatters back: per-*page* translation for indexed ops at the cost
of a sort, the software analogue of an IOMMU burst coalescer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret


def _paged_gather_kernel(pos_ref, page_table_ref, row_ref, o_ref):
    del pos_ref, page_table_ref  # consumed by the index maps
    o_ref[...] = row_ref[0]


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_gather(
    pool: jax.Array,         # [P, page, W]
    page_table_row: jax.Array,  # [max_pages] int32 — one sequence
    positions: jax.Array,    # [N] int32 logical token positions, any order
    *,
    page_size: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Gather ``pool`` rows at logical ``positions``. Returns [N, W]."""
    if interpret is None:
        interpret = should_interpret()
    n = positions.shape[0]
    _, page, w = pool.shape
    assert page == page_size

    def row_index(i, pos_ref, page_table_ref):
        # Per-element translation: every grid step walks the page table.
        p = pos_ref[i]
        frame = jnp.maximum(page_table_ref[p // page_size], 0)
        return (frame, p % page_size, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, 1, w), row_index)],
        out_specs=pl.BlockSpec((1, w), lambda i, *_: (i, 0)),
    )
    return pl.pallas_call(
        _paged_gather_kernel,
        out_shape=jax.ShapeDtypeStruct((n, w), pool.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(positions.astype(jnp.int32), page_table_row.astype(jnp.int32), pool)
