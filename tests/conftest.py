"""Shared pytest config: the `fast` marker.

Every test not explicitly marked ``slow`` is auto-marked ``fast``, so
``pytest -m fast`` runs the no-subprocess tier-1 subset without paying the
multi-minute sharding dry-run subprocesses (see scripts/check.sh).
"""

import pytest


def pytest_collection_modifyitems(config, items):
    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.fast)
