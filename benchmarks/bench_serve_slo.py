"""Open-loop SLO benchmark: Poisson arrivals against the serving router.

The closed-loop benches (seed-vs-split, replica sweep) submit everything
up front and measure steady-state throughput — which, like AraOS's point
about bare-metal vector benchmarks, is blind to the overheads users at
scale actually feel: queueing delay and first-hit jit compilation stalls.
This bench drives the production shape instead:

  * **Open loop** — requests arrive on a seeded Poisson process at target
    QPS levels and are submitted to a :class:`ReplicaRouter` as they
    become due; the router is stepped regardless, so arrival pressure and
    service rate decouple (queueing is visible).  Arrival times live in
    *engine-step time* (``STEPS_PER_SECOND`` scheduler steps per modeled
    second), so the schedule — and therefore every counter this bench
    gates on — is deterministic and independent of host wall-clock noise.
  * **AOT buckets** — the engines are built with
    ``ServeConfig.aot_buckets``, so every prefill/continuation dispatch
    must hit an executable compiled at engine build: ``aot_misses == 0``
    is gated (a miss is a potential compile stall on the serving path).
  * **Typed client surface** — requests are
    :class:`~repro.serve.api.ServeRequest` with ``stream_callback``; the
    per-request TTFT/TPOT come from :class:`~repro.serve.api.ServeResult`
    timing stamps, captured by the scheduler at host-visible commit
    points (never at detokenize).

Gates (``benchmarks/run.py --only slo``): per-request token streams
identical to a closed-loop UNBUCKETED reference engine (AOT padding and
open-loop scheduling must both be invisible in the tokens), streamed
events identical to the drained results, ``aot_misses == 0`` after
warmup with ``aot_hits > 0``, and bucket padding bounded per prefill
token.  TTFT/TPOT p50/p99 and queue depth are RECORDED into the
``section="slo"`` trajectory but never wall-clock-gated (CPU-interpret
wall time is ~5x noisy on shared runners; the deterministic counters are
the regression surface).
"""

from __future__ import annotations

import copy
from collections import deque

import numpy as np

#: scheduler steps per modeled second of arrival time.  Arrivals are
#: placed on the router's step clock, NOT the host wall clock, so the
#: admission schedule (and every gated counter) is bit-reproducible.
STEPS_PER_SECOND = 40.0

QPS_LEVELS = (2.0, 8.0)
N_REQUESTS = 8
MAX_NEW = 10


def poisson_arrival_steps(qps: float, n: int, seed: int,
                          steps_per_second: float = STEPS_PER_SECOND
                          ) -> np.ndarray:
    """Deterministic open-loop arrival schedule: ``n`` arrival times drawn
    from a seeded Poisson process at ``qps``, quantized to engine steps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / qps, size=n)
    return np.floor(np.cumsum(gaps) * steps_per_second).astype(np.int64)


def _prompts(cfg, n: int, seed: int) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, cfg.vocab_size,
                     size=int(rng.integers(4, 15))).astype(np.int32)
        for _ in range(n)
    ]


def _drive_open_loop(router, requests: list, arrivals: np.ndarray,
                     max_steps: int = 5000) -> list[int]:
    """Submit each request at its arrival step, stepping the router
    through idle gaps; returns the queue-depth trace (global + replica
    backlogs, sampled once per step)."""
    order = np.argsort(arrivals, kind="stable")
    pending = deque((int(arrivals[i]), requests[i]) for i in order)
    depths: list[int] = []
    step = 0
    while pending or router.has_work:
        if step > max_steps:
            raise RuntimeError("open-loop run did not drain")
        while pending and pending[0][0] <= step:
            router.submit(pending.popleft()[1])
        if router.has_work:
            router.step()
        depths.append(len(router.queue) + sum(
            len(rep.scheduler.queue) for rep in router.replicas
        ))
        step += 1
    return depths


def _pcts(xs: list[float]) -> tuple[float, float]:
    arr = np.asarray(xs, float)
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ReplicaRouter, ServeConfig, ServeRequest

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=16,
                            max_batch=3, aot_buckets=(8, 16))
    plain_cfg = ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=16,
                            max_batch=3)
    prompts = _prompts(cfg, N_REQUESTS, seed=7)

    def _requests(sink=None):
        return [
            ServeRequest(prompt=p.copy(), max_new_tokens=MAX_NEW, req_id=i,
                         stream_callback=sink)
            for i, p in enumerate(prompts)
        ]

    # ---- warmup: populate the module AOT cache + the decode-horizon
    # ---- ladder so the gated engines below are built entirely from
    # ---- cached executables/traces (fresh counters -> aot_misses == 0
    # ---- is checked over everything the gated runs dispatched)
    warm = Engine(model, params, serve_cfg)
    for r in _requests():
        warm.submit(copy.deepcopy(r))
    warm.drain()
    warm.close()

    # ---- closed-loop reference: UNBUCKETED engine, everything submitted
    # ---- up front — the oracle both for tokens (AOT padding must be
    # ---- invisible) and for open-vs-closed scheduling transparency
    ref_eng = Engine(model, params, plain_cfg)
    for r in _requests():
        ref_eng.submit(r)
    ref_results = ref_eng.drain()
    ref_eng.close()
    ref_tokens = {rid: [int(np.asarray(t)) for t in r.tokens]
                  for rid, r in ref_results.items()}

    levels = {}
    token_identical = True
    streams_identical = True
    aot_hits = aot_misses = pad_tokens = prefill_tokens = 0
    for qps in QPS_LEVELS:
        arrivals = poisson_arrival_steps(qps, N_REQUESTS, seed=int(qps * 10))
        streamed: dict[int, list] = {}

        def sink(ev, streamed=streamed):
            streamed.setdefault(ev.req_id, []).append(ev)

        eng = Engine(model, params, serve_cfg)     # fresh counters
        router = ReplicaRouter([eng.as_replica(0)])
        depths = _drive_open_loop(router, _requests(sink), arrivals)
        results = router.drain()
        eng.close()

        toks = {rid: [int(np.asarray(t)) for t in r.tokens]
                for rid, r in results.items()}
        token_identical &= toks == ref_tokens
        stream_toks = {
            rid: [int(np.asarray(e.token)) for e in evs]
            for rid, evs in streamed.items()
        }
        streams_identical &= stream_toks == toks

        c = eng.counters
        aot_hits += c.get("aot_hits")
        aot_misses += c.get("aot_misses")
        pad_tokens += c.get("bucket_pad_tokens")
        prefill_tokens += (c.get("prefill_tokens")
                           + c.get("continuation_prefill_tokens"))
        ttft_p50, ttft_p99 = _pcts([r.ttft for r in results.values()])
        tpot_p50, tpot_p99 = _pcts([r.tpot for r in results.values()])
        levels[f"qps{qps:g}"] = dict(
            qps=qps,
            ttft_p50_ms=ttft_p50 * 1e3, ttft_p99_ms=ttft_p99 * 1e3,
            tpot_p50_ms=tpot_p50 * 1e3, tpot_p99_ms=tpot_p99 * 1e3,
            queue_depth_peak=int(max(depths)),
            queue_depth_mean=float(np.mean(depths)),
            steps=len(depths),
            aot_hits=int(c.get("aot_hits")),
            aot_misses=int(c.get("aot_misses")),
            bucket_pad_tokens=int(c.get("bucket_pad_tokens")),
            detok_backlog_peak=int(c.get("detok_backlog_peak")),
        )
        s = levels[f"qps{qps:g}"]
        print(f"qps {qps:>4g}: TTFT p50 {s['ttft_p50_ms']:.1f} / p99 "
              f"{s['ttft_p99_ms']:.1f} ms, TPOT p50 {s['tpot_p50_ms']:.1f} "
              f"/ p99 {s['tpot_p99_ms']:.1f} ms, queue depth peak "
              f"{s['queue_depth_peak']} mean {s['queue_depth_mean']:.2f} "
              f"over {s['steps']} steps; aot {s['aot_hits']} hits / "
              f"{s['aot_misses']} misses, {s['bucket_pad_tokens']} pad "
              f"tokens, detok backlog peak {s['detok_backlog_peak']}")

    pad_per_prefill = pad_tokens / max(prefill_tokens, 1)
    print(f"token streams identical to closed-loop reference: "
          f"{token_identical}; streamed events identical to results: "
          f"{streams_identical}")
    print(f"aot after warmup: {aot_hits} hits, {aot_misses} misses, "
          f"{pad_per_prefill:.2f} pad tokens per prefill token")

    metrics = {
        "token_identical": bool(token_identical),
        "streams_identical": bool(streams_identical),
        "aot_hits": int(aot_hits),
        "aot_misses": int(aot_misses),
        "bucket_pad_tokens": int(pad_tokens),
        "bucket_pad_per_prefill_token": float(pad_per_prefill),
        "qps_levels": list(QPS_LEVELS),
        "levels": levels,
    }
    csv = [f"slo_aot_hits,0,{aot_hits}",
           f"slo_aot_misses,0,{aot_misses}",
           f"slo_bucket_pad_per_prefill_token,0,{pad_per_prefill:.4f}"]
    for name, s in levels.items():
        csv += [
            f"slo_{name}_ttft_p50_ms,0,{s['ttft_p50_ms']:.2f}",
            f"slo_{name}_ttft_p99_ms,0,{s['ttft_p99_ms']:.2f}",
            f"slo_{name}_tpot_p50_ms,0,{s['tpot_p50_ms']:.2f}",
            f"slo_{name}_tpot_p99_ms,0,{s['tpot_p99_ms']:.2f}",
            f"slo_{name}_queue_depth_peak,0,{s['queue_depth_peak']}",
        ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
