"""Unit-stride paged copy — one translation per page-bounded burst (C2-burst).

Prefill writes freshly computed K/V tokens (logical order) into physical
pages of the shared pool.  Like Ara2's VLSU, the copy is issued as unit-stride
bursts clipped at page boundaries: grid step ``(b, s)`` moves logical page
``s`` of sequence ``b`` into the physical frame the scalar-prefetched page
table names — exactly one translation per burst, performed in the output
index map *before* the store is issued.

A partially-filled tail page is handled read-modify-write: the existing frame
content is an input block at the same translated index, and tokens at or
beyond the sequence's new length keep the old bytes (precise commit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, should_interpret


def _paged_copy_kernel(
    lens_ref,         # SMEM [B]   number of valid new tokens per sequence
    page_table_ref,   # SMEM [B, max_pages]
    src_ref,          # VMEM [1, page, W]
    old_ref,          # VMEM [1, page, W]   existing frame content
    o_ref,            # VMEM [1, page, W]   the translated frame
    *,
    page_size: int,
):
    del page_table_ref
    b, s = pl.program_id(0), pl.program_id(1)
    n_valid = lens_ref[b] - s * page_size  # valid tokens in this burst
    tok = jax.lax.broadcasted_iota(jnp.int32, src_ref.shape, 1)
    o_ref[...] = jnp.where(tok < n_valid, src_ref[...], old_ref[...])


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_copy(
    src: jax.Array,          # [B, S, W] new tokens, logical order
    pool: jax.Array,         # [P, page, W] physical pool (updated)
    page_table: jax.Array,   # [B, max_pages] int32
    lens: jax.Array,         # [B] int32 — tokens of src actually valid
    *,
    page_size: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Write ``src[b, :lens[b]]`` through the page table. Returns new pool."""
    if interpret is None:
        interpret = should_interpret()
    b, s, w = src.shape
    n_frames, page, _ = pool.shape
    assert page == page_size
    n_bursts = cdiv(s, page_size)
    if s % page_size:
        src = jnp.pad(src, ((0, 0), (0, n_bursts * page_size - s), (0, 0)))

    # Bursts past a sequence's end have no mapped frame.  They must not be
    # routed to a real frame: their old_ref is the *pre-copy* pool, so a
    # read-modify-write against frame 0 would clobber fresh data written to
    # frame 0 by an earlier burst.  Route them to a trash frame instead
    # (production pools reserve this spare frame up front).
    trash = n_frames
    pool = jnp.pad(pool, ((0, 1), (0, 0), (0, 0)))

    def frame_index(bi, si, lens_ref, page_table_ref):
        del lens_ref
        entry = page_table_ref[bi, si]
        return (jnp.where(entry < 0, trash, entry), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_bursts),
        in_specs=[
            pl.BlockSpec((1, page_size, w), lambda bi, si, *_: (bi, si, 0)),
            pl.BlockSpec((1, page_size, w), frame_index),
        ],
        out_specs=pl.BlockSpec((1, page_size, w), frame_index),
    )
    out = pl.pallas_call(
        functools.partial(_paged_copy_kernel, page_size=page_size),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        grid_spec=grid_spec,
        input_output_aliases={3: 0},  # pool is updated in place
        interpret=interpret,
    )(lens.astype(jnp.int32), page_table.astype(jnp.int32),
      src.astype(pool.dtype), pool)
    return out[:-1]  # drop the trash frame
