"""Blockwise (flash) attention kernel for long prefill.

32 k-token prefill cannot materialize S = Q K^T (32k x 32k f32 = 4 GiB per
head), so attention is computed blockwise with an online softmax: grid
``(batch*q_heads, Sq/bq, Sk/bk)``, running max ``m``, normalizer ``l`` and
accumulator held in VMEM scratch across the KV sweep.

GQA is handled in the index maps: query head ``h`` reads KV head
``h // group`` — no KV replication in HBM (the bandwidth saving is the whole
point of GQA).  Causal masking compares absolute token indices derived from
the block ids; fully-masked KV blocks are skipped via ``pl.when`` (no MXU
work issued), which matters: at 32 k, half the blocks are dead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret
from repro.kernels import common

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, scale: float, causal: bool):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0]                                  # [bq, d]
        k = k_ref[0]                                  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # [bq, bk]
        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[...]                           # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                        # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)               # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    if causal:
        # Skip KV blocks strictly above the diagonal: no MXU work issued.
        pl.when(ik * bk <= iq * bq + (bq - 1))(_body)
    else:
        _body()

    @pl.when(ik == pl.num_programs(2) - 1)
    def _store():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "scale", "interpret"),
)
def flash_attention(
    q: jax.Array,                 # [B, Hq, Sq, D]
    k: jax.Array,                 # [B, Hkv, Sk, D]
    v: jax.Array,                 # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Blockwise attention with online softmax. Returns [B, Hq, Sq, D]."""
    if interpret is None:
        interpret = should_interpret()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, f"GQA requires Hq % Hkv == 0 ({hq}, {hkv})"
    group = hq // hkv
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = scale if scale is not None else d ** -0.5

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)

    def kv_index(bh, iq, ik):
        # query head bh = bi*hq + h  ->  kv row bi*hkv + h // group
        return ((bh // hq) * hkv + (bh % hq) // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, bq=bq, bk=bk, scale=scale, causal=causal
        ),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        grid=(b * hq, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running normalizer
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
