#!/usr/bin/env python3
"""Serve-perf regression gate over the ``BENCH_serve.json`` trajectory.

Compares the NEWEST entry (appended by the ``benchmarks/run.py --only
serve`` gate that just ran) against the PREVIOUS one on *deterministic
counters only*:

  * ``host_syncs_per_token``  — forced device->host transfers per decoded
    token (lower is better; the fused horizon's amortization contract);
  * ``ptab_syncs_per_token``  — page-table delta uploads per decoded token
    (lower is better; the delta-only satp contract);
  * ``mean_horizon``          — mean fused decode horizon K (higher is
    better; detects the horizon silently collapsing).

Never wall-clock tok/s: on shared CI/dev CPUs those swing up to 5x between
runs, while the counters are exact scheduler/executor event counts — same
code + same workload = same values, so any drift is a code change, not
noise.  The tiny relative slack below only forgives float formatting, not
behavior.

Exit status: 0 when there is nothing to compare (missing file, fewer than
two entries) or no counter regressed; 1 on regression, with one line per
offending counter.  Usage: ``python scripts/bench_regress.py [path]``.
"""

from __future__ import annotations

import json
import pathlib
import sys

REL_SLACK = 1e-6    # float round-trip noise, not a behavioral allowance

#: per-section (name, extractor, direction): "le" = new must stay <=
#: prev, "ge" = >=.  ``BENCH_serve.json`` interleaves records from the
#: ``serve``, ``sharded``, ``router``, ``prefix``, ``quant``, ``slo``
#: and ``migrate`` gates
#: (tagged with a "section" field; untagged legacy records read as ``serve`` for
#: backward compatibility, though the checked-in trajectory is fully
#: tagged — ``tests/test_benchmarks.py`` asserts that), so each section
#: is compared against its OWN previous record — never serve-vs-router.
CHECKS_BY_SECTION = {
    "serve": (
        ("host_syncs_per_token",
         lambda m: float(m["host_syncs_per_token"]), "le"),
        ("ptab_syncs_per_token",
         lambda m: float(m["sweep"]["auto"]["ptab_syncs_per_tok"]), "le"),
        ("mean_horizon",
         lambda m: float(m["mean_horizon"]), "ge"),
    ),
    "router": (
        ("host_syncs_per_token",
         lambda m: float(m["host_syncs_per_token"]), "le"),
        ("ptab_syncs_per_token",
         lambda m: float(m["sweep"]["2"]["ptab_syncs_per_tok"]), "le"),
        ("mean_horizon",
         lambda m: float(m["mean_horizon"]), "ge"),
    ),
    # the sharded gate: the kernel path's modeled continuation-prefill KV
    # gather volume must never creep back toward the ref path's, no step
    # may slip back onto the jnp twin, and the kernel dispatch count is an
    # exact event count (same workload = same value in both directions)
    "sharded": (
        ("prefill_bytes_gathered",
         lambda m: float(m["prefill_bytes_gathered_kernel"]), "le"),
        ("ref_path_dispatches",
         lambda m: float(m["ref_path_dispatches"]), "le"),
        ("kernel_dispatches",
         lambda m: float(m["kernel_dispatches"]), "ge"),
    ),
    # the radix-prefix gate: counters only (token identity and the >0.5
    # skip-ratio floor live in ``benchmarks/run.py --only prefix``; this
    # gate catches the cache silently matching/reusing LESS on the same
    # multi-turn workload — exact scheduler event counts, zero noise)
    "prefix": (
        ("prefix_hits",
         lambda m: float(m["prefix_hits"]), "ge"),
        ("prefill_tokens_skipped",
         lambda m: float(m["prefill_tokens_skipped"]), "ge"),
    ),
    # the open-loop SLO gate: aot_misses must stay at 0 (any miss is a
    # potential first-hit compile stall on the serving path) and the
    # bucket padding per prefill token must never creep up (buckets
    # silently coarsening).  TTFT/TPOT are recorded in the same records
    # but NEVER gated — wall-clock on shared runners is ~5x noisy; the
    # counters are exact dispatch-event counts
    "slo": (
        ("aot_misses",
         lambda m: float(m["aot_misses"]), "le"),
        ("bucket_pad_per_prefill_token",
         lambda m: float(m["bucket_pad_per_prefill_token"]), "le"),
    ),
    # the migration gate: with migration ON nothing may ever fail as
    # unreachable (hard 0-vs-0 in practice — "le" vs the previous record
    # keeps the check meaningful even if the floor ever moved), and the
    # scenario's rescue volume must never shrink: fewer migrations or
    # partial restores on the SAME skewed workload means victims waited
    # out the outage (or failed) instead of being moved/partially
    # restored — exact scheduler/router event counts, zero noise
    "migrate": (
        ("failed_unreachable_migrate",
         lambda m: float(m["failed_unreachable_migrate"]), "le"),
        ("restore_migrations",
         lambda m: float(m["restore_migrations"]), "ge"),
        ("partial_restores",
         lambda m: float(m["partial_restores"]), "ge"),
        ("swap_record_leaks",
         lambda m: float(m["swap_record_leaks"]), "le"),
    ),
    # the quantized-KV gate: bytes-per-page must never creep back up
    # (quantization silently widening), the greedy top-1 accuracy
    # envelope vs the fp engine must never shrink, and no quantized step
    # may slip onto the jnp twin — counters/accuracy only, never tok/s
    "quant": (
        ("bytes_per_page_int8",
         lambda m: float(m["bytes_per_page_int8"]), "le"),
        ("top1_agreement",
         lambda m: float(m["top1_agreement"]), "ge"),
        ("ref_path_dispatches_int8",
         lambda m: float(m["ref_path_dispatches_int8"]), "le"),
    ),
}


def compare(prev: dict, new: dict, section: str = "serve") -> list[str]:
    """Regression messages comparing two metric records (empty = pass)."""
    failures = []
    for name, extract, direction in CHECKS_BY_SECTION[section]:
        try:
            p, n = extract(prev), extract(new)
        except (KeyError, TypeError):
            # an older record predates this counter — nothing to gate on
            continue
        if direction == "le" and n > p * (1 + REL_SLACK) + 1e-12:
            failures.append(
                f"[{section}] {name} regressed: {p:.6f} -> {n:.6f} "
                "(must not increase)")
        elif direction == "ge" and n < p * (1 - REL_SLACK) - 1e-12:
            failures.append(
                f"[{section}] {name} regressed: {p:.6f} -> {n:.6f} "
                "(must not decrease)")
    return failures


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[1]) if len(argv) > 1 else (
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    )
    if not path.exists():
        print(f"bench_regress: {path} missing — nothing to compare")
        return 0
    history = json.loads(path.read_text())
    if not isinstance(history, list) or len(history) < 2:
        print(f"bench_regress: {path.name} has "
              f"{len(history) if isinstance(history, list) else '?'} "
              "record(s) — need two to compare")
        return 0
    failures: list[str] = []
    for section in CHECKS_BY_SECTION:
        recs = [r for r in history
                if r.get("section", "serve") == section]
        if len(recs) < 2:
            print(f"bench_regress: {len(recs)} {section} record(s) — "
                  "need two to compare")
            continue
        prev, new = recs[-2], recs[-1]
        section_failures = compare(prev["metrics"], new["metrics"], section)
        failures += section_failures
        if not section_failures:
            print(f"bench_regress: {section} counters OK "
                  f"({prev['t']} -> {new['t']})")
    for f in failures:
        print(f"FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
