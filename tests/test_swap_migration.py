"""Cross-replica swap migration + partial restore acceptance suite
(marker: ``router``).

The tentpole contract under test: **no request fails while any replica
can host it**.  Swap records are portable (``Scheduler.export_swapped`` /
``import_swapped`` over ``DataPlane.export_swap`` / ``import_swap``), so
the router migrates starved or about-to-fail swap victims to replicas
with headroom (``restore_migrations``), and a capacity-blocked FIFO head
that out-waits ``restore_patience`` comes back as the longest
page-aligned prefix that fits plus a re-prefilled tail
(``partial_restores`` / ``pages_refilled``).  Every path is pinned to the
fault-free closed-form token stream — migration and partial restore are
timing policies, never token policies.

Satellite leak audit: every terminal path for a spilled request —
failed-as-unreachable, migration source, partial restore, plain drain —
must leave the data plane holding NO swap record
(``FaultyDataPlane.swapped_out`` / ``ContextSwitcher.swapped_out`` empty).
"""

import collections
import copy

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # pragma: no cover
    from _prop_fallback import given, settings, st

from _fault_plane import (
    drive,
    drive_router,
    expected_output,
    make_replica,
)
from repro.serve import Replica, ReplicaRouter, ServeRequest, to_internal

pytestmark = pytest.mark.router


def req(i, plen=6, max_new=8, **kw):
    return ServeRequest(req_id=i, prompt=np.arange(plen, dtype=np.int32),
                        max_new_tokens=max_new, **kw)


def make_router(n, schedules=None, per_replica=None, migrate=True,
                migrate_after=2, **kw):
    """N fault-plane replicas behind a migrating router.

    ``per_replica``: optional dict of replica_id -> make_replica kwargs
    overriding ``kw`` (heterogeneous pools)."""
    replicas, planes = [], []
    for r in range(n):
        rkw = dict(kw)
        rkw.update((per_replica or {}).get(r, {}))
        sched, plane = make_replica(
            replica_id=r, schedule=(schedules or {}).get(r, ()), **rkw
        )
        replicas.append(Replica(replica_id=r, scheduler=sched, plane=plane))
        planes.append(plane)
    return ReplicaRouter(replicas, migrate=migrate,
                         migrate_after=migrate_after), planes


def outputs(done):
    return {rid: [int(x) for x in r.output] for rid, r in done.items()}


def statuses(done):
    return sorted((rid, r.status) for rid, r in done.items())


def assert_no_swap_records(planes):
    for i, plane in enumerate(planes):
        assert plane.swapped_out == [], (
            f"plane {i} leaked swap records: {plane.swapped_out}"
        )


# ---------------------------------------------------------------------------
# starvation migration: a capacity-starved victim moves to a replica with
# immediate headroom instead of waiting out the source's outage
# ---------------------------------------------------------------------------


class TestStarvationMigration:
    def _starved_pair(self, schedules_extra=(), migrate=True):
        """Replica 0 spills req 0 at step 3 and a hog then holds its whole
        pool for 60 steps; replica 1 idles with room to spare."""
        schedules = {0: (("force_spill", 3, 0), ("hog", 3, 16, 60))
                     + tuple(schedules_extra)}
        router, planes = make_router(
            2, schedules=schedules, migrate=migrate, migrate_after=2,
            usable_pages=8, max_batch=2, max_horizon=1,
        )
        reqs = [req(0, plen=6, max_new=10), req(1, plen=6, max_new=4)]
        for r in reqs:
            router.submit(copy.deepcopy(r))
        return router, planes, reqs

    def test_starved_victim_migrates_and_completes_token_identically(self):
        router, planes, reqs = self._starved_pair()
        steps = drive_router(router, planes)
        assert steps < 500 and not router.has_work
        total = router.global_counters()
        assert total["restore_migrations"] == 1
        assert total["swap_exports"] == 1 and total["swap_imports"] == 1
        assert total["failed_unreachable"] == 0
        # the victim restored and finished on the DESTINATION plane
        assert ("import_swap", 0) in planes[1].events
        assert ("restore", 0) in planes[1].events
        assert ("restore", 0) not in planes[0].events
        # migration is a timing policy, never a token policy
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        assert statuses(router.done) == [(0, "done"), (1, "done")]
        assert_no_swap_records(planes)
        router.check_invariants()
        # the migrate snapshot names (victim, src, dest)
        migs = [s.payload for s in router.counters.events("migrate")]
        assert migs == [(0, 0, 1)]

    def test_migration_off_waits_out_the_outage(self):
        """The same starvation with ``migrate=False``: no export/import,
        the victim just restores late at the source — the baseline the
        benchmark gate diffs against."""
        router, planes, reqs = self._starved_pair(migrate=False)
        steps = drive_router(router, planes)
        assert steps < 500 and not router.has_work
        total = router.global_counters()
        assert total["restore_migrations"] == 0
        assert total["swap_exports"] == 0
        assert ("restore", 0) in planes[0].events
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        assert_no_swap_records(planes)
        router.check_invariants()


# ---------------------------------------------------------------------------
# rescue migration: the PR 2 "failed as unreachable" verdict survives only
# when NO replica can host the pinned-prefix-adjusted demand
# ---------------------------------------------------------------------------


class TestRescueMigration:
    def _stranded_on_small_replica(self, migrate):
        """A victim whose spilled footprint exceeds the small replica's
        whole pool, imported there scheduler-plane (modeling a historical
        reach-blind placement): replica 0 can NEVER restore it, replica 1
        can."""
        router, planes = make_router(
            2, migrate=migrate, migrate_after=2,
            usable_pages=15, max_batch=2, max_horizon=1,
            per_replica={0: {"usable_pages": 4, "max_pages": 8}},
        )
        # a short filler loads replica 0 so least-loaded places the victim
        # on replica 1 in BOTH modes (with migrate=True the reach filter
        # would route it there anyway)
        router.submit(req(9, plen=4, max_new=2))
        r = req(0, plen=11, max_new=8)
        router.submit(copy.deepcopy(r))
        s0 = router.replicas[0].scheduler
        s1 = router.replicas[1].scheduler
        assert router.counters.get("placements_replica1") == 1
        # decode on replica 1 until the mapped footprint outgrows replica
        # 0's entire pool, then strand the spilled record there
        steps = 0
        while not (0 in s1.running
                   and s1.vmem.config.pages_for(s1.vmem.seq_len(0))
                   > s0.attainable_pages()):
            steps += 1
            assert steps < 100
            for p in planes:
                p.tick(steps)
            router.step()
        s1.spill(s1.running[0])
        s0.import_swapped(s1.export_swapped(0))
        return router, planes, r

    def test_rescue_migrates_instead_of_failing(self):
        router, planes, r = self._stranded_on_small_replica(migrate=True)
        assert drive_router(router, planes) < 500
        total = router.global_counters()
        assert total["restore_migrations"] == 1
        assert total["failed_unreachable"] == 0
        assert router.done[0].status == "done"
        assert outputs(router.done)[0] == expected_output(r)
        assert_no_swap_records(planes)
        router.check_invariants()

    def test_without_migration_the_unreachable_verdict_stands(self):
        """migrate=False: the stranded victim is failed fast at the small
        replica — and the leak audit's failed-unreachable path must
        discard the host-side swap record."""
        router, planes, r = self._stranded_on_small_replica(migrate=False)
        assert drive_router(router, planes) < 500
        total = router.global_counters()
        assert total["restore_migrations"] == 0
        assert total["failed_unreachable"] == 1
        assert router.done[0].status == "failed"
        assert ("discard", 0) in planes[0].events
        assert_no_swap_records(planes)
        router.check_invariants()

    def test_reach_aware_placement_counts_redirects(self):
        router, planes = make_router(
            2, usable_pages=15, max_batch=2, max_horizon=1,
            per_replica={0: {"usable_pages": 4, "max_pages": 8}},
        )
        # lifetime pf(11 + 7) = 5 pages > replica 0's 4: the least-loaded
        # baseline (tie -> replica 0) must be overridden by reach
        router.submit(req(0, plen=11, max_new=8))
        assert router.counters.get("reach_redirects") == 1
        assert router.counters.get("placements_replica1") == 1
        assert drive_router(router, planes) < 500
        assert router.global_counters()["failed_unreachable"] == 0
        router.check_invariants()


# ---------------------------------------------------------------------------
# migration faults: rejected imports, destinations filling mid-import,
# victims retiring before the sweep reaches them
# ---------------------------------------------------------------------------


class TestMigrationFaults:
    def test_rejected_import_rolls_back_at_source_head_then_retries(self):
        """The destination plane rejects the first import (raised BEFORE
        side effects): the router must re-import at the SOURCE HEAD
        (FIFO unchanged), count ``migration_aborts``, and succeed on a
        later sweep once the injection clears."""
        schedules = {0: (("force_spill", 3, 0), ("hog", 3, 16, 60)),
                     1: (("reject_import", 1, 0, 1),)}
        router, planes = make_router(
            2, schedules=schedules, migrate_after=2,
            usable_pages=8, max_batch=2, max_horizon=1,
        )
        reqs = [req(0, plen=6, max_new=10), req(1, plen=6, max_new=4)]
        for r in reqs:
            router.submit(copy.deepcopy(r))
        steps = drive_router(router, planes)
        assert steps < 500 and not router.has_work
        total = router.global_counters()
        assert total["migration_aborts"] == 1
        assert total["restore_migrations"] == 1
        # abort path: export, rejected import, re-import at source, then
        # the retried export/import pair
        assert total["swap_exports"] == 2
        assert total["swap_imports"] == 2
        assert ("import_rejected", 0) in planes[1].events
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        assert_no_swap_records(planes)
        router.check_invariants()

    def test_destination_fills_mid_import_blocks_then_restores_there(self):
        """hog composed on the destination right after the migration
        lands: the import succeeds but the restore is capacity-blocked at
        the destination until the hog releases — degraded, never failed,
        never migrated back to the still-hogged source."""
        schedules = {0: (("force_spill", 3, 0), ("hog", 3, 16, 60)),
                     1: (("hog", 6, 16, 12),)}
        router, planes = make_router(
            2, schedules=schedules, migrate_after=2,
            usable_pages=8, max_batch=2, max_horizon=1,
        )
        reqs = [req(0, plen=6, max_new=10), req(1, plen=6, max_new=4)]
        for r in reqs:
            router.submit(copy.deepcopy(r))
        steps = drive_router(router, planes)
        assert steps < 500 and not router.has_work
        total = router.global_counters()
        assert total["restore_migrations"] == 1
        assert total["failed_unreachable"] == 0
        assert ("import_swap", 0) in planes[1].events
        assert ("restore", 0) in planes[1].events
        assert outputs(router.done) == {
            r.req_id: expected_output(r) for r in reqs
        }
        assert_no_swap_records(planes)
        router.check_invariants()

    def test_export_of_a_retired_victim_raises_keyerror(self):
        """'Victim retired during migration': the head-only sweep makes
        the in-process race impossible, so the API contract is a hard
        KeyError for any rid that is no longer swapped."""
        sched, plane = make_replica(max_horizon=1)
        sched.submit(to_internal(req(0, plen=6, max_new=4)))
        drive(sched, plane)
        assert sched.done[0].status == "done"
        with pytest.raises(KeyError, match="not swapped"):
            sched.export_swapped(0)


# ---------------------------------------------------------------------------
# partial restore: the longest page-aligned prefix that fits comes back
# now, the evicted tail re-prefills through the continuation path
# ---------------------------------------------------------------------------


class TestPartialRestore:
    def test_partial_restore_reprefills_tail_token_identically(self):
        """Head blocked by a hog holding most (not all) of the pool:
        after ``restore_patience`` blocked passes the victim returns as a
        kept prefix + re-prefilled tail instead of waiting for the
        all-or-nothing restore — same stream, no ``restores`` increment,
        record consumed."""
        sched, plane = make_replica(
            page_size=4, usable_pages=8, max_pages=8, max_batch=2,
            max_horizon=1, restore_patience=2,
            schedule=(("force_spill", 4, 0), ("hog", 4, 6, 10)),
        )
        r = req(0, plen=8, max_new=8)
        sched.submit(to_internal(r))
        steps = drive(sched, plane, max_steps=300)
        assert steps < 300 and not sched.has_work
        assert sched.counters.get("partial_restores") == 1
        assert sched.counters.get("pages_refilled") >= 1
        assert sched.counters.get("restores") == 0    # never fully restored
        assert sched.counters.get("failed_unreachable") == 0
        # the partial restore re-mapped a page-aligned prefix via the
        # plane (consuming the record) and re-prefilled the tail through
        # the batched continuation dispatch
        assert ("restore", 0) in plane.events
        assert any(e[0] == "admit_forked_batch" for e in plane.events)
        assert sched.done[0].status == "done"
        assert [int(x) for x in sched.done[0].output] == expected_output(r)
        assert plane.swapped_out == []
        assert sched.state.partial_resume == {}
        sched.vmem.check_invariants()

    def test_patience_zero_disables_partial_restore(self):
        sched, plane = make_replica(
            page_size=4, usable_pages=8, max_pages=8, max_batch=2,
            max_horizon=1, restore_patience=0,
            schedule=(("force_spill", 4, 0), ("hog", 4, 6, 10)),
        )
        r = req(0, plen=8, max_new=8)
        sched.submit(to_internal(r))
        steps = drive(sched, plane, max_steps=300)
        assert steps < 300 and not sched.has_work
        assert sched.counters.get("partial_restores") == 0
        assert sched.counters.get("restores") == 1    # waited out the hog
        assert [int(x) for x in sched.done[0].output] == expected_output(r)
        assert plane.swapped_out == []
        sched.vmem.check_invariants()


# ---------------------------------------------------------------------------
# the headline property, migration enabled: token identity with the
# fault-free N=1 reference + global accounting == replica sums
# ---------------------------------------------------------------------------


USABLE_PAGES = 8


def gen_workload(rng):
    n = int(rng.integers(2, 9))
    return [req(i, plen=int(rng.integers(1, 13)),
                max_new=int(rng.integers(1, 11))) for i in range(n)]


def gen_faults(rng, reqs, steps_hi=30):
    """Migration-heavy schedules: spills chased by pool-hogging windows
    (the starvation shape), plus the PR 2 fault menagerie."""
    events = []
    rids = [r.req_id for r in reqs]
    for _ in range(int(rng.integers(0, 5))):
        kind = ["hog", "force_spill", "fail_restore", "delay_done",
                "starve", "reject_import"][int(rng.integers(0, 6))]
        step = int(rng.integers(1, steps_hi))
        rid = int(rng.choice(rids))
        if kind == "hog":
            events.append(("hog", step, int(rng.integers(1, 4)),
                           int(rng.integers(1, 7))))
        elif kind == "force_spill":
            events.append(("force_spill", step, rid))
        elif kind == "fail_restore":
            events.append(("fail_restore", step, rid,
                           int(rng.integers(1, 4))))
        elif kind == "delay_done":
            events.append(("delay_done", step, rid,
                           int(rng.integers(1, 4))))
        elif kind == "starve":
            events.append(("force_spill", step, rid))
            events.append(("hog", step, USABLE_PAGES * 2,
                           int(rng.integers(4, 16))))
        else:
            events.append(("reject_import", step, rid,
                           int(rng.integers(1, 3))))
    return tuple(events)


class TestMigrationEnabledSweep:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_token_identity_and_accounting_with_migration(self, seed):
        rng = np.random.default_rng(seed)
        reqs = gen_workload(rng)

        ref, ref_planes = make_router(1, usable_pages=USABLE_PAGES)
        for r in reqs:
            ref.submit(copy.deepcopy(r))
        assert drive_router(ref, ref_planes) < 500
        ref_done = dict(ref.done)
        ref_out = outputs(ref_done)
        assert ref_out == {r.req_id: expected_output(r) for r in reqs}

        for n in (1, 2, 4):
            schedules = {i: gen_faults(rng, reqs) for i in range(n)}
            router, planes = make_router(n, schedules=schedules,
                                         migrate_after=2,
                                         usable_pages=USABLE_PAGES)
            for r in reqs:
                router.submit(copy.deepcopy(r))
            steps = drive_router(router, planes)
            assert steps < 500, f"N={n}: starvation (drive never drained)"
            done = router.done
            assert outputs(done) == ref_out, f"N={n} diverged"
            assert statuses(done) == statuses(ref_done)
            router.check_invariants()
            # global accounting equals the sum of replica accounting,
            # recomputed by hand (not via the router's own helper)
            manual = collections.Counter()
            for rep in router.replicas:
                manual.update(rep.scheduler.counters.counters)
            manual.update(router.counters.counters)
            assert router.global_counters() == manual
            # migration bookkeeping balances: every completed migration is
            # one export/import pair, every abort adds a rollback import
            total = router.global_counters()
            assert total["swap_exports"] == (total["restore_migrations"]
                                             + total["migration_aborts"])
            assert total["swap_imports"] == total["swap_exports"]
            # the leak audit, swept across every random schedule: no plane
            # holds a swap record at drain
            assert_no_swap_records(planes)
            assert total["completed"] + total["failed_unreachable"] \
                == len(reqs)
            assert total["failed_unreachable"] == 0   # homogeneous fleet


# ---------------------------------------------------------------------------
# real engines: the leak audit on the REAL ContextSwitcher, and a rescue
# migration moving actual KV page bytes between device pools
# ---------------------------------------------------------------------------


class TestRealEngineSwapRecords:
    @pytest.fixture(scope="class")
    def model_setup(self):
        import jax

        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen2-7b", reduced=True)
        model = build_model(cfg, remat=False)
        return cfg, model, model.init(jax.random.PRNGKey(0))

    def test_switcher_holds_no_records_at_drain_under_preemption(
            self, model_setup):
        """Satellite leak audit on the real plane: a tight pool forces
        spill/restore churn; at drain the ContextSwitcher must hold no
        swap record (every spill was restored, exported or discarded)."""
        from repro.serve import Engine, ServeConfig
        cfg, model, params = model_setup
        eng = Engine(model, params, ServeConfig(
            page_size=4, num_pages=10, max_pages_per_seq=16, max_batch=3))
        rng = np.random.default_rng(11)
        reqs = [ServeRequest(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(6, 11))
                                ).astype(np.int32),
            max_new_tokens=8) for i in range(5)]
        for r in reqs:
            eng.submit(copy.deepcopy(r))
        done = eng.run()
        assert all(r.status == "done" for r in done.values())
        assert eng.counters.get("preemptions") > 0   # churn really happened
        assert eng.switcher.swapped_out == []
        eng.vmem.check_invariants()

    def test_rescue_migration_moves_real_kv_between_pools(self, model_setup):
        """A spilled victim stranded on a real small-pool replica is
        rescued to the roomy replica — its exported host-side KV pages
        re-enter the destination pool and greedy decode continues
        token-identically to the untouched single-engine run."""
        from repro.serve import Engine, ServeConfig
        cfg, model, params = model_setup
        big_cfg = ServeConfig(page_size=4, num_pages=64,
                              max_pages_per_seq=32, max_batch=3,
                              max_horizon=1)
        small_cfg = ServeConfig(page_size=4, num_pages=8,
                                max_pages_per_seq=8, max_batch=3,
                                max_horizon=1)
        rng = np.random.default_rng(7)
        prompt = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
        sreq = ServeRequest(req_id=0, prompt=prompt, max_new_tokens=8)

        ref = Engine(model, params, big_cfg)
        ref.submit(copy.deepcopy(sreq))
        ref_out = [int(x) for x in ref.run()[0].output]

        small = Engine(model, params, small_cfg)
        big = Engine(model, params, big_cfg)
        router = ReplicaRouter(
            [small.as_replica(0), big.as_replica(1)], migrate_after=2)
        router.submit(copy.deepcopy(sreq))
        # lifetime pf(24 + 7) = 8 pages > the small replica's 7: the
        # reach filter must place it on the roomy replica
        assert router.counters.get("reach_redirects") == 1
        assert router.counters.get("placements_replica1") == 1
        s0, s1 = small.scheduler, big.scheduler
        steps = 0
        while not (0 in s1.running
                   and s1.vmem.config.pages_for(s1.vmem.seq_len(0))
                   > s0.attainable_pages()):
            steps += 1
            assert steps < 100
            router.step()
        s1.spill(s1.running[0])
        s0.import_swapped(s1.export_swapped(0))     # strand it: real bytes
        assert small.switcher.swapped_out == [0]
        done = router.run()
        assert router.counters.get("restore_migrations") == 1
        assert router.global_counters()["failed_unreachable"] == 0
        assert done[0].status == "done"
        assert [int(x) for x in done[0].output] == ref_out
        assert small.switcher.swapped_out == []
        assert big.switcher.swapped_out == []
        router.check_invariants()
