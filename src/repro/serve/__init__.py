"""Serving: continuous batching over paged virtual memory (the "OS").

Split per the AraOS architecture: :class:`Scheduler` is the host-side
CVA6/OS plane (policy, no device arrays), :class:`Executor` is the
device-resident Ara2 data plane (KV pools, persistent page table, jitted
steps), and :class:`Engine` is the thin facade wiring them together.
:class:`ReferenceEngine` is the frozen pre-split seed implementation kept
for equivalence testing and before/after benchmarks.
"""
from repro.serve.engine import Engine
from repro.serve.executor import Executor
from repro.serve.reference import ReferenceEngine
from repro.serve.scheduler import (
    DataPlane,
    DecodePlan,
    HostOnlyPlane,
    Request,
    Scheduler,
    ServeConfig,
)

__all__ = [
    "DataPlane",
    "DecodePlan",
    "Engine",
    "Executor",
    "HostOnlyPlane",
    "ReferenceEngine",
    "Request",
    "Scheduler",
    "ServeConfig",
]
