"""Sharding rules: parameters (TP + FSDP), optimizer state (ZeRO), batches,
activations.

Strategy (DESIGN.md §3):
  * TP over `model`: per-role dimension — attention head projections, FFN
    hidden, expert index, vocabulary;
  * FSDP over `data` (intra-pod only): the *other* large dimension of every
    weight is sharded over the data axis; XLA inserts per-layer all-gathers
    (prefetchable) and the optimizer state inherits the full sharding
    (ZeRO-3-equivalent memory);
  * pure DP over `pod`: gradients cross pods only once per step;
  * activations: batch over (pod, data); sequence over `model` between
    blocks (Megatron-style sequence parallelism) — the shard hook.

Every rule degrades gracefully: a dimension that does not divide its mesh
axis is left unsharded (e.g. granite-moe's 49155 vocab).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import dp_axes, fsdp_axis

# role -> which dim gets the `model` axis (after the stacked-layer dim is
# stripped).  Everything else: biases, norms, scalars -> replicated.
_MODEL_DIM_BY_NAME: dict[str, int] = {
    # [in, out]-style projections: shard the output (hidden/head) dim
    "wq": 1, "wk": 1, "wv": 1, "w_gate": 1, "w_up": 1, "wg": 1,
    "wr": 1, "wi": 1, "w_x": 1, "w_i": 1,
    # output projections: shard the input dim (row-parallel)
    "wo": 0, "w_down": 0, "w_out": 0,
    # embeddings / heads: vocab-parallel
    "embed": 0, "head": 1,
    # moe experts [E, D, F]: expert-parallel
    "w_gate_moe": 0, "w_up_moe": 0, "w_down_moe": 0,
}


def _spec_for(path: tuple[str, ...], shape: tuple[int, ...],
              mesh: jax.sharding.Mesh, use_fsdp: bool = True,
              model_axes: tuple[str, ...] = ("model",)) -> P:
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
    leaf = names[-1]
    msize = 1
    for a in model_axes:
        msize *= mesh.shape[a]
    model_assign = model_axes if len(model_axes) > 1 else model_axes[0]
    fsdp = fsdp_axis(mesh) if use_fsdp else None
    fsize = mesh.shape[fsdp] if fsdp else 1

    # stacked-layer leading dim (scan stacks): never sharded
    stacked = int(names[0] in ("blocks", "supers", "tail"))
    dims: list[Any] = [None] * len(shape)
    if len(shape) - stacked < 1 or leaf in ("scale", "lam", "w0", "u",
                                            "conv_w", "mu", "ln_x"):
        return P(*dims)
    is_moe = any(n == "mlp" for n in names) and len(shape) - stacked == 3
    key = leaf + "_moe" if (is_moe and leaf in ("w_gate", "w_up", "w_down")) \
        else leaf
    model_dim = _MODEL_DIM_BY_NAME.get(key)
    if key.startswith("mu_") or key.startswith("b"):
        return P(*dims)
    if model_dim is None:
        # unknown 2D+ leaf: try FSDP on the largest dim only
        model_dim = -1
    if model_dim >= 0:
        d = model_dim + stacked
        if is_moe and not use_fsdp and d < len(shape):
            # serving MoE: expert weights are the bulk of the model — shard
            # the expert dim over data x model axes too (expert parallelism;
            # the dispatch all-to-all crosses data groups).  Largest
            # divisible combination wins.
            for combo in (("data",) + model_axes,
                          ("data", model_axes[0]),
                          model_axes,
                          (model_axes[0],)):
                prod = 1
                for a in combo:
                    prod *= mesh.shape.get(a, 1)
                if shape[d] % prod == 0:
                    dims[d] = combo if len(combo) > 1 else combo[0]
                    break
            # remaining per-expert dims: spread leftover model axes on F
            if (isinstance(dims[d], tuple) and "data" in dims[d]
                    and len(dims[d]) < 1 + len(model_axes)):
                rest = tuple(a for a in model_axes if a not in dims[d])
                for dd in range(len(shape) - 1, stacked, -1):
                    if dims[dd] is None and rest:
                        prod = 1
                        for a in rest:
                            prod *= mesh.shape[a]
                        if shape[dd] % prod == 0:
                            dims[dd] = rest if len(rest) > 1 else rest[0]
                            break
        elif d < len(shape) and shape[d] % msize == 0:
            dims[d] = model_assign
    # FSDP: largest remaining divisible dim.  EXCEPT for embed/head with an
    # indivisible vocab: their only shardable dim is the matmul CONTRACTION
    # dim (d_model), and contraction-sharding turns every logits product
    # into a full [B,S,V] psum — measured 227 GB/step of all-reduce on
    # granite-moe (EXPERIMENTS.md §Perf D-1).  Replicate them instead
    # (the table is small precisely when the vocab is odd-sized).
    if leaf in ("embed", "head") and all(d is None for d in dims):
        return P(*dims)
    if fsdp:
        cands = [
            i for i in range(stacked, len(shape))
            if dims[i] is None and shape[i] % fsize == 0 and shape[i] >= fsize
        ]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            dims[best] = fsdp
    return P(*dims)


def param_shardings(params_shape: Any, mesh: jax.sharding.Mesh,
                    use_fsdp: bool = True,
                    model_axes: tuple[str, ...] = ("model",)) -> Any:
    """Pytree of NamedShardings congruent with a params(-shaped) tree.

    ``use_fsdp=False`` (serving): TP over the model axes only, replicated
    over `data` — per-token parameter all-gathers would dominate decode.
    ``model_axes``: the serving mesh views the model axis as ('kv', 'hd')."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    out = [
        NamedSharding(
            mesh, _spec_for(path, tuple(leaf.shape), mesh, use_fsdp,
                            model_axes)
        )
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_shardings(params_shape: Any, mesh: jax.sharding.Mesh) -> Any:
    """Optimizer state: moments inherit the parameter sharding (the params
    are already fully sharded under TP+FSDP => ZeRO-3-equivalent).

    Built structurally from the params tree: AdamWState(step, m, v) with
    m and v congruent to params (NamedTuple paths are positional, so the
    name-based rule cannot be reused on the wrapper)."""
    from repro.optim import AdamWState

    p_sh = param_shardings(params_shape, mesh)
    return AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)


def batch_shardings(batch_shape: Any, mesh: jax.sharding.Mesh) -> Any:
    """Batch dim over (pod, data); positions [3, B, S] handled."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        nd = len(leaf.shape)
        if names and names[-1] == "positions" and nd == 3:
            return NamedSharding(mesh, P(None, dp, *([None] * (nd - 2))))
        return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def serve_shardings(tree_shape: Any, mesh: jax.sharding.Mesh) -> Any:
    """Serving trees carry a leading data-group axis G on every leaf
    (tokens [G, b], pools [G, L, P, page, Hkv, hd], ...): G -> 'data',
    and any dim divisible by the model axis among the trailing dims of
    pool-like leaves -> 'model' (KV head_dim).  G == 1 -> replicated."""
    msize = mesh.shape["model"]

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        dims: list[Any] = [None] * len(shape)
        if shape and shape[0] > 1:
            dims[0] = "data"
        if len(shape) >= 5 and shape[-1] % msize == 0:
            dims[-1] = "model"   # head_dim of KV pools / wkv state
        return NamedSharding(mesh, P(*dims))

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


def make_shard_hook(mesh: jax.sharding.Mesh, *, sequence_parallel: bool = True):
    """The models' activation-sharding hook (with_sharding_constraint)."""
    dp = dp_axes(mesh)
    msize = mesh.shape["model"]

    def shard(x: jax.Array, name: str) -> jax.Array:
        if name == "act_btd_nosp" and x.ndim == 3:
            # gather the sequence axis (un-SP): per-row MoE dispatch must
            # see whole rows, or its scatter/gather psums over `model`
            # (EXPERIMENTS.md §Perf D-2)
            spec = P(dp, None, None)
        elif name == "act_btd" and x.ndim == 3:
            seq = "model" if (
                sequence_parallel and x.shape[1] % msize == 0
            ) else None
            spec = P(dp, seq, None)
        elif name == "logits" and x.ndim >= 2:
            v_ok = x.shape[-1] % msize == 0
            spec = P(dp, *([None] * (x.ndim - 2)),
                     "model" if v_ok else None)
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec)
        )

    return shard
