"""Benchmark harness: one section per paper table/figure + the roofline.

Prints a ``name,us_per_call,derived`` CSV block at the end (harness
contract).  Sections:
  fig2   — matmul VM overhead vs DTLB size x problem size  (bench_tlb_sweep)
  table1 — RiVEC suite scalar vs vector speedups           (bench_rivec)
  s31    — scheduler ticks + context switches              (bench_context_switch)
  c2     — burst vs element translation (+ coalescing)     (bench_translation)
  roof   — dry-run roofline table                          (roofline)
"""

from __future__ import annotations

import sys
import time


def section(title: str):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def main() -> None:
    t0 = time.time()
    csv: list[str] = ["name,us_per_call,derived"]

    section("Fig. 2(b,c,d): matmul VM overhead vs DTLB size")
    from benchmarks import bench_tlb_sweep
    csv += bench_tlb_sweep.main()

    section("Table 1: RiVEC suite (S / V / Vu)")
    from benchmarks import bench_rivec
    csv += bench_rivec.main()

    section("§3.1: scheduler interrupts + context switches")
    from benchmarks import bench_context_switch
    csv += bench_context_switch.main()

    section("Serving split: seed vs Scheduler/Executor (decode + switches)")
    from benchmarks import bench_serve_throughput
    csv += bench_serve_throughput.main()

    section("C2: translation counts (burst / element / coalesced)")
    from benchmarks import bench_translation
    csv += bench_translation.main()

    section("Beyond-paper: page-size sweep (the TPU dual of the TLB sweep)")
    from benchmarks import bench_page_size
    csv += bench_page_size.main()

    section("Roofline (from dry-run artifacts)")
    from benchmarks import roofline
    csv += roofline.main()

    section(f"CSV (total {time.time() - t0:.0f}s)")
    for line in csv:
        print(line)


if __name__ == "__main__":
    main()
