"""Performance counters + event snapshots.

The paper adds "a measurement infrastructure composed of performance counters
and FIFOs to create snapshots of the internal state of the architecture and
relevant event timestamps" (§3).  This is the software restatement: named
monotonic counters, a bounded snapshot FIFO of (timestamp, event, payload)
records, and context-manager timers.  Used by the serving engine, the train
loop, and every benchmark.

Counters are open-vocabulary (any name auto-registers at zero).  The radix
prefix layer adds the reuse accounting the prefix bench gates on:
``prefix_hits`` (admissions that COW-mapped a matched prefix),
``pages_reused`` (physical frames re-shared by refcount — radix hits plus
shared-page restores), ``prefill_tokens_skipped`` (prompt tokens whose
prefill was replaced by page sharing), ``shared_restores`` (restores that
re-shared still-resident pinned-prefix frames instead of allocating), and
the router's ``prefix_routed`` (placements where the longest-matching-
prefix score changed the prefix-blind choice).

Quantized-KV serving adds ``quant_dispatches``: compute steps whose KV
pools were stored quantized (``ServeConfig.kv_dtype="int8"``), counted
alongside ``kernel_dispatches`` / ``ref_path_dispatches`` so a quantized
engine that silently lost the kernel path is visible as
``quant_dispatches > 0`` with ``ref_path_dispatches > 0``.  The accuracy
envelope that makes the quantized counters trustworthy is NOT a counter —
it is measured per run by ``benchmarks/bench_kv_quant.py`` and recorded
in the ``section:"quant"`` trajectory (``top1_agreement``: positionwise
greedy-token agreement vs the fp-pool engine; ``logit_max_abs_err``: a
model-level decode-logit probe), where ``scripts/bench_regress.py`` gates
it (agreement "ge", bytes-per-page "le" — never tok/s).

AOT-bucketed serving (``ServeConfig.aot_buckets``) adds the compile-stall
observability the open-loop SLO gate runs on: ``aot_hits`` (prefill /
continuation batches dispatched through an executable compiled at engine
build), ``aot_misses`` (batches that fell back to the shape-keyed jit —
the gate requires 0 after warmup, because each miss is a potential
first-hit compile stall on the serving path), and ``bucket_pad_tokens``
(pure padding overhead of rounding batches up to the compiled shape —
gated per prefill token, "le").  The async stream pipeline adds
``detok_backlog_peak``: the deepest the background detokenize queue ever
got — a PEAK, not a monotonic count, written directly by the
detokenizer — the observable for "host post-processing is falling behind
the device".

Portable swap records add the migration/partial-restore accounting the
``section:"migrate"`` benchmark gates on.  Scheduler-side:
``swap_exports`` / ``swap_imports`` (swap records detached from / adopted
into a replica — every completed migration is one export/import pair,
every rollback adds one more import at the source), ``partial_restores``
(capacity-blocked FIFO heads brought back as the longest page-aligned
prefix that fit, tail re-enqueued for re-prefill), ``pages_refilled``
(frames re-faulted for those evicted tails at resume admission — the
price of restoring early, paid in recompute instead of waiting), and
``second_chance_restores`` (victims behind a ``RestoreFailure``-pinned
head restored by the bounded scan without popping the head).
Router-side: ``restore_migrations`` (swapped victims moved to a replica
with headroom — rescue or starvation), ``migration_aborts``
(destination-rejected imports rolled back at the source head), and
``reach_redirects`` (placements where the admission reach filter
overrode a reach-blind policy choice).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Iterator


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One FIFO record: a timestamped event with an arbitrary payload."""

    t: float
    event: str
    payload: Any = None


class PerfCounters:
    """Named counters + bounded snapshot FIFO + wall-clock timers."""

    def __init__(self, fifo_depth: int = 4096):
        self.counters: collections.Counter[str] = collections.Counter()
        self.fifo: collections.deque[Snapshot] = collections.deque(maxlen=fifo_depth)
        self._timers: collections.defaultdict[str, float] = collections.defaultdict(float)
        self._t0 = time.perf_counter()

    # ---- counters ----------------------------------------------------------

    def inc(self, name: str, n: int = 1) -> None:
        self.counters[name] += n

    def get(self, name: str) -> int:
        return self.counters[name]

    @staticmethod
    def merged(parts: "Iterator[PerfCounters] | Any") -> collections.Counter:
        """Element-wise sum of several counter sets (cross-replica
        accounting: the router's global view must equal the sum of the
        per-replica views — ``merged`` is how the global side of that
        invariant is computed, and the test suite asserts the equality
        counter by counter)."""
        total: collections.Counter[str] = collections.Counter()
        for p in parts:
            total.update(p.counters)
        return total

    def ratio(self, num: str, den: str) -> float:
        """``counters[num] / counters[den]`` (0.0 when the denominator is 0).

        The serving gate reads ``ratio("host_syncs", "decode_tokens")`` —
        host interventions per decoded token, the amortization the fused
        decode horizon exists to buy (< 1.0 means the scalar/OS plane
        stayed off the per-token critical path).
        """
        d = self.counters[den]
        return self.counters[num] / d if d else 0.0

    # ---- snapshots -----------------------------------------------------------

    def snapshot(self, event: str, payload: Any = None) -> None:
        self.fifo.append(Snapshot(time.perf_counter() - self._t0, event, payload))

    def events(self, event: str | None = None) -> list[Snapshot]:
        if event is None:
            return list(self.fifo)
        return [s for s in self.fifo if s.event == event]

    # ---- timers ------------------------------------------------------------

    @contextlib.contextmanager
    def timer(self, name: str) -> Iterator[None]:
        t = time.perf_counter()
        try:
            yield
        finally:
            self._timers[name] += time.perf_counter() - t

    def seconds(self, name: str) -> float:
        return self._timers[name]

    # ---- reporting -----------------------------------------------------------

    def report(self) -> dict[str, Any]:
        return {
            "counters": dict(self.counters),
            "timers_s": dict(self._timers),
            "events": len(self.fifo),
        }
