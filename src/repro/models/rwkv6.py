"""RWKV-6 "Finch" — attention-free LM with data-dependent decay.

Faithful structure (arXiv:2404.05892): alternating time-mix and channel-mix
blocks.  Time-mix computes r/k/v/g from token-shift interpolations and a
*data-dependent* per-channel decay ``w_t = exp(-exp(w0 + LoRA(x_t)))`` — the
defining Finch feature — then runs the linear-state recurrence
(``kernels/wkv6.py``).  Channel-mix is the squared-ReLU MLP.

Simplifications vs the released checkpoints (documented per DESIGN.md §2):
RMSNorm instead of biased LayerNorm; static token-shift mixing coefficients
(the decay keeps its LoRA); per-head RMS normalization of the wkv output in
place of GroupNorm.  None affect the latency/overhead quantities this
reproduction evaluates.

Serving: NO KV cache — per-request state is O(1) in context length
(`[H, N, N]` wkv state + two shift vectors per layer), which is why this
architecture runs the ``long_500k`` shape.  State slabs are allocated and
context-switched by the vmem subsystem, but paging/translation is
inapplicable (DESIGN.md §4 — noted, arch fully implemented).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]
LORA_RANK = 64


class RecurrentState(NamedTuple):
    """Per-request recurrent state, stacked over layers."""

    tm_shift: jax.Array   # [L, B, D]   last token seen by time-mix
    cm_shift: jax.Array   # [L, B, D]   last token seen by channel-mix
    wkv: jax.Array        # [L, B, H, N, N]  f32 recurrence state
    seq_lens: jax.Array   # [B]


def _head_rms(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS normalization of the wkv output. x [..., H, N]."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


class RWKV6LM:
    def __init__(self, cfg: ModelConfig, *, use_kernels: bool = False,
                 remat: bool = True, shard=None,
                 tm_impl: str = "sequential"):
        assert cfg.family == "rwkv6"
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.tm_impl = tm_impl  # "sequential" | "chunked_matmul"
        self.remat = remat
        self.shard = shard or (lambda x, name: x)
        self.dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            cfg.param_dtype
        ]

    # ------------------------------------------------------------------

    def _init_block(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        d, f = cfg.d_model, cfg.d_ff
        h, n = cfg.num_rwkv_heads, cfg.rwkv_head_size
        ks = jax.random.split(key, 10)
        u01 = lambda k, shape: jax.random.uniform(k, shape, jnp.float32)
        return {
            "ln1": L.rmsnorm_init(d, dt),
            "ln2": L.rmsnorm_init(d, dt),
            "tm": {
                "mu_r": u01(ks[0], (d,)).astype(dt),
                "mu_k": u01(ks[1], (d,)).astype(dt),
                "mu_v": u01(ks[2], (d,)).astype(dt),
                "mu_g": u01(ks[3], (d,)).astype(dt),
                "mu_w": u01(ks[4], (d,)).astype(dt),
                "w0": (-6.0 + u01(ks[5], (d,)) * 2.0),          # f32
                "w_lora_A": L.dense_init(ks[6], d, LORA_RANK, jnp.float32),
                "w_lora_B": jnp.zeros((LORA_RANK, d), jnp.float32),
                "wr": L.dense_init(ks[7], d, d, dt),
                "wk": L.dense_init(ks[8], d, d, dt),
                "wv": L.dense_init(ks[9], d, d, dt),
                "wg": L.dense_init(jax.random.fold_in(key, 10), d, d, dt),
                "wo": L.dense_init(jax.random.fold_in(key, 11), d, d, dt),
                "u": (u01(jax.random.fold_in(key, 12), (h, n)) - 0.5),  # f32
                "ln_x": jnp.ones((d,), dt),
            },
            "cm": {
                "mu": u01(jax.random.fold_in(key, 13), (d,)).astype(dt),
                "wr": L.dense_init(jax.random.fold_in(key, 14), d, d, dt),
                "wk": L.dense_init(jax.random.fold_in(key, 15), d, f, dt),
                "wv": L.dense_init(jax.random.fold_in(key, 16), f, d, dt),
            },
        }

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_emb, k_blocks, k_head = jax.random.split(key, 3)
        stacked = jax.vmap(self._init_block)(
            jax.random.split(k_blocks, cfg.num_layers)
        )
        return {
            "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "blocks": stacked,
            "ln_f": L.rmsnorm_init(cfg.d_model, dt),
            "head": L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
        }

    # ------------------------------------------------------------------
    # block math (shared by train and serve paths)
    # ------------------------------------------------------------------

    def _decay(self, tm: Params, xw: jax.Array) -> jax.Array:
        """Data-dependent decay in (0, 1): exp(-exp(w0 + LoRA(xw)))."""
        lora = jnp.tanh(xw.astype(jnp.float32) @ tm["w_lora_A"]) @ tm["w_lora_B"]
        return jnp.exp(-jnp.exp(tm["w0"] + lora))

    def _time_mix(
        self, p: Params, x: jax.Array, x_prev: jax.Array,
        wkv_state: jax.Array,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """x [B, T, D]; x_prev [B, D]; wkv_state [B, H, N, N] f32.

        Returns (out [B, T, D], new_x_prev, new_wkv_state).
        """
        cfg = self.cfg
        b, t, d = x.shape
        h, n = cfg.num_rwkv_heads, cfg.rwkv_head_size
        tm = p["tm"]
        shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
        mix = lambda mu: x + (shifted - x) * mu
        r = mix(tm["mu_r"]) @ tm["wr"]
        k = mix(tm["mu_k"]) @ tm["wk"]
        v = mix(tm["mu_v"]) @ tm["wv"]
        g = mix(tm["mu_g"]) @ tm["wg"]
        w = self._decay(tm, mix(tm["mu_w"]))                  # [B, T, D] f32

        to_heads = lambda z: z.reshape(b, t, h, n).transpose(0, 2, 1, 3).reshape(
            b * h, t, n
        )
        u = jnp.tile(tm["u"], (b, 1))                          # [B*H, N]
        o, s_fin = ops.wkv6(
            to_heads(r).astype(jnp.float32),
            to_heads(k).astype(jnp.float32),
            to_heads(v).astype(jnp.float32),
            to_heads(w),
            u,
            wkv_state.reshape(b * h, n, n),
            use_kernel=self.use_kernels,
            matmul_chunks=(self.tm_impl == "chunked_matmul"),
        )
        o = o.reshape(b, h, t, n).transpose(0, 2, 1, 3)        # [B, T, H, N]
        o = _head_rms(o, tm["ln_x"].reshape(h, n)).reshape(b, t, d)
        out = (o.astype(x.dtype) * jax.nn.silu(g)) @ tm["wo"]
        return out, x[:, -1, :], s_fin.reshape(b, h, n, n)

    def _channel_mix(
        self, p: Params, x: jax.Array, x_prev: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        cm = p["cm"]
        shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
        xm = x + (shifted - x) * cm["mu"]
        rr = jax.nn.sigmoid(xm @ cm["wr"])
        kk = jnp.square(jax.nn.relu(xm @ cm["wk"]))
        return rr * (kk @ cm["wv"]), x[:, -1, :]

    def _block(self, block_p: Params, x: jax.Array, tm_prev, cm_prev, wkv):
        cfg = self.cfg
        x = self.shard(x, "act_btd")
        xn = L.rmsnorm(block_p["ln1"], x, cfg.norm_eps)
        tm_out, tm_prev_new, wkv_new = self._time_mix(block_p, xn, tm_prev, wkv)
        x = x + tm_out
        xn = L.rmsnorm(block_p["ln2"], x, cfg.norm_eps)
        cm_out, cm_prev_new = self._channel_mix(block_p, xn, cm_prev)
        return x + cm_out, tm_prev_new, cm_prev_new, wkv_new

    # ------------------------------------------------------------------
    # training
    # ------------------------------------------------------------------

    def forward(self, params: Params, tokens: jax.Array,
                state: RecurrentState | None = None
                ) -> tuple[jax.Array, RecurrentState | None]:
        cfg = self.cfg
        b, t = tokens.shape
        h, n = cfg.num_rwkv_heads, cfg.rwkv_head_size
        x = params["embed"][tokens]
        if state is None:
            zeros_d = jnp.zeros((cfg.num_layers, b, cfg.d_model), x.dtype)
            state = RecurrentState(
                zeros_d, zeros_d,
                jnp.zeros((cfg.num_layers, b, h, n, n), jnp.float32),
                jnp.zeros((b,), jnp.int32),
            )

        def body(carry, xs):
            x = carry
            block_p, tm_prev, cm_prev, wkv = xs
            x, tm_new, cm_new, wkv_new = self._block(
                block_p, x, tm_prev, cm_prev, wkv
            )
            return x, (tm_new, cm_new, wkv_new)

        f = jax.checkpoint(body) if self.remat else body
        x, (tm_s, cm_s, wkv_s) = jax.lax.scan(
            f, x, (params["blocks"], state.tm_shift, state.cm_shift, state.wkv)
        )
        new_state = RecurrentState(
            tm_s, cm_s, wkv_s, state.seq_lens + t
        )
        return L.rmsnorm(params["ln_f"], x, cfg.norm_eps), new_state

    def loss(self, params: Params, batch: dict[str, jax.Array]):
        h, _ = self.forward(params, batch["tokens"])
        logits = self.shard(h @ params["head"], "logits")
        xent = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
        return xent, {"xent": xent, "aux": jnp.float32(0.0)}

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def init_state(self, batch: int) -> RecurrentState:
        cfg = self.cfg
        h, n = cfg.num_rwkv_heads, cfg.rwkv_head_size
        zeros_d = jnp.zeros((cfg.num_layers, batch, cfg.d_model), self.dtype)
        return RecurrentState(
            zeros_d, zeros_d,
            jnp.zeros((cfg.num_layers, batch, h, n, n), jnp.float32),
            jnp.zeros((batch,), jnp.int32),
        )

    @functools.partial(jax.jit, static_argnums=(0,))
    def prefill(self, params: Params, tokens: jax.Array,
                prompt_lens: jax.Array, state: RecurrentState
                ) -> tuple[jax.Array, RecurrentState]:
        """NOTE: recurrences consume prompts sequentially; padded batches
        assume right-aligned equal lengths for exactness (the serve engine
        runs per-bucket).  Returns last-token logits + state."""
        h, new_state = self.forward(params, tokens, state)
        last = jnp.take_along_axis(
            h, jnp.maximum(prompt_lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        new_state = new_state._replace(
            seq_lens=state.seq_lens + prompt_lens.astype(jnp.int32)
        )
        return last @ params["head"], new_state

    @functools.partial(jax.jit, static_argnums=(0,))
    def decode_step(self, params: Params, tokens: jax.Array,
                    state: RecurrentState
                    ) -> tuple[jax.Array, RecurrentState]:
        h, new_state = self.forward(params, tokens[:, None], state)
        return h[:, 0] @ params["head"], new_state
