"""Decoder-only transformer LM (dense / MoE / VLM / audio families).

Design (DESIGN.md §3):
  * params are dict pytrees; the repeated block's params are STACKED along a
    leading layer axis and the forward pass is a ``lax.scan`` — HLO size is
    O(1) in depth, which keeps 95-layer x 512-device dry-runs compilable and
    matches production frameworks;
  * training uses blockwise causal attention (flash kernel or jnp oracle);
  * serving reads/writes the KV cache through the paged virtual-memory
    subsystem: prefill writes KV with one translation per page burst
    (paged_copy), decode attends through the page table
    (paged_decode_attention) — the paper's C2 contract end to end;
  * an injectable ``shard(x, name)`` hook lets the launcher pin activation
    shardings without the model importing any mesh machinery.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.vmem import INVALID_PAGE
from repro.kernels import ops, ref  # noqa: F401
from repro.models import layers as L
from repro.models import moe as M
from repro.models.config import ModelConfig

Params = dict[str, Any]
ShardFn = Callable[[jax.Array, str], jax.Array]


def _no_shard(x: jax.Array, name: str) -> jax.Array:
    return x


class PagedKVState(NamedTuple):
    """Serving-side state: paged KV pools + the page table ("satp")."""

    k_pools: jax.Array     # [L, P, page, Hkv, hd]
    v_pools: jax.Array     # [L, P, page, Hkv, hd]
    page_table: jax.Array  # [B, max_pages] int32
    seq_lens: jax.Array    # [B] int32 — tokens currently in cache

    @property
    def page_size(self) -> int:
        return self.k_pools.shape[2]


class TransformerLM:
    """Families: dense | moe | vlm | audio (GQA attention backbones)."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        use_kernels: bool = False,
        capacity_factor: float = 1.25,
        remat: bool = True,
        shard: ShardFn | None = None,
        moe_dispatch: str = "sorted",   # "sorted" | "ragged" | "dense"
        remat_policy: str | None = None,  # None | "dots" (§Perf cell B)
        kv_dtype: str = "native",       # "native" | "int8" (§Perf cell A)
        kernel_mesh=None,               # ('kv','hd') mesh for serve kernels
    ):
        assert cfg.family in ("dense", "moe", "vlm", "audio"), cfg.family
        self.cfg = cfg
        self.use_kernels = use_kernels
        #: with a >1-device ('kv','hd') mesh the serve paths dispatch the
        #: Pallas kernels through the shard_map wrappers in kernels.ops —
        #: each device runs the kernel on its local KV-pool slice (see the
        #: ops module docstring); the executor binds this via
        #: ``serve.executor._mesh_kernel_model``
        self.kernel_mesh = kernel_mesh
        self.capacity_factor = capacity_factor
        self.remat = remat
        self.shard = shard or _no_shard
        self.moe_dispatch = moe_dispatch
        self.remat_policy = remat_policy
        self.kv_dtype = kv_dtype
        self.dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            cfg.param_dtype
        ]
        # scan unit: a superblock of `moe_every` layers; for interleaved MoE
        # (llama4: moe_every=2) only the last layer of each group routes.
        self.moe_every = cfg.moe_every if cfg.family == "moe" else 1
        assert cfg.num_layers % self.moe_every == 0, (
            cfg.num_layers, self.moe_every
        )
        self.n_super = cfg.num_layers // self.moe_every

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def _init_block(self, key, is_moe: bool) -> Params:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        p: Params = {
            "ln1": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.attention_init(ks[0], cfg, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
        }
        if is_moe:
            p["mlp"] = M.moe_init(ks[1], cfg, dt)
        else:
            p["mlp"] = L.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dt)
        return p

    def _is_moe_sub(self, i: int) -> bool:
        return self.cfg.family == "moe" and i == self.moe_every - 1

    def _init_superblock(self, key) -> Params:
        ks = jax.random.split(key, self.moe_every)
        return {
            f"sub{i}": self._init_block(ks[i], self._is_moe_sub(i))
            for i in range(self.moe_every)
        }

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_emb, k_blocks, k_head = jax.random.split(key, 3)
        block_keys = jax.random.split(k_blocks, self.n_super)
        stacked = jax.vmap(self._init_superblock)(block_keys)
        p: Params = {
            "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "blocks": stacked,
            "ln_f": L.rmsnorm_init(cfg.d_model, dt),
        }
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            # per-codebook embeddings + heads (MusicGen over EnCodec streams)
            p["embed"] = jax.vmap(
                lambda k: L.embed_init(k, cfg.vocab_size, cfg.d_model, dt)
            )(jax.random.split(k_emb, cfg.num_codebooks))
            p["head"] = jax.vmap(
                lambda k: L.dense_init(k, cfg.d_model, cfg.vocab_size, dt)
            )(jax.random.split(k_head, cfg.num_codebooks))
        elif not cfg.tie_embeddings:
            p["head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt)
        return p

    # ------------------------------------------------------------------
    # embedding / logits
    # ------------------------------------------------------------------

    def embed(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            # tokens [..., K]: sum of per-codebook embeddings (EnCodec streams)
            per_book = jax.vmap(
                lambda e, t: e[t], in_axes=(0, -1), out_axes=-2
            )(params["embed"], tokens)            # [..., K, D]
            return per_book.sum(axis=-2).astype(self.dtype)
        return params["embed"][tokens]

    def logits_fn(self, params: Params, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            return jnp.einsum("...d,kdv->...kv", h, params["head"])
        if cfg.tie_embeddings:
            return h @ params["embed"].T
        return h @ params["head"]

    # ------------------------------------------------------------------
    # training forward
    # ------------------------------------------------------------------

    def _block_apply(
        self, p: Params, x: jax.Array, positions: jax.Array, is_moe: bool
    ) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = self.shard(x, "act_btd")
        h = L.attention_train(
            p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions, cfg,
            use_kernel=self.use_kernels,
        )
        x = x + h
        hn = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        aux = jnp.float32(0.0)
        if is_moe:
            b, s, d = hn.shape
            if self.moe_dispatch == "sorted":
                # per-row groups: dispatch stays local to the data shard
                ff, aux = M.moe_apply_sorted_rows(
                    p["mlp"], hn,
                    num_experts=cfg.num_experts, k=cfg.experts_per_token,
                    capacity_factor=self.capacity_factor,
                )
                return self.shard(x + ff, "act_btd"), aux
            elif self.moe_dispatch == "ragged":
                ff, aux = M.moe_apply_ragged(
                    p["mlp"], hn.reshape(b * s, d),
                    num_experts=cfg.num_experts, k=cfg.experts_per_token,
                )
            else:
                ff, aux = M.moe_apply_dense(
                    p["mlp"], hn.reshape(b * s, d),
                    num_experts=cfg.num_experts, k=cfg.experts_per_token,
                )
            ff = ff.reshape(b, s, d)
        else:
            ff = L.swiglu(p["mlp"], hn)
        return self.shard(x + ff, "act_btd"), aux

    def _superblock_apply(
        self, sb: Params, x: jax.Array, positions: jax.Array
    ) -> tuple[jax.Array, jax.Array]:
        aux = jnp.float32(0.0)
        for i in range(self.moe_every):
            x, a = self._block_apply(
                sb[f"sub{i}"], x, positions, self._is_moe_sub(i)
            )
            aux = aux + a
        return x, aux

    def forward(
        self,
        params: Params,
        tokens: jax.Array,                    # [B, S] (or [B, S, K] audio)
        positions: jax.Array | None = None,   # [B, S] or [3, B, S] (mrope)
        vision_embeds: jax.Array | None = None,  # [B, Nvis, D] stub frontend
    ) -> tuple[jax.Array, jax.Array]:
        """Returns (hidden [B, S, D], aux_loss scalar)."""
        cfg = self.cfg
        b, s = tokens.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions, (3, b, s))
        x = self.embed(params, tokens)
        if vision_embeds is not None:
            nvis = vision_embeds.shape[1]
            x = jnp.concatenate(
                [vision_embeds.astype(x.dtype), x[:, nvis:]], axis=1
            )
        def body(carry, sb_params):
            return self._superblock_apply(sb_params, carry, positions)

        if self.remat and self.remat_policy == "dots":
            # save matmul outputs: the backward pass re-gathers FSDP weights
            # once instead of twice (collective term down, memory term up)
            f = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        elif self.remat:
            f = jax.checkpoint(body)
        else:
            f = body
        x, auxs = jax.lax.scan(f, x, params["blocks"])
        aux = auxs.mean() if cfg.family == "moe" else jnp.float32(0.0)
        return L.rmsnorm(params["ln_f"], x, cfg.norm_eps), aux

    def loss(self, params: Params, batch: dict[str, jax.Array]) -> tuple[
        jax.Array, dict[str, jax.Array]
    ]:
        """batch: tokens, labels, [mask], [positions], [vision_embeds]."""
        h, aux = self.forward(
            params, batch["tokens"], batch.get("positions"),
            batch.get("vision_embeds"),
        )
        logits = self.logits_fn(params, h)
        logits = self.shard(logits, "logits")
        if self.cfg.family == "audio" and self.cfg.num_codebooks > 1:
            # mean over codebook heads
            losses = jax.vmap(
                lambda lg, lb: L.softmax_xent(lg, lb, batch.get("mask")),
                in_axes=(-2, -1),
            )(logits, batch["labels"])
            xent = losses.mean()
        else:
            xent = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
        total = xent + 0.01 * aux
        return total, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------------
    # serving: prefill + paged decode
    # ------------------------------------------------------------------

    KV_INT8_SCALE = 24.0  # fixed-point scale (values are post-norm, O(1))

    def _kv_store_dtype(self):
        return jnp.int8 if self.kv_dtype == "int8" else self.dtype

    def _serve_kernel_mesh(self):
        """The ('kv','hd') mesh the serve-path kernels shard_map over, or
        None for a plain single-device trace.  Only live when kernels are:
        the jnp paths need no shard_map (GSPMD partitions them freely)."""
        m = getattr(self, "kernel_mesh", None)
        if self.use_kernels and m is not None and m.size > 1:
            return m
        return None

    def _kv_quant(self, x: jax.Array) -> jax.Array:
        if self.kv_dtype != "int8":
            return x
        return jnp.clip(
            jnp.round(x.astype(jnp.float32) * self.KV_INT8_SCALE), -127, 127
        ).astype(jnp.int8)

    def init_kv_state(
        self, batch: int, num_pages: int, page_size: int, max_pages: int
    ) -> PagedKVState:
        cfg = self.cfg
        shape = (cfg.num_layers, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        return PagedKVState(
            k_pools=jnp.zeros(shape, self._kv_store_dtype()),
            v_pools=jnp.zeros(shape, self._kv_store_dtype()),
            page_table=jnp.full((batch, max_pages), -1, jnp.int32),
            seq_lens=jnp.zeros((batch,), jnp.int32),
        )

    def _block_serve_qkv(self, p, x, positions):
        """Shared q/k/v + rope for serve paths. x [B, T, D]."""
        cfg = self.cfg
        q, k, v = L.qkv_project(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cfg)
        if cfg.mrope_sections:
            pos3 = jnp.broadcast_to(positions, (3,) + positions.shape)
            q = L.apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
            k = L.apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
        return q, k, v

    def _ffn_serve(self, p, x, is_moe: bool):
        cfg = self.cfg
        hn = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if is_moe:
            shp = hn.shape
            n_tok = int(np.prod(shp[:-1]))
            if n_tok <= 2048:
                # decode: tiny token count — drop-free ragged dispatch
                # (vmap-safe across serve groups)
                ff, _ = M.moe_apply_ragged_batched(
                    p["mlp"], hn.reshape(-1, shp[-1]),
                    num_experts=cfg.num_experts, k=cfg.experts_per_token,
                )
            else:
                # prefill: per-row sorted dispatch with generous capacity
                # (cf=2.0: drops are astronomically unlikely; keeps the
                # buffers data-shard-local)
                ff, _ = M.moe_apply_sorted_rows(
                    p["mlp"], hn.reshape(-1, shp[-2], shp[-1]),
                    num_experts=cfg.num_experts, k=cfg.experts_per_token,
                    capacity_factor=2.0,
                )
            return x + ff.reshape(shp)
        return x + L.swiglu(p["mlp"], hn)

    def _group_pools(self, pools: jax.Array) -> jax.Array:
        """[L, P, ...] -> [n_super, moe_every, P, ...] for superblock scans."""
        return pools.reshape(
            (self.n_super, self.moe_every) + pools.shape[1:]
        )

    def _ungroup_pools(self, pools: jax.Array) -> jax.Array:
        return pools.reshape((self.cfg.num_layers,) + pools.shape[2:])

    @functools.partial(jax.jit, static_argnums=(0,))
    def prefill(
        self,
        params: Params,
        tokens: jax.Array,        # [B, S] padded prompts
        prompt_lens: jax.Array,   # [B] true lengths
        state: PagedKVState,
        vision_embeds: jax.Array | None = None,
    ) -> tuple[jax.Array, PagedKVState]:
        """Run prompts, write KV through the page table (burst copies).

        Returns (last-token logits [B, V...], updated state with
        seq_lens = prompt_lens).
        """
        cfg = self.cfg
        b, s = tokens.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self.embed(params, tokens)
        if vision_embeds is not None:
            nvis = vision_embeds.shape[1]
            x = jnp.concatenate(
                [vision_embeds.astype(x.dtype), x[:, nvis:]], axis=1
            )
        page = state.page_size
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        mesh = self._serve_kernel_mesh()

        def layer(block_p, x, k_pool, v_pool, is_moe):
            q, k, v = self._block_serve_qkv(block_p, x, positions)
            # unit-stride burst write through the page table (C2-burst).
            # The pool copy stores the POOL's dtype: quantized under int8
            # (the copies are dtype-agnostic, so the burst itself narrows),
            # while the chunk's own flash attention below keeps the raw
            # activations — quantization error only enters once pages are
            # re-read through the paged-attention kernels.
            kq, vq = self._kv_quant(k), self._kv_quant(v)
            if mesh is not None:
                # shard_map dispatch: 4-D natural layout to the boundary,
                # merged-W reshape happens shard-locally (kernels/ops.py)
                k_pool = ops.paged_copy_sharded(
                    kq, k_pool, state.page_table, prompt_lens,
                    page_size=page, mesh=mesh,
                )
                v_pool = ops.paged_copy_sharded(
                    vq, v_pool, state.page_table, prompt_lens,
                    page_size=page, mesh=mesh,
                )
            else:
                k_pool = ops.paged_copy(
                    kq.reshape(b, s, hkv * hd),
                    k_pool.reshape(-1, page, hkv * hd),
                    state.page_table, prompt_lens, page_size=page,
                    use_kernel=self.use_kernels,
                ).reshape(k_pool.shape)
                v_pool = ops.paged_copy(
                    vq.reshape(b, s, hkv * hd),
                    v_pool.reshape(-1, page, hkv * hd),
                    state.page_table, prompt_lens, page_size=page,
                    use_kernel=self.use_kernels,
                ).reshape(v_pool.shape)
            qt, kt, vt = (t.swapaxes(1, 2) for t in (q, k, v))
            if mesh is not None:
                o = ops.flash_attention_sharded(qt, kt, vt, causal=True,
                                                mesh=mesh)
            elif self.use_kernels:
                o = ops.flash_attention(qt, kt, vt, causal=True)
            elif s > 1024:
                o = ref.chunked_attention_ref(qt, kt, vt, causal=True)
            else:
                o = ref.flash_attention_ref(qt, kt, vt, causal=True)
            x = x + o.swapaxes(1, 2).reshape(b, s, -1) @ block_p["attn"]["wo"]
            x = self._ffn_serve(block_p, x, is_moe)
            return x, k_pool, v_pool

        def body(carry, xs):
            x = carry
            sb, k_pools_g, v_pools_g = xs   # pools [moe_every, P, ...]
            kps, vps = [], []
            for i in range(self.moe_every):
                x, kp, vp = layer(
                    sb[f"sub{i}"], x, k_pools_g[i], v_pools_g[i],
                    self._is_moe_sub(i),
                )
                kps.append(kp)
                vps.append(vp)
            return x, (jnp.stack(kps), jnp.stack(vps))

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x,
            (params["blocks"], self._group_pools(state.k_pools),
             self._group_pools(state.v_pools)),
        )
        k_pools = self._ungroup_pools(k_pools)
        v_pools = self._ungroup_pools(v_pools)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        last = jnp.take_along_axis(
            x, jnp.maximum(prompt_lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        logits = self.logits_fn(params, last)
        new_state = PagedKVState(
            k_pools, v_pools, state.page_table, prompt_lens.astype(jnp.int32)
        )
        return logits, new_state

    @functools.partial(jax.jit, static_argnums=(0,))
    def prefill_continue(
        self,
        params: Params,
        tokens: jax.Array,        # [B, S] padded continuation chunks
        start_lens: jax.Array,    # [B] tokens already cached per sequence
        chunk_lens: jax.Array,    # [B] true lengths of the new chunks
        state: PagedKVState,
    ) -> tuple[jax.Array, PagedKVState]:
        """Chunked prefill at arbitrary start offsets (continuation).

        Extends sequences that already have ``start_lens`` tokens in the
        paged cache by a chunk of new tokens: KV is written through the page
        table with one translation per burst starting at the (not
        necessarily page-aligned) logical offset (``paged_copy_at``), and
        each chunk query attends causally over cache + chunk through the
        page table (``paged_prefill_attention`` — the Pallas kernel streams
        KV pages per query block; the jnp oracle gathers the full logical
        prefix).  This replaces one-token-at-a-time teacher forcing for
        forked/continued requests with a single device step per chunk, and
        the batch axis lets same-step forked admissions run as one call.

        The host must have mapped pages covering positions
        ``[start, start + chunk)`` (VirtualMemory.append_tokens).
        Returns (last-chunk-token logits [B, V...], state with
        seq_lens = start_lens + chunk_lens).
        """
        cfg = self.cfg
        b, s = tokens.shape[:2]
        page = state.page_size
        hkv, hd, g = cfg.num_kv_heads, cfg.head_dim, cfg.q_per_kv
        positions = start_lens[:, None] + jnp.arange(s)[None, :]    # [B, S]
        x = self.embed(params, tokens)
        kv_scale = (1.0 / self.KV_INT8_SCALE
                    if self.kv_dtype == "int8" else None)
        mesh = self._serve_kernel_mesh()

        def layer(block_p, x, k_pool, v_pool, is_moe):
            q, k, v = self._block_serve_qkv(block_p, x, positions)
            if mesh is not None:
                k_pool = ops.paged_copy_at_sharded(
                    self._kv_quant(k), k_pool, state.page_table,
                    start_lens, chunk_lens, page_size=page, mesh=mesh,
                )
                v_pool = ops.paged_copy_at_sharded(
                    self._kv_quant(v), v_pool, state.page_table,
                    start_lens, chunk_lens, page_size=page, mesh=mesh,
                )
            else:
                k_pool = ops.paged_copy_at(
                    self._kv_quant(k).reshape(b, s, hkv * hd),
                    k_pool.reshape(-1, page, hkv * hd),
                    state.page_table, start_lens, chunk_lens, page_size=page,
                    use_kernel=self.use_kernels,
                ).reshape(k_pool.shape)
                v_pool = ops.paged_copy_at(
                    self._kv_quant(v).reshape(b, s, hkv * hd),
                    v_pool.reshape(-1, page, hkv * hd),
                    state.page_table, start_lens, chunk_lens, page_size=page,
                    use_kernel=self.use_kernels,
                ).reshape(v_pool.shape)
            # attend through the page table: causal mask on absolute
            # positions (cache + committed chunk prefix).  int8 pools ride
            # the same kernel dispatch — kv_scale is a scalar-prefetch
            # operand and the tiles dequantize in VMEM (kernels/ops.py).
            if mesh is not None:
                o = ops.paged_prefill_attention_sharded(
                    q.reshape(b, s, hkv, g, hd), k_pool, v_pool,
                    state.page_table, start_lens, page_size=page, mesh=mesh,
                    kv_scale=kv_scale,
                )
            else:
                o = ops.paged_prefill_attention(
                    q.reshape(b, s, hkv, g, hd), k_pool, v_pool,
                    state.page_table, start_lens, page_size=page,
                    use_kernel=self.use_kernels, kv_scale=kv_scale,
                )
            o = o.reshape(b, s, hkv * g * hd)
            x = x + o @ block_p["attn"]["wo"]
            x = self._ffn_serve(block_p, x, is_moe)
            return x, k_pool, v_pool

        def body(carry, xs):
            x = carry
            sb, k_pools_g, v_pools_g = xs
            kps, vps = [], []
            for i in range(self.moe_every):
                x, kp, vp = layer(
                    sb[f"sub{i}"], x, k_pools_g[i], v_pools_g[i],
                    self._is_moe_sub(i),
                )
                kps.append(kp)
                vps.append(vp)
            return x, (jnp.stack(kps), jnp.stack(vps))

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x,
            (params["blocks"], self._group_pools(state.k_pools),
             self._group_pools(state.v_pools)),
        )
        k_pools = self._ungroup_pools(k_pools)
        v_pools = self._ungroup_pools(v_pools)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        last = jnp.take_along_axis(
            x, jnp.maximum(chunk_lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        logits = self.logits_fn(params, last)
        new_lens = (start_lens + chunk_lens).astype(jnp.int32)
        return logits, PagedKVState(
            k_pools, v_pools, state.page_table, new_lens
        )

    @functools.partial(jax.jit, static_argnums=(0,))
    def decode_step(
        self,
        params: Params,
        tokens: jax.Array,       # [B] (or [B, K] audio) freshly sampled
        state: PagedKVState,
    ) -> tuple[jax.Array, PagedKVState]:
        """One token: write KV at position seq_lens, attend through pages.

        The host must already have mapped a page covering position
        ``seq_lens`` (VirtualMemory.append_tokens — the page-fault path).
        """
        cfg = self.cfg
        b = tokens.shape[0]
        page = state.page_size
        hkv, hd, g = cfg.num_kv_heads, cfg.head_dim, cfg.q_per_kv
        pos = state.seq_lens                      # [B] position of new token
        x = self.embed(params, tokens)[:, None, :]  # [B, 1, D]
        # flat physical row of the new token in every pool (one translation
        # per element here — B independent sequences, B translations).
        # Inactive batch slots (unmapped page-table rows) are routed to the
        # pool's LAST row, which the serving engine reserves as scratch —
        # never to a live frame.
        frames = jnp.take_along_axis(
            state.page_table, (pos // page)[:, None], axis=1
        )[:, 0]
        n_rows = state.k_pools.shape[1] * page
        rows = jnp.where(
            frames < 0, n_rows - 1, frames * page + pos % page
        )                                                       # [B]
        new_lens = jnp.where(frames < 0, pos, pos + 1)
        mesh = self._serve_kernel_mesh()

        def layer(block_p, x, k_pool, v_pool, is_moe):
            q, k, v = self._block_serve_qkv(block_p, x, pos[:, None])
            # the single-token row scatter is plain jnp — GSPMD shards it
            k_pool = k_pool.reshape(-1, hkv, hd).at[rows].set(
                self._kv_quant(k[:, 0])
            ).reshape(k_pool.shape)
            v_pool = v_pool.reshape(-1, hkv, hd).at[rows].set(
                self._kv_quant(v[:, 0])
            ).reshape(v_pool.shape)
            qh = q[:, 0].reshape(b, hkv, g, hd)
            kv_scale = (1.0 / self.KV_INT8_SCALE
                        if self.kv_dtype == "int8" else None)
            if mesh is not None:
                o = ops.paged_decode_attention_sharded(
                    qh, k_pool, v_pool, state.page_table, new_lens,
                    page_size=page, mesh=mesh, kv_scale=kv_scale,
                )
            else:
                o = ops.paged_decode_attention(
                    qh, k_pool, v_pool, state.page_table, new_lens,
                    page_size=page, use_kernel=self.use_kernels,
                    kv_scale=kv_scale,
                )                                 # [B, Hkv, G, hd]
            x = x + (o.reshape(b, 1, hkv * g * hd) @ block_p["attn"]["wo"])
            x = self._ffn_serve(block_p, x, is_moe)
            return x, k_pool, v_pool

        def body(carry, xs):
            x = carry
            sb, k_pools_g, v_pools_g = xs
            kps, vps = [], []
            for i in range(self.moe_every):
                x, kp, vp = layer(
                    sb[f"sub{i}"], x, k_pools_g[i], v_pools_g[i],
                    self._is_moe_sub(i),
                )
                kps.append(kp)
                vps.append(vp)
            return x, (jnp.stack(kps), jnp.stack(vps))

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x,
            (params["blocks"], self._group_pools(state.k_pools),
             self._group_pools(state.v_pools)),
        )
        k_pools = self._ungroup_pools(k_pools)
        v_pools = self._ungroup_pools(v_pools)
        x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = self.logits_fn(params, x[:, 0])
        return logits, PagedKVState(
            k_pools, v_pools, state.page_table, new_lens
        )

    def decode_multi_step(
        self,
        params: Params,
        tokens: jax.Array,       # [B] (or [B, K] audio) last sampled tokens
        state: PagedKVState,
        steps_left: jax.Array,   # [B] int32 — active inner steps per lane
        rng: jax.Array,          # PRNG key (threaded; ignored when greedy)
        temperature: jax.Array,  # scalar     (ignored when greedy)
        *,
        horizon: int,
        greedy: bool,
    ) -> tuple[jax.Array, PagedKVState, jax.Array]:
        """Fused K-token decode: ``lax.scan`` over ``horizon`` chained
        :meth:`decode_step` calls with ON-DEVICE sampling.

        The scalar/OS plane intervenes once per *horizon*, not once per
        token (the AraOS amortization contract applied to the decode loop):
        each inner step writes KV at ``seq_lens``, attends through the page
        table, samples the next token on device (greedy argmax, or
        temperature/categorical with the PRNG key split exactly like the
        host path — one split per step, carry ``split(key)[0]``, consume
        ``split(key)[1]`` — so fused and step-wise stochastic streams are
        identical), and feeds it straight back into the next step.

        Per-lane retirement is masked on device: lane ``i`` is active at
        inner step ``t`` iff ``t < steps_left[i]``.  Inactive lanes get
        their page-table row masked to the invalid sentinel, which routes
        their KV write to the reserved scratch frame and freezes their
        ``seq_lens`` (``decode_step``'s existing guard) — the table itself
        is never rewritten.  The host must have pre-faulted pages covering
        every position the horizon touches (``VirtualMemory.
        append_tokens_batch``).

        Returns ``(token_block [horizon, B, ...], state, rng)``; block rows
        at ``t >= steps_left[i]`` are scratch output the caller discards.
        """
        ptab = state.page_table

        def body(carry, t):
            toks, k_pools, v_pools, seq_lens, key = carry
            active = t < steps_left                           # [B] bool
            masked = jnp.where(active[:, None], ptab, INVALID_PAGE)
            st = PagedKVState(k_pools, v_pools, masked, seq_lens)
            logits, ns = self.decode_step(params, toks, st)
            if greedy:
                new_tok = jnp.argmax(logits, axis=-1)
            else:
                key, sub = jax.random.split(key)
                new_tok = jax.random.categorical(
                    sub, logits / temperature, axis=-1
                )
            new_tok = new_tok.astype(toks.dtype)
            lane = active.reshape((-1,) + (1,) * (toks.ndim - 1))
            toks = jnp.where(lane, new_tok, toks)
            return (toks, ns.k_pools, ns.v_pools, ns.seq_lens, key), new_tok

        (tokens, k_pools, v_pools, seq_lens, rng), block = jax.lax.scan(
            body,
            (tokens, state.k_pools, state.v_pools, state.seq_lens, rng),
            jnp.arange(horizon),
        )
        return block, PagedKVState(k_pools, v_pools, ptab, seq_lens), rng
