"""RiVEC-J: the RiVEC benchmark suite's kernels in vectorized JAX.

Each kernel returns ``(result, Work)`` where Work records the architectural
quantities AraOS's speedups derive from: total element operations, how many
issue as long unit-stride vectors vs short vectors, ordered-reduction
elements (serialized on Ara2 unless the unordered variant is allowed),
per-element-translated indexed accesses (spmv/canneal/lavaMD), and register
reshuffles (canneal's EW-reinterpretation pathology, paper §3.2).

The numerical results are real (validated against NumPy oracles in
tests/test_benchmarks.py); the S/V/Vu columns of Table 1 are produced by
``bench_rivec``'s cycle model from these Work records.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Work:
    """Architectural work counters for the AraOS cycle model."""

    elems: int = 0              # total element-operations (vectorizable)
    avg_vl: float = 256.0       # average vector length achieved
    scalar_ops: int = 0         # irreducibly scalar work
    ordered_red_elems: int = 0  # elements entering ordered reductions
    indexed_elems: int = 0      # per-element-translated accesses
    reshuffles: int = 0         # full-VLEN register reshuffles (canneal)
    flops_per_elem: float = 1.0
    serial_frac: float = 0.0    # Amdahl fraction that stays scalar


# --------------------------------------------------------------------------
# sizes: simtiny / simsmall / simmedium / simlarge (scaled from RiVEC)
# --------------------------------------------------------------------------

SIZES = ("simtiny", "simsmall", "simmedium", "simlarge")
_N = {"simtiny": 1 << 10, "simsmall": 1 << 13, "simmedium": 1 << 15,
      "simlarge": 1 << 17}


def _key(name: str, size: str):
    return jax.random.PRNGKey(abs(hash((name, size))) % (2**31))


# -- axpy -------------------------------------------------------------------


def axpy(size: str):
    n = _N[size]
    k = _key("axpy", size)
    x = jax.random.normal(k, (n,))
    y = jax.random.normal(jax.random.fold_in(k, 1), (n,))
    out = 2.5 * x + y
    return out, Work(elems=n, avg_vl=256, flops_per_elem=2)


# -- blackscholes ------------------------------------------------------------


def blackscholes(size: str):
    n = _N[size]
    k = _key("bs", size)
    s = jax.random.uniform(k, (n,), minval=10, maxval=100)
    strike = jax.random.uniform(jax.random.fold_in(k, 1), (n,), minval=10,
                                maxval=100)
    t = jax.random.uniform(jax.random.fold_in(k, 2), (n,), minval=0.2,
                           maxval=2.0)
    r, vol = 0.05, 0.3
    d1 = (jnp.log(s / strike) + (r + vol * vol / 2) * t) / (
        vol * jnp.sqrt(t)
    )
    d2 = d1 - vol * jnp.sqrt(t)
    cnd = lambda x: 0.5 * (1 + jax.lax.erf(x / jnp.sqrt(2.0)))
    call = s * cnd(d1) - strike * jnp.exp(-r * t) * cnd(d2)
    return call, Work(elems=n, avg_vl=256, flops_per_elem=25)


# -- jacobi-2d ---------------------------------------------------------------


def jacobi2d(size: str, iters: int = 10):
    n = int(np.sqrt(_N[size]))
    k = _key("jacobi", size)
    a = jax.random.normal(k, (n, n))

    def step(a, _):
        inner = 0.2 * (a[1:-1, 1:-1] + a[1:-1, :-2] + a[1:-1, 2:]
                       + a[:-2, 1:-1] + a[2:, 1:-1])
        return a.at[1:-1, 1:-1].set(inner), None

    a, _ = jax.lax.scan(step, a, None, length=iters)
    return a, Work(elems=n * n * iters, avg_vl=min(256, n),
                   flops_per_elem=5)


# -- matmul ------------------------------------------------------------------


def matmul(size: str):
    n = {"simtiny": 64, "simsmall": 128, "simmedium": 256,
         "simlarge": 512}[size]
    k = _key("matmul", size)
    a = jax.random.normal(k, (n, n))
    b = jax.random.normal(jax.random.fold_in(k, 1), (n, n))
    c = a @ b
    return c, Work(elems=n * n * n, avg_vl=min(256, n), flops_per_elem=2,
                   ordered_red_elems=n * n * n)


# -- pathfinder (DP over rows) ------------------------------------------------


def pathfinder(size: str):
    rows, cols = 64, _N[size] // 64
    k = _key("pf", size)
    grid = jax.random.randint(k, (rows, cols), 0, 10)

    def step(prev, row):
        left = jnp.concatenate([prev[:1], prev[:-1]])
        right = jnp.concatenate([prev[1:], prev[-1:]])
        return row + jnp.minimum(prev, jnp.minimum(left, right)), None

    out, _ = jax.lax.scan(step, grid[0], grid[1:])
    return out, Work(elems=rows * cols, avg_vl=min(256, cols),
                     flops_per_elem=3)


# -- somier (spring-mass stencil) ---------------------------------------------


def somier(size: str, iters: int = 4):
    n = int(round(_N[size] ** (1 / 3))) + 2
    k = _key("somier", size)
    pos = jax.random.normal(k, (3, n, n, n)) * 0.01

    def forces(p):
        f = jnp.zeros_like(p)
        for axis in (1, 2, 3):
            f = f + (jnp.roll(p, 1, axis) - p) + (jnp.roll(p, -1, axis) - p)
        return f

    def step(p, _):
        return p + 1e-3 * forces(p), None

    pos, _ = jax.lax.scan(step, pos, None, length=iters)
    return pos, Work(elems=3 * n ** 3 * iters * 6, avg_vl=min(256, n * n),
                     flops_per_elem=2)


# -- spmv (CSR; indexed gathers -> per-element translation) --------------------


def spmv(size: str):
    # NZE-per-row grows with size: ~5 (tiny), ~21 (small), ~27 (med/large),
    # mirroring the paper's explanation of why speedup rises with size.
    n = _N[size] // 16
    nnz_per_row = {"simtiny": 5, "simsmall": 21, "simmedium": 27,
                   "simlarge": 27}[size]
    rng = np.random.default_rng(42)
    cols = rng.integers(0, n, size=(n, nnz_per_row)).astype(np.int32)
    vals = rng.normal(size=(n, nnz_per_row)).astype(np.float32)
    x = rng.normal(size=(n,)).astype(np.float32)
    out = jnp.einsum("ij,ij->i", jnp.asarray(vals),
                     jnp.asarray(x)[jnp.asarray(cols)])
    nnz = n * nnz_per_row
    return out, Work(elems=nnz, avg_vl=nnz_per_row, flops_per_elem=2,
                     ordered_red_elems=nnz, indexed_elems=nnz)


# -- streamcluster (distance eval + reduction) ---------------------------------


def streamcluster(size: str):
    n, d, kc = _N[size] // 32, 32, 8
    k = _key("sc", size)
    pts = jax.random.normal(k, (n, d))
    ctr = jax.random.normal(jax.random.fold_in(k, 1), (kc, d))
    d2 = ((pts[:, None, :] - ctr[None, :, :]) ** 2).sum(-1)
    assign = jnp.argmin(d2, axis=1)
    cost = d2.min(axis=1).sum()
    # argmin/bookkeeping per point remains scalar-ish (paper V ~1.9x,
    # Vu ~3.6-4.2x once ordered reductions are lifted)
    return (assign, cost), Work(
        elems=n * kc * d, avg_vl=min(256, d * kc), flops_per_elem=3,
        ordered_red_elems=n * kc * d, serial_frac=0.10,
    )


# -- swaptions (HJM-lite Monte Carlo) -----------------------------------------


def swaptions(size: str):
    n_sw, n_paths, n_steps = 8, _N[size] // 64, 16
    k = _key("sw", size)
    z = jax.random.normal(k, (n_sw, n_paths, n_steps)) * 0.02
    rates = 0.04 + jnp.cumsum(z, axis=-1)
    payoff = jnp.maximum(rates[..., -1] - 0.045, 0.0)
    disc = jnp.exp(-rates.sum(-1) * (1.0 / n_steps))
    price = (payoff * disc).mean(axis=1)
    # HJM's inner loops vectorize over short tenor segments, and path
    # setup stays scalar (paper: ~2.7x flat across sizes)
    return price, Work(
        elems=n_sw * n_paths * n_steps * 3, avg_vl=24,
        flops_per_elem=4, ordered_red_elems=n_sw * n_paths,
        serial_frac=0.18,
    )


# -- lavaMD (particle neighbors; indexed) ---------------------------------------


def lavamd(size: str):
    boxes = max(4, _N[size] // 2048)
    per_box = 32
    k = _key("lava", size)
    pos = jax.random.normal(k, (boxes, per_box, 3))
    q = jax.random.normal(jax.random.fold_in(k, 1), (boxes, per_box))
    # self-box interactions (neighbor boxes elided: same arithmetic shape)
    d = pos[:, :, None, :] - pos[:, None, :, :]
    r2 = (d * d).sum(-1) + 0.5
    f = (q[:, :, None] * q[:, None, :] / r2)[..., None] * d
    force = f.sum(axis=2)
    n_int = boxes * per_box * per_box
    return force, Work(
        elems=n_int * 3, avg_vl=per_box, flops_per_elem=10,
        ordered_red_elems=n_int, indexed_elems=n_int // 4,
    )


# -- particlefilter -------------------------------------------------------------


def particlefilter(size: str, steps: int = 8):
    n = _N[size] // 8
    k = _key("pfil", size)

    def step(carry, kk):
        particles, = carry
        noise = jax.random.normal(kk, particles.shape) * 0.1
        particles = particles + noise
        w = jnp.exp(-0.5 * particles ** 2)
        w = w / w.sum()
        # systematic resampling (gather by cumulative weights)
        cum = jnp.cumsum(w)
        u = (jnp.arange(n) + 0.5) / n
        idx = jnp.searchsorted(cum, u)
        return (particles[idx],), None

    keys = jax.random.split(jax.random.fold_in(k, 9), steps)
    (particles,), _ = jax.lax.scan(
        step, (jax.random.normal(k, (n,)),), keys
    )
    # resampling/binning bookkeeping stays scalar (paper: 1.1x -> 2.0x,
    # growing with size as the vector phase amortizes)
    frac = {"simtiny": 0.75, "simsmall": 0.7, "simmedium": 0.5,
            "simlarge": 0.4}[size]
    return particles, Work(
        elems=n * steps * 6, avg_vl=min(256, n),
        flops_per_elem=4, ordered_red_elems=n * steps,
        indexed_elems=n * steps, serial_frac=frac,
    )


# -- canneal (short vectors + EW reshuffles: the pathological case) -------------


def canneal(size: str, swaps: int = 64):
    n_elem = _N[size] // 8
    rng = np.random.default_rng(7)
    netlist = rng.integers(0, n_elem, size=(n_elem, 10)).astype(np.int32)
    locs = jnp.asarray(rng.normal(size=(n_elem, 2)).astype(np.float32))
    nets = jnp.asarray(netlist)

    def swap_cost(locs, i, j):
        # routing cost of the two candidates' nets (vectors of ~10 elems)
        li = locs[nets[i]]            # [10, 2] short vector + indexed gather
        lj = locs[nets[j]]
        return jnp.abs(li - locs[i]).sum() + jnp.abs(lj - locs[j]).sum()

    total = 0.0
    idx = rng.integers(0, n_elem, size=(swaps, 2))
    for i, j in idx:
        total = total + swap_cost(locs, int(i), int(j))
    n_work = swaps * 2 * 10 * 2
    return total, Work(
        elems=n_work, avg_vl=10.0,          # paper: 5..22, avg 10
        flops_per_elem=3, indexed_elems=n_work,
        reshuffles=swaps * 2,               # EW reinterpretation per access
        ordered_red_elems=n_work,
    )


KERNELS = {
    "axpy": axpy,
    "blackscholes": blackscholes,
    "canneal": canneal,
    "jacobi-2d": jacobi2d,
    "lavaMD": lavamd,
    "matmul": matmul,
    "particlefilter": particlefilter,
    "pathfinder": pathfinder,
    "somier": somier,
    "spmv": spmv,
    "streamcluster": streamcluster,
    "swaptions": swaptions,
}
