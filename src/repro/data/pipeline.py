"""Deterministic synthetic data pipeline with multi-host sharding.

Production shape without production weight: a seeded, reproducible token
stream (Zipf-ish marginal over the vocab so losses move like language data),
document packing with loss masks, per-host slicing
(``process_index``-striped), and assembly into globally-sharded arrays.
Batches are keyed by (seed, step) — restart-safe: resuming at step K yields
the same batch K every time, which checkpoint/restart tests rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    mean_doc_len: int = 512      # document packing geometry
    pad_id: int = 0


class SyntheticLMStream:
    """Deterministic packed-LM batches: tokens/labels/mask (+ modality extras)."""

    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 data_cfg: DataConfig = DataConfig()):
        self.model_cfg = model_cfg
        self.shape = shape
        self.data_cfg = data_cfg
        v = model_cfg.vocab_size
        # Zipf-ish marginal, deterministic
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    # ---- host sharding ------------------------------------------------

    def host_batch_size(self) -> int:
        n = jax.process_count()
        b = self.shape.global_batch
        assert b % n == 0, f"global batch {b} not divisible by {n} hosts"
        return b // n

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.data_cfg.seed, step, jax.process_index())
        )

    # ---- batch synthesis ------------------------------------------------

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg, shape = self.model_cfg, self.shape
        b, s = self.host_batch_size(), shape.seq_len
        rng = self._rng(step)
        if cfg.family == "audio" and cfg.num_codebooks > 1:
            toks = rng.choice(
                cfg.vocab_size, size=(b, s + 1, cfg.num_codebooks),
                p=self._probs,
            ).astype(np.int32)
            out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            # packing mask over time
            out["mask"] = self._doc_mask(rng, b, s)
            return out
        toks = rng.choice(
            cfg.vocab_size, size=(b, s + 1), p=self._probs
        ).astype(np.int32)
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
            "mask": self._doc_mask(rng, b, s),
        }
        if cfg.family == "vlm":
            n_vis = min(256, s // 4)
            out["vision_embeds"] = rng.normal(
                size=(b, n_vis, cfg.d_model)
            ).astype(np.float32) * 0.02
            # M-RoPE positions: vision prefix gets a 2-D grid, text linear
            pos = np.broadcast_to(np.arange(s), (b, s)).copy()
            grid = int(np.sqrt(n_vis))
            t_pos, h_pos, w_pos = pos.copy(), pos.copy(), pos.copy()
            hh = (np.arange(n_vis) // max(grid, 1))
            ww = (np.arange(n_vis) % max(grid, 1))
            h_pos[:, :n_vis] = hh
            w_pos[:, :n_vis] = ww
            t_pos[:, :n_vis] = 0
            out["positions"] = np.stack([t_pos, h_pos, w_pos]).astype(np.int32)
        return out

    def _doc_mask(self, rng: np.random.Generator, b: int, s: int) -> np.ndarray:
        """Document-packing loss mask: 0 at (simulated) document boundaries."""
        mask = np.ones((b, s), np.float32)
        n_bounds = max(1, s // self.data_cfg.mean_doc_len)
        bounds = rng.integers(0, s, size=(b, n_bounds))
        rows = np.repeat(np.arange(b), n_bounds)
        mask[rows, bounds.reshape(-1)] = 0.0
        return mask

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_global_batch(
    host_batch: dict[str, np.ndarray],
    mesh: jax.sharding.Mesh,
    batch_spec: jax.sharding.PartitionSpec,
) -> dict[str, jax.Array]:
    """Assemble per-host arrays into globally-sharded jax.Arrays.

    Single-process: a device_put with the target sharding. Multi-process:
    ``jax.make_array_from_process_local_data`` stitches host shards.
    """
    def put(name: str, x: np.ndarray):
        if name == "positions" and x.ndim == 3:  # [3, B, S] — batch is dim 1
            spec = jax.sharding.PartitionSpec(None, *batch_spec)
        elif x.ndim >= 1:
            spec = jax.sharding.PartitionSpec(
                *batch_spec, *([None] * (x.ndim - 1))
            )
        else:
            spec = jax.sharding.PartitionSpec()
        sharding = jax.sharding.NamedSharding(mesh, spec)
        if jax.process_count() == 1:
            return jax.device_put(x, sharding)
        return jax.make_array_from_process_local_data(sharding, x)

    return {k: put(k, v) for k, v in host_batch.items()}
