"""Public jit'd wrappers for the kernel package.

These handle shape padding to block multiples, block-size selection, and
(for the gather path) the beyond-paper burst-coalescing optimization, so the
rest of the framework never deals with tiling details.  Every wrapper
dispatches to the Pallas kernel (``use_kernel=True``, default) or the pure
jnp oracle (``use_kernel=False`` — the XLA-native path used by dry-runs).

The Pallas kernels assume a single device's pool view (scalar-prefetched
page tables index local frames; no partitioning annotations), so they must
not be traced into a computation laid out over a >1-device mesh.  That
guard lives where the mesh does: the sharded serving executor swaps in a
ref-path twin of its model (``serve.executor._ref_path_model``) so every
wrapper here receives ``use_kernel=False`` under a multi-device mesh and
GSPMD partitions the jnp paths freely — while single-device callers (the
kernel differential grids, engines without a mesh) keep the kernel paths
live regardless of how many devices the process can see.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.common import round_up
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.matmul import matmul as _matmul_kernel
from repro.kernels.paged_attention import (
    paged_decode_attention as _paged_attn_kernel,
)
from repro.kernels.paged_prefill_attention import (
    paged_prefill_attention as _paged_prefill_kernel,
)
from repro.kernels.paged_copy import paged_copy as _paged_copy_kernel
from repro.kernels.paged_copy import paged_copy_at as _paged_copy_at_kernel
from repro.kernels.paged_gather import paged_gather as _paged_gather_kernel
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "use_kernel")
)
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype: jnp.dtype | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """``x @ y`` for arbitrary shapes (pads to MXU-aligned blocks)."""
    if not use_kernel:
        return ref.matmul_ref(x, y, out_dtype)
    m, k = x.shape
    _, n = y.shape
    bm_, bn_, bk_ = min(bm, round_up(m, 8)), min(bn, round_up(n, 128)), min(
        bk, round_up(k, 128)
    )
    mp, np_, kp = round_up(m, bm_), round_up(n, bn_), round_up(k, bk_)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _matmul_kernel(xp, yp, bm=bm_, bn=bn_, bk=bk_, out_dtype=out_dtype)
    return out[:m, :n]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "scale", "use_kernel")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    scale: float | None = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Blockwise attention; pads sequence lengths to block multiples.

    Padding is appended at the *end* of both Q and KV.  For causal
    attention padded KV tokens sit above every real query's diagonal, so
    they are masked structurally; padded Q rows are sliced off.
    """
    if not use_kernel:
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    bq_, bk_ = min(bq, round_up(sq, 8)), min(bk, round_up(sk, 128))
    sqp, skp = round_up(sq, bq_), round_up(sk, bk_)
    if not causal and (sqp != sq or skp != sk):
        raise ValueError("non-causal flash requires block-aligned shapes")
    scale = scale if scale is not None else d ** -0.5
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - sq), (0, 0)))
    kp_ = jnp.pad(k, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - sk), (0, 0)))
    # keep the causal diagonal anchored at the *end*: pad Q and KV equally
    out = _flash_kernel(
        qp, kp_, vp, causal=causal, bq=bq_, bk=bk_, scale=scale
    )
    return out[:, :, :sq]


paged_decode_attention = jax.jit(
    lambda q, k_pool, v_pool, page_table, seq_lens, *, page_size,
    scale=None, window=None, use_kernel=True, kv_scale=None: (
        _paged_attn_kernel(
            q, k_pool, v_pool, page_table, seq_lens,
            page_size=page_size, scale=scale, window=window
        )
        if use_kernel and kv_scale is None
        else ref.paged_decode_attention_ref(
            q, k_pool, v_pool, page_table, seq_lens,
            page_size=page_size, scale=scale, window=window,
            kv_scale=kv_scale,
        )
    ),
    static_argnames=("page_size", "scale", "window", "use_kernel",
                     "kv_scale"),
)


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "scale", "bq", "use_kernel", "kv_scale"),
)
def paged_prefill_attention(
    q: jax.Array,            # [B, S, Hkv, G, D] chunk queries
    k_pool: jax.Array,       # [P, page, Hkv, D]
    v_pool: jax.Array,       # [P, page, Hkv, D]
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32
    *,
    page_size: int,
    scale: float | None = None,
    bq: int = 32,
    use_kernel: bool = True,
    kv_scale: float | None = None,
) -> jax.Array:
    """Continuation-chunk attention through the page table.

    Kernel path streams KV pages per query block (one translation per
    page-bounded burst, pages above the causal diagonal skipped); the ref
    path gathers the whole logical prefix (the pre-kernel hot path, kept
    as the differential oracle).  int8 pools (``kv_scale``) dequantize on
    the gather path only, like ``paged_decode_attention``.
    """
    if use_kernel and kv_scale is None:
        return _paged_prefill_kernel(
            q, k_pool, v_pool, page_table, starts,
            page_size=page_size, scale=scale, bq=bq,
        )
    return ref.paged_prefill_attention_ref(
        q, k_pool, v_pool, page_table, starts,
        page_size=page_size, scale=scale, kv_scale=kv_scale,
    )


# ---------------------------------------------------------------------------
# paged memory movement
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_copy(
    src: jax.Array,
    pool: jax.Array,
    page_table: jax.Array,
    lens: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    if use_kernel:
        return _paged_copy_kernel(
            src, pool, page_table, lens, page_size=page_size
        )
    return ref.paged_copy_ref(src, pool, page_table, lens, page_size=page_size)


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_copy_at(
    src: jax.Array,
    pool: jax.Array,
    page_table: jax.Array,
    starts: jax.Array,
    lens: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    """Burst copy at arbitrary logical start offsets (continuation prefill)."""
    if use_kernel:
        return _paged_copy_at_kernel(
            src, pool, page_table, starts, lens, page_size=page_size
        )
    return ref.paged_copy_at_ref(
        src, pool, page_table, starts, lens, page_size=page_size
    )


@functools.partial(jax.jit, static_argnames=("page_size", "use_kernel"))
def paged_gather(
    pool: jax.Array,
    page_table_row: jax.Array,
    positions: jax.Array,
    *,
    page_size: int,
    use_kernel: bool = True,
) -> jax.Array:
    """Indexed gather, one translation per element (the paper's C2 cost)."""
    if use_kernel:
        return _paged_gather_kernel(
            pool, page_table_row, positions, page_size=page_size
        )
    return ref.paged_gather_ref(
        pool, page_table_row, positions, page_size=page_size
    )


@functools.partial(jax.jit, static_argnames=("page_size",))
def paged_gather_coalesced(
    pool: jax.Array,
    page_table_row: jax.Array,
    positions: jax.Array,
    *,
    page_size: int,
) -> jax.Array:
    """Beyond-paper: sort-coalesced indexed gather (per-PAGE translation).

    AraOS translates indexed accesses per element; sorting the indices first
    turns runs within a page into single bursts — the translation count
    drops from N to the number of *distinct pages touched* at the cost of a
    sort and an unpermute.  `benchmarks/bench_translation.py` quantifies the
    crossover.  Functionally identical to :func:`paged_gather`.
    """
    order = jnp.argsort(positions)
    sorted_pos = positions[order]
    gathered = ref.paged_gather_ref(
        pool, page_table_row, sorted_pos, page_size=page_size
    )
    inverse = jnp.argsort(order)
    return gathered[inverse]


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("bt", "use_kernel", "matmul_chunks")
)
def wkv6(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    initial_state: jax.Array | None = None,
    *,
    bt: int = 128,
    use_kernel: bool = True,
    matmul_chunks: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bh, t, n = r.shape
    if matmul_chunks and use_kernel and t % 32 == 0:
        # chunk-parallel Pallas kernel: the [C,C,N] intra-chunk tensor and
        # the state never leave VMEM (kernels/wkv6_chunked.py)
        from repro.kernels.wkv6_chunked import wkv6_chunked as _wkv6_ck
        return _wkv6_ck(r, k, v, w, u, initial_state, chunk=32)
    if not use_kernel:
        if matmul_chunks:
            # flash-linear-attention formulation: MXU matmuls, state
            # traffic / chunk (EXPERIMENTS.md §Perf cell C)
            return ref.wkv6_chunked_matmul_ref(
                r, k, v, w, u, initial_state, chunk=min(bt, 32)
            )
        return ref.wkv6_chunked_ref(r, k, v, w, u, initial_state, chunk=bt)
    bt_ = min(bt, t)
    tp = round_up(t, bt_)
    if tp != t:
        # pad with identity steps: w=1 (no decay), k=0 (no update), r=0
        pad = ((0, 0), (0, tp - t), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)
    o, s_fin = _wkv6_kernel(r, k, v, w, u, initial_state, bt=bt_)
    return o[:, :t], s_fin
