"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The real library is preferred (see ``requirements-dev.txt``); this shim
implements just the subset the test suite uses — ``given``, ``settings``,
and the ``integers`` / ``floats`` / ``lists`` / ``booleans`` strategies —
by drawing ``max_examples`` pseudo-random examples from a fixed seed.  No
shrinking, no database; failures reproduce exactly because the seed is
fixed.  Usage in test modules::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:                       # pragma: no cover
        from _prop_fallback import given, settings, st
"""

from __future__ import annotations

import functools
import inspect
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw


class st:  # noqa: N801 — mimics `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements._draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    @staticmethod
    def tuples(*parts: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(p._draw(rng) for p in parts))


def settings(max_examples: int = 20, deadline=None, **_kw):
    """Records the example budget on the (already ``given``-wrapped) test."""
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy, **kw_strategies: _Strategy):
    """Runs the test once per drawn example (seeded, deterministic)."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(0xA7A05)
            n = getattr(wrapper, "_max_examples", 20)
            for _ in range(n):
                drawn = [s._draw(rng) for s in strategies]
                drawn_kw = {k: s._draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **drawn_kw, **kwargs)
        # pytest must not treat the drawn parameters as fixtures: hide the
        # original signature (drop the trailing drawn args) and the
        # __wrapped__ attribute pytest would unwrap to.
        del wrapper.__wrapped__
        params = list(inspect.signature(fn).parameters.values())
        if strategies:
            params = params[: len(params) - len(strategies)]
        params = [p for p in params if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco
