"""Radix prefix-cache property suite (the ``prefix`` check.sh stage).

The radix layer's correctness claims, each pinned by a property or a
deterministic construction:

  1. IDENTITY — over random multi-turn workloads (shared page-aligned
     leading blocks, random tails, random arrival steps), a radix engine
     delivers exactly the streams a cold engine does, for N in {1, 2}
     router replicas.  On the fault-plane harness every stream has a
     closed form (``expected_output``), so a single wrong fork length,
     sliced prompt, or total-length miscount surfaces as a stream
     mismatch.
  2. EVICTION — registrations live exactly as long as their mapped run:
     when every sequence retires (refcounts drop to zero, pages unmap),
     the trie is empty and internally consistent.  No stale owner may
     ever be matched.
  3. ROUTING — the longest-matching-prefix score steers plain admissions
     to the replica holding the matched pages, while true COW forks keep
     their HARD affinity to a prefix-holding replica (the score must
     never override the constraint).
  4. SAMPLING — a prefix-hit admission consumes the executor PRNG stream
     exactly like cold prefill (one split per sample call), so
     temperature streams are bit-identical warm vs cold.  (Device test —
     the one test here that needs jax.)
"""

import copy

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                       # pragma: no cover
    from _prop_fallback import given, settings, st

from _fault_plane import (
    drive,
    drive_router,
    expected_output,
    make_replica,
)
from repro.serve import (
    Replica,
    ReplicaRouter,
    Request,
    ServeRequest,
    to_internal,
)

pytestmark = pytest.mark.prefix

PS = 4          # page size for every host-only replica here
VOCAB = 3000


def make_router(n, prefix_cache=True, **kw):
    replicas, planes = [], []
    for r in range(n):
        sched, plane = make_replica(page_size=PS, replica_id=r,
                                    prefix_cache=prefix_cache, **kw)
        replicas.append(Replica(replica_id=r, scheduler=sched, plane=plane))
        planes.append(plane)
    return ReplicaRouter(replicas), planes


def radix_workload(seed: int):
    """Random multi-turn-shaped arrivals: every prompt is a random-length
    page-aligned slice of one shared block plus a random tail, arriving
    at a random drive step — so later requests radix-hit whatever
    earlier ones happen to be resident, including nothing at all."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, VOCAB, size=int(rng.integers(1, 4)) * PS) \
        .astype(np.int32)
    submits = []
    for i in range(int(rng.integers(3, 7))):
        keep = int(rng.integers(0, len(base) // PS + 1)) * PS
        tail = rng.integers(0, VOCAB, size=int(rng.integers(1, 6))) \
            .astype(np.int32)
        submits.append((int(rng.integers(1, 20)), ServeRequest(
            req_id=i, prompt=np.concatenate([base[:keep], tail]),
            max_new_tokens=int(rng.integers(2, 7)),
        )))
    return submits


class TestTokenIdentityVsCold:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10**6))
    def test_radix_streams_equal_cold_streams(self, seed):
        submits = radix_workload(seed)
        closed_form = {r.req_id: expected_output(r) for _, r in submits}
        for n in (1, 2):
            outs = {}
            for warm in (True, False):
                router, planes = make_router(n, prefix_cache=warm)
                steps = drive_router(
                    router, planes,
                    submits=[(s, copy.deepcopy(r)) for s, r in submits],
                )
                assert steps < 500
                done = router.done
                assert all(r.status == "done" for r in done.values())
                outs[warm] = {rid: [int(x) for x in r.output]
                              for rid, r in done.items()}
                router.check_invariants()
            # warm == cold == the analytic per-request stream
            assert outs[True] == outs[False] == closed_form, f"N={n}"

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10**6))
    def test_reuse_accounting_is_consistent(self, seed):
        """prefix_hits/pages_reused/prefill_tokens_skipped move together:
        every hit skips >= one whole page of prefill and reuses >= one
        frame, and skipped tokens are always whole-page multiples."""
        sched, plane = make_replica(page_size=PS)
        for s, r in sorted(radix_workload(seed), key=lambda e: e[0]):
            plane._schedule = plane._schedule + \
                [("submit", s, to_internal(r))]
            plane._fired.append(False)
        drive(sched, plane)
        c = sched.counters
        hits = c.get("prefix_hits")
        assert c.get("prefill_tokens_skipped") % PS == 0
        assert c.get("prefill_tokens_skipped") >= hits * PS
        assert c.get("pages_reused") >= hits
        assert c.get("failed_unreachable") == 0


class TestEviction:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 10**6))
    def test_trie_empties_when_refcounts_drop_to_zero(self, seed):
        """Registration lifetime == mapped-run lifetime: after every
        request retires (all refcounts to zero, all pages unmapped) the
        radix trie holds no runs and no leaked nodes."""
        sched, plane = make_replica(page_size=PS)
        submits = [(s, copy.deepcopy(r)) for s, r in radix_workload(seed)]
        for s, r in sorted(submits, key=lambda e: e[0]):
            plane._schedule = plane._schedule + \
                [("submit", s, to_internal(r))]
            plane._fired.append(False)
        steps = drive(sched, plane)
        assert steps < 500 and not sched.has_work
        assert sched.vmem.num_seqs == 0          # everything retired
        assert sched.prefix_cache.num_runs == 0
        sched.prefix_cache.check_invariants()
        sched.vmem.check_invariants()

    def test_matched_owner_is_always_resident(self):
        """A probe can never return an evicted owner: retire the owner,
        and the next identical prompt must probe cold (then re-register
        itself)."""
        sched, plane = make_replica(page_size=PS)
        prompt = np.arange(500, 512, dtype=np.int32)
        sched.submit(Request(req_id=0, prompt=prompt.copy(),
                             max_new_tokens=2))
        drive(sched, plane)
        assert 0 not in sched.prefix_cache       # owner retired -> evicted
        matched, owner = sched.probe_prefix(
            Request(req_id=1, prompt=prompt.copy(), max_new_tokens=2))
        assert (matched, owner) == (0, None)
        sched.submit(Request(req_id=1, prompt=prompt.copy(),
                             max_new_tokens=2))
        drive(sched, plane)
        assert sched.counters.get("prefix_hits") == 0
        assert sched.done[1].status == "done"
        sched.prefix_cache.check_invariants()


class TestPrefixAwareRouting:
    PREFIX = np.arange(900, 908, dtype=np.int32)    # 2 whole pages

    def _router_with_prefix_on_replica0(self):
        router, planes = make_router(2)
        s0 = router.replicas[0].scheduler
        s0.vmem.map_seq(s0.PREFIX_ID, len(self.PREFIX))
        s0.prefix_len = len(self.PREFIX)
        s0.register_resident(s0.PREFIX_ID, self.PREFIX)
        return router, planes

    def test_matching_admission_routed_to_prefix_holder(self):
        """Blind least-loaded would pick empty replica 1 (replica 0 holds
        the pinned prefix pages); the prefix score must flip the choice
        to replica 0 and count it."""
        router, planes = self._router_with_prefix_on_replica0()
        r = ServeRequest(req_id=0,
                         prompt=np.concatenate([
                             self.PREFIX, np.arange(40, 44, dtype=np.int32)]),
                         max_new_tokens=3)
        router.submit(r)
        assert drive_router(router, planes) < 500
        assert router.counters.get("placements_replica0") == 1
        assert router.counters.get("placements_replica1") == 0
        assert router.counters.get("prefix_routed") == 1
        s0 = router.replicas[0].scheduler
        assert s0.counters.get("prefix_hits") == 1
        assert [int(x) for x in router.done[0].output] == expected_output(r)
        router.check_invariants()

    def test_non_matching_admission_stays_prefix_blind(self):
        router, planes = self._router_with_prefix_on_replica0()
        router.submit(ServeRequest(req_id=0,
                                   prompt=np.arange(40, 50, dtype=np.int32),
                                   max_new_tokens=3))
        assert drive_router(router, planes) < 500
        # least loaded: replica 1 (no pinned pages) — score added nothing
        assert router.counters.get("placements_replica1") == 1
        assert router.counters.get("prefix_routed") == 0
        router.check_invariants()

    def test_fork_affinity_stays_hard_over_prefix_score(self):
        """True COW forks rank prefix-blind under the HARD constraint:
        only prefix-holding replicas are eligible, however loaded —
        the additive score must not reopen the constraint."""
        router, planes = self._router_with_prefix_on_replica0()
        # load replica 0 well above replica 1 first
        filler = ServeRequest(req_id=0,
                              prompt=np.concatenate([
                                  self.PREFIX,
                                  np.arange(60, 64, dtype=np.int32)]),
                              max_new_tokens=8)
        fork = ServeRequest(req_id=1,
                            prompt=np.arange(70, 76, dtype=np.int32),
                            max_new_tokens=3, share_prefix=True)
        router.submit(filler)
        router.submit(fork)
        assert drive_router(router, planes) < 500
        assert router.counters.get("placements_replica0") == 2
        assert router.counters.get("placements_replica1") == 0
        assert all(r.status == "done" for r in router.done.values())
        router.check_invariants()


class TestTemperatureStreamIdentity:
    """Device-plane PRNG contract: a radix hit replaces ONE cold prefill
    sample call with ONE continuation-prefill sample call, so the
    executor's key-split sequence — and therefore every stochastic
    token — is identical warm vs cold."""

    @pytest.fixture(scope="class")
    def model_and_params(self):
        import jax

        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("qwen2-7b", reduced=True)
        model = build_model(cfg, remat=False)
        return cfg, model, model.init(jax.random.PRNGKey(0))

    def test_prefix_hit_temperature_stream_identical_to_cold(
            self, model_and_params):
        from repro.serve import Engine, ServeConfig
        cfg, model, params = model_and_params
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
        tails = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
                 for _ in range(2)]
        outs = {}
        for warm in (True, False):
            eng = Engine(model, params, ServeConfig(
                page_size=4, num_pages=64, max_pages_per_seq=16,
                max_batch=2, greedy=False, temperature=0.8, seed=3,
                prefix_cache=warm,
            ))
            eng.preload_prefix(prefix)
            streams = []
            # single-request admissions: one sample call per admission on
            # both paths keeps the split sequence aligned per request
            for i, tail in enumerate(tails):
                eng.submit(ServeRequest(
                    req_id=i, prompt=np.concatenate([prefix, tail]),
                    max_new_tokens=6))
                done = eng.run()
                streams.append([int(x) for x in done[i].output])
            outs[warm] = streams
            hits = eng.counters.get("prefix_hits")
            assert hits == (2 if warm else 0)
        assert outs[True] == outs[False]
