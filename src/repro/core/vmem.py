"""Paged virtual tensor memory — the TPU restatement of AraOS's MMU.

AraOS gives the Ara2 vector unit virtual memory by letting its address
generator (ADDRGEN) translate virtual addresses through CVA6's MMU before each
AXI burst.  On TPU there is no user-visible MMU, so the translation layer is
software: dynamically growing tensors (above all the serving KV cache and
per-request recurrent state) live in *physical pages* of a preallocated HBM
pool, and a per-sequence *page table* maps logical token positions to physical
pages.

This module owns:
  * :class:`PagePool`      — the physical frame allocator ("the OS");
  * :class:`VirtualMemory` — per-sequence page tables, fault-driven growth,
    refcounted sharing (copy-on-write prefix reuse), spill/restore hooks;
  * device-side pure functions (`logical_to_physical`, `gather_pages`) used
    inside jitted serve steps;
  * address-trace extraction for the TLB simulator (`burst_trace`,
    `element_trace`) — these produce the *actual* page-access streams the
    kernels issue, which drive the paper's Fig.-2 reproduction.

Host-side state is NumPy (it is scheduler state, mutated between steps);
device-side functions are pure JAX and jit-safe.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.faults import OutOfPagesError, PageFault

#: Sentinel for an unmapped page-table entry (like a cleared PTE valid bit).
INVALID_PAGE: int = -1


@dataclasses.dataclass(frozen=True)
class VMemConfig:
    """Geometry of the paged memory system.

    ``page_size`` is in *tokens*.  The default of 16 makes one page of one
    KV head a native ``(16, 128)`` VMEM tile: 16 tokens x 128 head_dim x
    2 B (bf16) = 4 KiB — the same burst granularity AXI enforces with 4-KiB
    pages (DESIGN.md §6.3).
    """

    page_size: int = 16
    num_pages: int = 1024
    max_pages_per_seq: int = 64
    max_seqs: int = 8

    def __post_init__(self) -> None:
        if self.page_size <= 0 or self.num_pages <= 0:
            raise ValueError("page_size and num_pages must be positive")
        if self.max_pages_per_seq <= 0 or self.max_seqs <= 0:
            raise ValueError("max_pages_per_seq and max_seqs must be positive")

    def pages_for(self, num_tokens: int) -> int:
        """Number of pages needed to back ``num_tokens`` tokens."""
        return -(-num_tokens // self.page_size)

    @property
    def max_tokens_per_seq(self) -> int:
        return self.max_pages_per_seq * self.page_size


class PagePool:
    """Physical frame allocator with refcounting.

    Refcounts support copy-on-write prefix sharing between requests (a
    beyond-paper feature mirroring vLLM's block sharing): a physical page may
    back the same logical prefix of several sequences; it is returned to the
    free list only when the last reference drops.
    """

    def __init__(self, num_pages: int):
        self.num_pages = int(num_pages)
        self._refcount = np.zeros(self.num_pages, dtype=np.int32)
        # LIFO free list: reuse hot frames first (cache friendliness).
        self._free: list[int] = list(range(self.num_pages - 1, -1, -1))
        self.alloc_count = 0
        self.fault_count = 0

    # ---- queries ------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return int(self._refcount[page])

    # ---- allocation ----------------------------------------------------

    def alloc(self, n: int = 1) -> list[int]:
        """Allocate ``n`` physical pages or raise :class:`OutOfPagesError`."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise OutOfPagesError(requested=n, available=len(self._free))
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self._refcount[p] == 0, f"free page {p} had refcount"
            self._refcount[p] = 1
        self.alloc_count += n
        return pages

    def share(self, page: int) -> int:
        """Add a reference to ``page`` (copy-on-write sharing)."""
        if self._refcount[page] <= 0:
            raise ValueError(f"cannot share unallocated page {page}")
        self._refcount[page] += 1
        return page

    def free(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; return pages that reach zero."""
        for p in pages:
            if p == INVALID_PAGE:
                continue
            if self._refcount[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(int(p))

    def check_invariants(self) -> None:
        """Allocator invariants (property-tested)."""
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "free list has duplicates"
        for p in self._free:
            assert self._refcount[p] == 0, f"free page {p} has refcount"
        assert int((self._refcount > 0).sum()) == self.num_used


@dataclasses.dataclass
class SeqState:
    """Host-side bookkeeping for one mapped sequence."""

    seq_id: int
    slot: int                     # row in the batch page table
    length: int                   # tokens currently mapped
    pages: list[int]              # physical pages, logical order


class VirtualMemory:
    """Per-sequence page tables over a shared :class:`PagePool`.

    This is the "OS" of the serving engine: it owns the satp-equivalent (the
    batch page-table array handed to kernels), handles page faults by
    allocating frames on demand, and exposes spill/restore for context
    switches.
    """

    def __init__(self, config: VMemConfig):
        self.config = config
        self.pool = PagePool(config.num_pages)
        self._seqs: dict[int, SeqState] = {}
        self._free_slots: list[int] = list(range(config.max_seqs - 1, -1, -1))
        # NumPy mirror of the device page table.
        self._table = np.full(
            (config.max_seqs, config.max_pages_per_seq), INVALID_PAGE, np.int32
        )
        self._lens = np.zeros(config.max_seqs, dtype=np.int32)
        # rows whose PTEs changed since the last ``drain_dirty_rows`` — the
        # device-resident copy of the table (serve.Executor) is updated
        # incrementally from these deltas instead of re-uploaded wholesale.
        self._dirty_rows: set[int] = set()
        # observers of mapping teardown (unmap_seq / spill_seq): the serve
        # prefix cache keys its radix index off these so it never
        # advertises pages whose frames have been freed.
        self._unmap_hooks: list = []

    # ---- queries ------------------------------------------------------

    @property
    def num_seqs(self) -> int:
        return len(self._seqs)

    def seq(self, seq_id: int) -> SeqState:
        return self._seqs[seq_id]

    def has_seq(self, seq_id: int) -> bool:
        return seq_id in self._seqs

    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].length

    @property
    def num_free_slots(self) -> int:
        return len(self._free_slots)

    def add_unmap_hook(self, fn) -> None:
        """Register ``fn(seq_id)`` to fire whenever a sequence's mapping is
        torn down — retirement (:meth:`unmap_seq`), preemption
        (:meth:`spill_seq`), or a fork rollback.  The serve-plane prefix
        cache uses this to evict its index entries the moment the page run
        they describe stops being resident (refcounts may drop to zero)."""
        self._unmap_hooks.append(fn)

    def device_page_table(self) -> jnp.ndarray:
        """The satp analogue: `[max_seqs, max_pages_per_seq] int32`."""
        return jnp.asarray(self._table)

    def device_seq_lens(self) -> jnp.ndarray:
        return jnp.asarray(self._lens)

    def drain_dirty_rows(self) -> tuple[np.ndarray, np.ndarray]:
        """Rows of the page table mutated since the last drain.

        Returns ``(row_indices [D] int32, row_contents [D, max_pages] int32)``
        and clears the dirty set.  The serving executor applies these as a
        scatter into its persistent device-side table — the decode hot path
        never re-uploads the whole satp array.
        """
        rows = np.asarray(sorted(self._dirty_rows), np.int32)
        self._dirty_rows.clear()
        return rows, self._table[rows].copy()

    # ---- mapping ------------------------------------------------------

    def map_seq(self, seq_id: int, num_tokens: int) -> SeqState:
        """Map a new sequence with ``num_tokens`` tokens (prefill).

        Raises :class:`OutOfPagesError` if the pool cannot back it — callers
        (the scheduler) respond by preempting a victim (context switch).
        """
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already mapped")
        if num_tokens > self.config.max_tokens_per_seq:
            raise ValueError(
                f"seq of {num_tokens} tokens exceeds page-table reach "
                f"{self.config.max_tokens_per_seq}"
            )
        if not self._free_slots:
            raise OutOfPagesError(requested=1, available=0, kind="slots")
        n_pages = self.config.pages_for(num_tokens)
        pages = self.pool.alloc(n_pages)  # may raise OutOfPagesError
        slot = self._free_slots.pop()
        state = SeqState(seq_id=seq_id, slot=slot, length=num_tokens, pages=pages)
        self._seqs[seq_id] = state
        self._table[slot, :n_pages] = pages
        self._lens[slot] = num_tokens
        self._dirty_rows.add(slot)
        return state

    def fork_seq(self, parent_id: int, child_id: int, prefix_tokens: int) -> SeqState:
        """Map ``child_id`` sharing the parent's full-page prefix (COW).

        Only whole pages are shared; a partially filled tail page is copied
        by the caller (it owns the data arrays).
        """
        parent = self._seqs[parent_id]
        if prefix_tokens > parent.length:
            raise ValueError("prefix longer than parent")
        if not self._free_slots:
            raise OutOfPagesError(requested=1, available=0, kind="slots")
        whole = prefix_tokens // self.config.page_size
        shared = [self.pool.share(p) for p in parent.pages[:whole]]
        tail = self.config.pages_for(prefix_tokens) - whole
        try:
            own = self.pool.alloc(tail)
        except OutOfPagesError:
            self.pool.free(shared)
            raise
        pages = shared + own
        slot = self._free_slots.pop()
        state = SeqState(seq_id=child_id, slot=slot, length=prefix_tokens, pages=pages)
        self._seqs[child_id] = state
        self._table[slot, : len(pages)] = pages
        self._lens[slot] = prefix_tokens
        self._dirty_rows.add(slot)
        return state

    def append_tokens(self, seq_id: int, n: int = 1) -> list[PageFault]:
        """Extend a sequence by ``n`` tokens, faulting in new pages.

        Returns the list of page faults taken (empty if the tail page had
        room).  Each fault allocates a frame on demand — the vstart-style
        *element index* of the fault is recorded so benchmarks can model the
        paper's mid-instruction fault cost.  Raises OutOfPagesError if the
        pool is exhausted; the sequence is left unmodified in that case
        (precise-exception semantics: architectural state is only committed
        once all translations succeed).
        """
        state = self._seqs[seq_id]
        new_len = state.length + n
        if new_len > self.config.max_tokens_per_seq:
            raise ValueError("sequence exceeds page-table reach")
        need = self.config.pages_for(new_len) - len(state.pages)
        faults: list[PageFault] = []
        if need > 0:
            first_new_page = len(state.pages)
            pages = self.pool.alloc(need)  # may raise; state untouched
            self.pool.fault_count += need
            self._dirty_rows.add(state.slot)
            for i, p in enumerate(pages):
                lpn = first_new_page + i
                self._table[state.slot, lpn] = p
                faults.append(
                    PageFault(
                        seq_id=seq_id,
                        logical_page=lpn,
                        vstart=lpn * self.config.page_size - state.length,
                    )
                )
            state.pages.extend(pages)
        state.length = new_len
        self._lens[state.slot] = new_len
        return faults

    def append_tokens_batch(
        self, grows: Sequence[tuple[int, int]]
    ) -> list[PageFault]:
        """All-or-nothing growth of several sequences at once.

        The serving scheduler pre-faults every page a fused K-step decode
        horizon will touch through ONE call, so the device page table is
        flushed once per horizon (``drain_dirty_rows``) instead of once per
        token.  ``grows`` is ``[(seq_id, n_tokens), ...]``.  If the pool
        cannot back the ENTIRE batch, :class:`OutOfPagesError` is raised
        with no sequence modified (precise-exception semantics, batch-wide)
        — callers collapse the horizon to K=1 and fall back to the
        per-step fault path, which may preempt.
        """
        need = 0
        for seq_id, n in grows:
            state = self._seqs[seq_id]
            new_len = state.length + n
            if new_len > self.config.max_tokens_per_seq:
                raise ValueError("sequence exceeds page-table reach")
            need += max(0, self.config.pages_for(new_len) - len(state.pages))
        if need > self.pool.num_free:
            raise OutOfPagesError(requested=need, available=self.pool.num_free)
        faults: list[PageFault] = []
        for seq_id, n in grows:
            if n > 0:
                faults.extend(self.append_tokens(seq_id, n))
        return faults

    def unmap_seq(self, seq_id: int) -> None:
        state = self._seqs.pop(seq_id)
        self.pool.free(state.pages)
        self._table[state.slot, :] = INVALID_PAGE
        self._lens[state.slot] = 0
        self._free_slots.append(state.slot)
        self._dirty_rows.add(state.slot)
        for fn in self._unmap_hooks:
            fn(seq_id)

    # ---- spill / restore (context switch) --------------------------------

    def spill_seq(self, seq_id: int) -> SeqState:
        """Release a sequence's frames for preemption, returning its state.

        The caller (context_switch.py) is responsible for copying the page
        *data* out before calling this; VirtualMemory only manages mappings.
        """
        state = self._seqs.pop(seq_id)
        self.pool.free(state.pages)
        self._table[state.slot, :] = INVALID_PAGE
        self._lens[state.slot] = 0
        self._free_slots.append(state.slot)
        self._dirty_rows.add(state.slot)
        for fn in self._unmap_hooks:
            fn(seq_id)
        return state

    def restore_seq(self, seq_id: int, num_tokens: int,
                    shared_prefix_pages: Sequence[int] | None = None
                    ) -> SeqState:
        """Re-map a previously spilled sequence (frames may differ).

        ``shared_prefix_pages``: physical frames, still resident under
        another mapping (in practice the pinned engine prefix), to re-SHARE
        as the sequence's leading pages by refcount instead of demanding
        fresh frames.  The caller guarantees their content already equals
        the corresponding spilled bytes (whole shared pages are immutable
        while refcounted), so only the unshared tail needs frames — the
        reason a victim whose footprint exceeds the preemptible pool can
        still be restorable.

        ``num_tokens`` may be any page-aligned-or-shorter prefix of the
        spilled length (a PARTIAL restore): the scheduler re-maps the
        longest prefix that fits now and re-prefills the evicted tail
        through the continuation path, so this layer only ever sees a
        smaller ``num_tokens`` — no partial-mapping state exists here.
        """
        if not shared_prefix_pages:
            return self.map_seq(seq_id, num_tokens)
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already mapped")
        if num_tokens > self.config.max_tokens_per_seq:
            raise ValueError(
                f"seq of {num_tokens} tokens exceeds page-table reach "
                f"{self.config.max_tokens_per_seq}"
            )
        if not self._free_slots:
            raise OutOfPagesError(requested=1, available=0, kind="slots")
        n_pages = self.config.pages_for(num_tokens)
        if len(shared_prefix_pages) > n_pages:
            raise ValueError("more shared pages than the sequence spans")
        shared = [self.pool.share(p) for p in shared_prefix_pages]
        try:
            own = self.pool.alloc(n_pages - len(shared))
        except OutOfPagesError:
            self.pool.free(shared)
            raise
        pages = shared + own
        slot = self._free_slots.pop()
        state = SeqState(seq_id=seq_id, slot=slot, length=num_tokens,
                         pages=pages)
        self._seqs[seq_id] = state
        self._table[slot, :n_pages] = pages
        self._lens[slot] = num_tokens
        self._dirty_rows.add(slot)
        return state

    # ---- translation (host-side, trace-producing) -------------------------

    def translate(self, seq_id: int, positions: np.ndarray) -> np.ndarray:
        """Translate token positions to flat physical slot indices.

        Raises :class:`PageFault` (as an exception) on an unmapped position,
        carrying the vstart-equivalent index of the first faulting element —
        mirroring Ara2 stopping the ADDRGEN at the faulty element.
        """
        state = self._seqs[seq_id]
        positions = np.asarray(positions)
        bad = positions >= state.length
        if bad.any():
            first = int(np.argmax(bad))
            raise PageFault(
                seq_id=seq_id,
                logical_page=int(positions[first]) // self.config.page_size,
                vstart=first,
            )
        vpn = positions // self.config.page_size
        off = positions % self.config.page_size
        ppn = self._table[state.slot, vpn]
        return ppn * self.config.page_size + off

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        seen: set[int] = set()
        for s in self._seqs.values():
            assert len(s.pages) == self.config.pages_for(s.length)
            for p in s.pages:
                assert self.pool.refcount(p) >= 1
            mapped = self._table[s.slot, : len(s.pages)]
            assert (mapped == np.asarray(s.pages, np.int32)).all()
            assert s.slot not in seen
            seen.add(s.slot)


# ===========================================================================
# Device-side pure functions (jit-safe)
# ===========================================================================


def logical_to_physical(
    positions: jnp.ndarray, page_table_row: jnp.ndarray, page_size: int
) -> jnp.ndarray:
    """Translate logical token positions to flat physical slots (pure JAX).

    ``positions``: int32 [...] token positions of one sequence.
    ``page_table_row``: int32 [max_pages_per_seq] physical page numbers.
    Returns int32 [...] of ``ppn * page_size + offset``.
    """
    vpn = positions // page_size
    off = positions % page_size
    ppn = page_table_row[vpn]
    return ppn * page_size + off


def gather_pages(
    kv_pool: jnp.ndarray, page_table_row: jnp.ndarray, num_pages: int
) -> jnp.ndarray:
    """Gather ``num_pages`` physical pages into logical order.

    ``kv_pool``: [num_phys_pages, page_size, ...] physical storage.
    Returns [num_pages, page_size, ...] in logical page order.
    """
    return jnp.take(kv_pool, page_table_row[:num_pages], axis=0)


# ===========================================================================
# Address-trace extraction (feeds the TLB simulator)
# ===========================================================================


def burst_trace(positions: Sequence[int] | np.ndarray, page_size: int) -> np.ndarray:
    """VPN trace for a *unit-stride* access: one translation per page burst.

    AXI bursts are clipped at page boundaries, so a contiguous vector access
    of N tokens issues one MMU request per page touched, in order (paper C2).
    """
    positions = np.asarray(positions)
    vpn = positions // page_size
    # collapse consecutive repeats: one burst per page-run
    if vpn.size == 0:
        return vpn.astype(np.int64)
    keep = np.ones(vpn.shape, dtype=bool)
    keep[1:] = vpn[1:] != vpn[:-1]
    return vpn[keep].astype(np.int64)


def element_trace(positions: Sequence[int] | np.ndarray, page_size: int) -> np.ndarray:
    """VPN trace for an *indexed* access: one translation per element.

    AraOS pays a dedicated translation per element on indexed memory ops to
    keep exceptions precise — the reason spmv/canneal underperform (paper
    §3.2).  No run-collapsing here.
    """
    positions = np.asarray(positions)
    return (positions // page_size).astype(np.int64)
