"""Loop-aware cost analysis of post-SPMD optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` sums every computation ONCE —
a ``lax.scan`` over 95 layers reports 1/95th of the real FLOPs, bytes and
collective traffic.  This analyzer re-derives per-device costs from
``compiled.as_text()`` with while-loop trip counts applied
(``backend_config={"known_trip_count":{"n":...}}``, emitted for all
counted loops; fall back to the largest integer constant in the loop
condition computation).

Accounting conventions:
  * FLOPs: 2*prod(out_dims)*prod(contracting_dims) per dot (batch dims are
    part of out_dims); elementwise ops contribute prod(out) for arithmetic
    opcodes.  Fusion computations are recursed (their dots count; their
    elementwise internals count once per fusion execution).
  * bytes: per *top-level* instruction, output + operand bytes (XLA's own
    per-op convention); fusion internals are NOT counted (fused values
    never touch HBM); parameter/gte/tuple/bitcast/constant are free.
  * collectives: output-shape bytes per op (bytes received per device),
    multiplied by enclosing trip counts; async -done halves skipped.
"""

from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "u64": 8,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota",
}
_COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start", "all-to-all-start", "reduce-scatter-start",
}
_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "log",
    "tanh", "rsqrt", "sqrt", "maximum", "minimum", "negate", "abs",
    "exponential-minus-one", "logistic", "cosine", "sine",
}


def _shapes_of(text: str) -> list[tuple[str, list[int]]]:
    return [
        (dt, [int(x) for x in dims.split(",")] if dims else [])
        for dt, dims in _SHAPE_RE.findall(text)
    ]


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes_of(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(text: str) -> int:
    total = 0
    for _, dims in _shapes_of(text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


_SCOPE_RE = re.compile(r'op_name="([^"]*)"')

#: coarse buckets for scope attribution (profile-style reporting)
_SCOPE_BUCKETS = (
    ("attention", ("bhgqd", "bhgqk", "bhkd", "attention", "flash", "paged")),
    ("moe", ("ragged_dot", "moe", "top_k", "expert")),
    ("optimizer", ("adamw", "transpose(jvp", "sqrt", "optimizer")),
    ("embedding", ("embed", "take", "gather")),
    ("loss", ("logsumexp", "xent", "log_softmax")),
)


def scope_bucket(op_name: str) -> str:
    low = op_name.lower()
    if "transpose(jvp" in low:
        return "backward"
    for bucket, keys in _SCOPE_BUCKETS:
        if any(k in low for k in keys):
            return bucket
    return "other"


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    opcode: str
    rest: str          # everything after the opcode's '('

    def operands(self) -> list[str]:
        # operand list = inside the first balanced paren group of `rest`
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND_RE.findall(self.rest[:i])
        return _OPERAND_RE.findall(self.rest)

    def attrs(self) -> str:
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return self.rest[i + 1:]
        return ""


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    bytes_by_scope: dict = dataclasses.field(default_factory=dict)
    flops_by_scope: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for attr in ("coll_by_kind", "coll_counts", "bytes_by_scope",
                     "flops_by_scope"):
            mine, theirs = getattr(self, attr), getattr(other, attr)
            for k, v in theirs.items():
                mine[k] = mine.get(k, 0) + v * mult

    def tag(self, scope: str, flops: float, nbytes: float) -> None:
        self.bytes_by_scope[scope] = self.bytes_by_scope.get(scope, 0) + nbytes
        self.flops_by_scope[scope] = self.flops_by_scope.get(scope, 0) + flops


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Costs] = {}
        self.entry = self._entry_name

    # ---- parsing ----------------------------------------------------------

    def _parse(self, text: str) -> None:
        cur: list[Instr] | None = None
        cur_name = None
        self._entry_name = None
        for line in text.splitlines():
            if cur is None:
                m = _HEADER_RE.match(line)
                if m:
                    cur_name = m.group(1)
                    cur = []
                    if line.startswith("ENTRY"):
                        self._entry_name = cur_name
                continue
            if line.strip() == "}":
                self.computations[cur_name] = cur
                cur = None
                continue
            mi = _INSTR_RE.match(line)
            if mi:
                cur.append(Instr(*mi.groups()))

    # ---- cost recursion ---------------------------------------------------

    def _trip_count(self, instr: Instr) -> int:
        m = _TRIP_RE.search(instr.attrs())
        if m:
            return int(m.group(1))
        # fallback: biggest integer constant in the condition computation
        mc = _COND_RE.search(instr.attrs())
        if mc and mc.group(1) in self.computations:
            consts = [
                int(x)
                for ins in self.computations[mc.group(1)]
                if ins.opcode == "constant"
                for x in re.findall(r"constant\((\d+)", "constant(" + ins.rest)
            ]
            if consts:
                return max(consts)
        return 1

    def _dot_flops(self, instr: Instr, symtab: dict[str, str]) -> float:
        out_elems = _elems_of(instr.out_type)
        attrs = instr.attrs()
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
        contracting = [int(x) for x in m.group(1).split(",")] if (
            m and m.group(1)
        ) else []
        ops = instr.operands()
        lhs_dims: list[int] = []
        if ops and ops[0] in symtab:
            shapes = _shapes_of(symtab[ops[0]])
            if shapes:
                lhs_dims = shapes[0][1]
        k = 1
        for c in contracting:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
        return 2.0 * out_elems * max(k, 1)

    def compute(self, comp_name: str) -> Costs:
        if comp_name in self._memo:
            return self._memo[comp_name]
        total = Costs()
        instrs = self.computations.get(comp_name, [])
        symtab = {i.name: i.out_type for i in instrs}
        for instr in instrs:
            op = instr.opcode
            if op in _FREE_OPS:
                continue
            if op == "while":
                trips = self._trip_count(instr)
                body = _CALLS_RE.search(instr.attrs())
                if body:
                    total.add(self.compute(body.group(1)), trips)
                cond = _COND_RE.search(instr.attrs())
                if cond:
                    total.add(self.compute(cond.group(1)), trips)
                continue
            if op in ("call", "conditional", "async-start"):
                for target in _CALLS_RE.findall(instr.attrs()):
                    total.add(self.compute(target))
                continue
            if op in _COLLECTIVE_OPS or op.rstrip("-done") in _COLLECTIVE_OPS:
                if op.endswith("-done"):
                    continue
                kind = op.replace("-start", "")
                b = _bytes_of(instr.out_type)
                # TPU-projection: XLA:CPU float-normalizes bf16 params to
                # f32 *before* SPMD inserts the gathers; on TPU the wire
                # format stays bf16.  Count float collectives at 2 B/elem.
                f32_b = _bytes_of(instr.out_type.replace("f32[", "@["))
                n_f32 = (b - f32_b) // 4 if b > f32_b else 0
                b -= 2 * n_f32
                total.coll_bytes += b
                total.coll_by_kind[kind] = total.coll_by_kind.get(kind, 0) + b
                total.coll_counts[kind] = total.coll_counts.get(kind, 0) + 1
                total.bytes += b
                continue
            sm = _SCOPE_RE.search(instr.attrs())
            scope = scope_bucket(sm.group(1)) if sm else "other"
            flops_i = 0.0
            root_op = op
            if op == "fusion":
                m = _CALLS_RE.search(instr.attrs())
                if m:
                    inner = self.compute(m.group(1))
                    # dots inside fusions still burn MXU flops; fused
                    # elementwise/bytes stay on-chip -> only flops recurse
                    flops_i = inner.flops
                    root_op = self._root_opcode(m.group(1))
            elif op == "dot":
                flops_i = self._dot_flops(instr, symtab)
            elif op == "convolution":
                flops_i = 2.0 * _elems_of(instr.out_type)  # lower bound
            elif op in _ELEMENTWISE_FLOP_OPS or op in (
                "reduce", "reduce-window", "sort", "map", "scatter",
                "select-and-scatter",
            ):
                flops_i = float(_elems_of(instr.out_type))
            bytes_i = self._instr_bytes(instr, symtab, root_op)
            total.flops += flops_i
            total.bytes += bytes_i
            total.tag(scope, flops_i, bytes_i)
        self._memo[comp_name] = total
        return total

    def _root_opcode(self, comp_name: str) -> str:
        instrs = self.computations.get(comp_name, [])
        return instrs[-1].opcode if instrs else "fusion"

    def _fusion_param_bytes(self, comp_name: str,
                            op_bytes: list[float]) -> float:
        """Traffic for a fusion's parameters: a parameter whose only
        consumers inside the fusion are slice-type ops is charged at the
        consumers' output size (the fusion reads one layer of a stacked
        scan operand, not the whole stack); other parameters are charged
        fully (elementwise/reduce fusions read everything)."""
        instrs = self.computations.get(comp_name, [])
        symtab = {i.name: i.out_type for i in instrs}
        params: dict[int, str] = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.match(r"(\d+)", i.rest)
                if m:
                    params[int(m.group(1))] = i.name
        total = 0.0
        for idx, pname in params.items():
            if idx >= len(op_bytes):
                continue
            consumers = [
                i for i in instrs if pname in i.operands()
            ]
            slicey = consumers and all(
                i.opcode in ("dynamic-slice", "slice", "gather")
                for i in consumers
            )
            if slicey:
                total += sum(_bytes_of(i.out_type) for i in consumers)
            else:
                total += op_bytes[idx]
        return total

    def _instr_bytes(self, instr: Instr, symtab: dict[str, str],
                     root_op: str) -> float:
        """HBM traffic per instruction, matching TPU buffer-assignment
        behavior for the in-place slice family:

          * dynamic-slice / gather read only the addressed region (~= the
            output), not the whole operand;
          * dynamic-update-slice / scatter write in place: traffic is the
            update region (+ indices), not the full buffer (the big operand
            is aliased to the output);
          * everything else: output + operands (XLA's own convention).
        """
        out_b = _bytes_of(instr.out_type)
        ops = instr.operands()
        op_bytes = [_bytes_of(symtab.get(o, "")) for o in ops]
        if root_op in ("convert", "bitcast", "copy") and ops:
            # TPU-projection rule: XLA:CPU's FloatNormalization materializes
            # bf16<->f32 copies of whole buffers (CPU has no native bf16
            # dot/scatter) and layout copies; TPU executes bf16 natively and
            # fuses such converts.  A same-element-count convert/copy chain
            # is counted as free (methodology note in EXPERIMENTS.md).
            if any(_elems_of(symtab.get(o, "")) == _elems_of(instr.out_type)
                   for o in ops):
                return 0.0
        if root_op in ("dynamic-slice", "gather"):
            small = sum(b for b in op_bytes if b < out_b)
            return 2.0 * out_b + small
        if root_op in ("dynamic-update-slice", "scatter",
                       "select-and-scatter"):
            # exclude the aliased full buffer (the largest operand ~= out);
            # traffic = read updates/indices + write the touched region
            if op_bytes:
                rest = sum(op_bytes) - max(op_bytes)
                return 2.0 * rest
            return out_b
        if instr.opcode == "fusion":
            m = _CALLS_RE.search(instr.attrs())
            if m and m.group(1) in self.computations:
                return out_b + self._fusion_param_bytes(m.group(1), op_bytes)
        return out_b + sum(op_bytes)

    def entry_costs(self) -> Costs:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        return self.compute(self.entry)


def analyze(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.entry_costs()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collective_bytes_by_kind": {
            k: float(v) for k, v in c.coll_by_kind.items()
        },
        "collective_counts": {
            k: float(v) for k, v in c.coll_counts.items()
        },
        "bytes_by_scope": {k: float(v) for k, v in c.bytes_by_scope.items()},
        "flops_by_scope": {k: float(v) for k, v in c.flops_by_scope.items()},
    }
