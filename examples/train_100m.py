"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

Exercises the full training substrate — synthetic packed data pipeline,
sharded model (when >1 device), AdamW + cosine schedule + clipping, gradient
accumulation, async atomic checkpointing, auto-resume — and verifies the
loss drops substantially below its initial value.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.data import DataConfig, SyntheticLMStream
from repro.models import build_model
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import AdamWConfig
from repro.train import Trainer

# ~100M params: 12 layers, d=512, vocab 32k
CFG = ModelConfig(
    name="lm-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32_000,
    head_dim=64, param_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--accum-steps", type=int, default=2)
    args = ap.parse_args()

    model = build_model(CFG, remat=True)
    print(f"params: {CFG.param_count()/1e6:.0f}M")
    shape = ShapeConfig("ex", args.seq_len, args.global_batch, "train")
    stream = SyntheticLMStream(CFG, shape, DataConfig(seed=7))
    opt = AdamWConfig(base_lr=1e-3, warmup_steps=20, total_steps=args.steps)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(model, opt, ckpt_dir=ckpt_dir, ckpt_every=100,
                          accum_steps=args.accum_steps)
        params, opt_state, start = trainer.init_or_restore(
            jax.random.PRNGKey(0)
        )
        batch_fn = lambda s: {k: jnp.asarray(v)
                              for k, v in stream.batch(s).items()}
        t0 = time.perf_counter()
        params, opt_state, hist = trainer.run(
            params, opt_state, batch_fn, start, args.steps, log_every=25
        )
        dt = time.perf_counter() - t0
    tokens = args.steps * args.global_batch * args.seq_len
    print(f"\n{args.steps} steps / {tokens/1e6:.1f}M tokens in {dt:.0f}s")
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"lr {h['lr']:.2e}  |g| {h['grad_norm']:.2f}")
    drop = hist[0]["loss"] - hist[-1]["loss"]
    print(f"loss drop: {drop:.3f} "
          f"({hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f})")
    assert drop > 0.5, "training failed to learn"


if __name__ == "__main__":
    main()
