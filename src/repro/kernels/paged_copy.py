"""Unit-stride paged copy — one translation per page-bounded burst (C2-burst).

Prefill writes freshly computed K/V tokens (logical order) into physical
pages of the shared pool.  Like Ara2's VLSU, the copy is issued as unit-stride
bursts clipped at page boundaries: grid step ``(b, s)`` moves logical page
``s`` of sequence ``b`` into the physical frame the scalar-prefetched page
table names — exactly one translation per burst, performed in the output
index map *before* the store is issued.

A partially-filled tail page is handled read-modify-write: the existing frame
content is an input block at the same translated index, and tokens at or
beyond the sequence's new length keep the old bytes (precise commit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv, should_interpret


def _paged_copy_kernel(
    lens_ref,         # SMEM [B]   number of valid new tokens per sequence
    page_table_ref,   # SMEM [B, max_pages]
    src_ref,          # VMEM [1, page, W]
    old_ref,          # VMEM [1, page, W]   existing frame content
    o_ref,            # VMEM [1, page, W]   the translated frame
    *,
    page_size: int,
):
    del page_table_ref
    b, s = pl.program_id(0), pl.program_id(1)
    n_valid = lens_ref[b] - s * page_size  # valid tokens in this burst
    tok = jax.lax.broadcasted_iota(jnp.int32, src_ref.shape, 1)
    o_ref[...] = jnp.where(tok < n_valid, src_ref[...], old_ref[...])


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_copy(
    src: jax.Array,          # [B, S, W] new tokens, logical order
    pool: jax.Array,         # [P, page, W] physical pool (updated)
    page_table: jax.Array,   # [B, max_pages] int32
    lens: jax.Array,         # [B] int32 — tokens of src actually valid
    *,
    page_size: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Write ``src[b, :lens[b]]`` through the page table. Returns new pool."""
    if interpret is None:
        interpret = should_interpret()
    b, s, w = src.shape
    n_frames, page, _ = pool.shape
    assert page == page_size
    n_bursts = cdiv(s, page_size)
    if s % page_size:
        src = jnp.pad(src, ((0, 0), (0, n_bursts * page_size - s), (0, 0)))

    # Bursts past a sequence's end have no mapped frame.  They must not be
    # routed to a real frame: their old_ref is the *pre-copy* pool, so a
    # read-modify-write against frame 0 would clobber fresh data written to
    # frame 0 by an earlier burst.  Route them to a trash frame instead
    # (production pools reserve this spare frame up front).
    trash = n_frames
    pool = jnp.pad(pool, ((0, 1), (0, 0), (0, 0)))

    def frame_index(bi, si, lens_ref, page_table_ref):
        del lens_ref
        entry = page_table_ref[bi, si]
        return (jnp.where(entry < 0, trash, entry), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_bursts),
        in_specs=[
            pl.BlockSpec((1, page_size, w), lambda bi, si, *_: (bi, si, 0)),
            pl.BlockSpec((1, page_size, w), frame_index),
        ],
        out_specs=pl.BlockSpec((1, page_size, w), frame_index),
    )
    out = pl.pallas_call(
        functools.partial(_paged_copy_kernel, page_size=page_size),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        grid_spec=grid_spec,
        input_output_aliases={3: 0},  # pool is updated in place
        interpret=interpret,
    )(lens.astype(jnp.int32), page_table.astype(jnp.int32),
      src.astype(pool.dtype), pool)
    return out[:-1]  # drop the trash frame


# ---------------------------------------------------------------------------
# continuation copy: bursts starting at an arbitrary logical offset
# ---------------------------------------------------------------------------


def _paged_copy_at_kernel(
    starts_ref,       # SMEM [B]   logical start position per sequence
    lens_ref,         # SMEM [B]   number of valid new tokens per sequence
    page_table_ref,   # SMEM [B, max_pages]
    src_ref,          # VMEM [1, page, W]  offset-aligned chunk tokens
    old_ref,          # VMEM [1, page, W]  existing frame content
    o_ref,            # VMEM [1, page, W]  the translated frame
    *,
    page_size: int,
):
    del page_table_ref
    b, s = pl.program_id(0), pl.program_id(1)
    off = starts_ref[b] % page_size
    # token u of this burst sits at shifted chunk index s*page + u; it is a
    # real chunk token iff it falls inside the [off, off + len) window
    u = s * page_size + jax.lax.broadcasted_iota(jnp.int32, src_ref.shape, 1)
    valid = (u >= off) & (u < off + lens_ref[b])
    o_ref[...] = jnp.where(valid, src_ref[...], old_ref[...])


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def paged_copy_at(
    src: jax.Array,          # [B, S, W] chunk tokens, logical order
    pool: jax.Array,         # [P, page, W] physical pool (updated)
    page_table: jax.Array,   # [B, max_pages] int32
    starts: jax.Array,       # [B] int32 — logical position of src[:, 0]
    lens: jax.Array,         # [B] int32 — tokens of src actually valid
    *,
    page_size: int,
    interpret: bool | None = None,
) -> jax.Array:
    """Write ``src[b, :lens[b]]`` at logical positions ``starts[b]...``.

    The continuation-prefill burst engine: chunk token ``t`` of sequence
    ``b`` lands at logical position ``starts[b] + t``, translated through
    the page table one burst per touched page (C2-burst, same contract as
    :func:`paged_copy`).  ``starts`` need not be page-aligned: the source
    is pre-shifted by ``starts % page`` so every burst stays page-aligned
    in both source and destination, and the first/last partial pages are
    handled read-modify-write (precise commit, existing bytes kept).
    """
    if interpret is None:
        interpret = should_interpret()
    b, s, w = src.shape
    n_frames, page, _ = pool.shape
    assert page == page_size
    # +1 burst: an unaligned window [start, start+S) can straddle one extra
    # page boundary compared to the aligned case.
    s_pad = cdiv(s, page_size) * page_size
    n_bursts = s_pad // page_size + 1
    s2 = n_bursts * page_size
    starts = starts.astype(jnp.int32)
    # shift each row right by its page offset: shifted[b, off + t] = src[b, t]
    off = (starts % page_size)[:, None]                        # [B, 1]
    idx = (jnp.arange(s2)[None, :] - off) % s2                 # [B, S2]
    srcp = jnp.pad(src, ((0, 0), (0, s2 - s), (0, 0)))
    src_shifted = jnp.take_along_axis(srcp, idx[:, :, None], axis=1)

    trash = n_frames
    pool = jnp.pad(pool, ((0, 1), (0, 0), (0, 0)))
    max_pages = page_table.shape[1]

    def frame_index(bi, si, starts_ref, lens_ref, page_table_ref):
        del lens_ref
        vpn = starts_ref[bi] // page_size + si
        entry = page_table_ref[bi, jnp.minimum(vpn, max_pages - 1)]
        bad = (entry < 0) | (vpn >= max_pages)
        return (jnp.where(bad, trash, entry), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_bursts),
        in_specs=[
            pl.BlockSpec((1, page_size, w), lambda bi, si, *_: (bi, si, 0)),
            pl.BlockSpec((1, page_size, w), frame_index),
        ],
        out_specs=pl.BlockSpec((1, page_size, w), frame_index),
    )
    out = pl.pallas_call(
        functools.partial(_paged_copy_at_kernel, page_size=page_size),
        out_shape=jax.ShapeDtypeStruct(pool.shape, pool.dtype),
        grid_spec=grid_spec,
        input_output_aliases={4: 0},  # pool is updated in place
        interpret=interpret,
    )(starts, lens.astype(jnp.int32), page_table.astype(jnp.int32),
      src_shifted.astype(pool.dtype), pool)
    return out[:-1]  # drop the trash frame
