"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention.

Block pattern (arXiv:2402.19427): repeating (recurrent, recurrent, local
attention) — a 1:2 local-attn:RG-LRU ratio.  38 layers = 12 scanned
super-blocks of 3 + a 2-layer recurrent tail.

Recurrent block:   x -> [gelu(W_gate x)] * [RG-LRU(conv1d_4(W_x x))] -> W_out
RG-LRU:            r_t = sig(W_r x_t), i_t = sig(W_i x_t)
                   a_t = exp(-c * softplus(L) * r_t)           (c = 8)
                   h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * x_t)
Training uses an associative scan over time (log-depth — the sub-quadratic
path that qualifies this arch for ``long_500k``); decode is a single
recurrence step.

Local attention: MQA (kv=1) with a sliding window; serving uses the paged KV
cache with *window*-bounded masking, so the engine only keeps the last
``window`` tokens mapped — pages behind the window are freed (a paging win
impossible with a contiguous cache; DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]
CONV_WIDTH = 4
RGLRU_C = 8.0


class HybridState(NamedTuple):
    """Serving state: recurrent slabs + paged KV for the attention layers."""

    rg_h: jax.Array        # [n_rec, B, R]      RG-LRU hidden state
    conv_buf: jax.Array    # [n_rec, B, CONV_WIDTH-1, R] causal conv tail
    k_pools: jax.Array     # [n_att, P, page, 1, hd]
    v_pools: jax.Array     # [n_att, P, page, 1, hd]
    page_table: jax.Array  # [B, max_pages]
    seq_lens: jax.Array    # [B]

    @property
    def page_size(self) -> int:
        return self.k_pools.shape[3]


def _rglru_scan(a: jax.Array, bx: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + bx_t along axis 1, first-order linear scan.

    a, bx: [B, T, R]; h0 [B, R].  Associative combine:
    (a1, b1) . (a2, b2) = (a1*a2, a2*b1 + b2).
    """
    bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


class RecurrentGemmaLM:
    def __init__(self, cfg: ModelConfig, *, use_kernels: bool = False,
                 remat: bool = True, shard=None):
        assert cfg.family == "hybrid_rglru"
        self.cfg = cfg
        self.use_kernels = use_kernels
        self.remat = remat
        self.shard = shard or (lambda x, name: x)
        self.dtype = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
            cfg.param_dtype
        ]
        pattern = cfg._full_pattern()
        self.pattern_len = len(cfg.block_pattern)
        self.n_super = cfg.num_layers // self.pattern_len
        self.n_tail = cfg.num_layers - self.n_super * self.pattern_len
        tail_pattern = cfg.block_pattern[: self.n_tail]
        assert all(p == "rglru" for p in tail_pattern), (
            "tail layers must be recurrent (pattern starts with rglru)"
        )
        self.n_rec = sum(1 for p in pattern if p == "rglru")
        self.n_att = sum(1 for p in pattern if p == "local")

    @property
    def rdim(self) -> int:
        return self.cfg.rglru_dim or self.cfg.d_model

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------

    def _init_recurrent(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        d, r = cfg.d_model, self.rdim
        ks = jax.random.split(key, 6)
        return {
            "ln": L.rmsnorm_init(d, dt),
            "w_gate": L.dense_init(ks[0], d, r, dt),
            "w_x": L.dense_init(ks[1], d, r, dt),
            "conv_w": (jax.random.normal(ks[2], (CONV_WIDTH, r), jnp.float32)
                       * 0.1).astype(dt),
            "w_r": L.dense_init(ks[3], r, r, dt),
            "w_i": L.dense_init(ks[4], r, r, dt),
            "lam": jax.random.uniform(
                jax.random.fold_in(key, 7), (r,), jnp.float32, 0.4, 0.8
            ),  # Lambda, pre-softplus
            "w_out": L.dense_init(ks[5], r, d, dt),
            "ln2": L.rmsnorm_init(d, dt),
            "mlp": L.swiglu_init(jax.random.fold_in(key, 8), d, cfg.d_ff, dt),
        }

    def _init_attention(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        return {
            "ln": L.rmsnorm_init(cfg.d_model, dt),
            "attn": L.attention_init(key, cfg, dt),
            "ln2": L.rmsnorm_init(cfg.d_model, dt),
            "mlp": L.swiglu_init(jax.random.fold_in(key, 1), cfg.d_model,
                                 cfg.d_ff, dt),
        }

    def _init_superblock(self, key) -> Params:
        ks = jax.random.split(key, self.pattern_len)
        p: Params = {}
        rec_i = 0
        for i, kind in enumerate(self.cfg.block_pattern):
            if kind == "rglru":
                p[f"rec{rec_i}"] = self._init_recurrent(ks[i])
                rec_i += 1
            else:
                p["attn"] = self._init_attention(ks[i])
        return p

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        k_emb, k_sb, k_tail, k_head = jax.random.split(key, 4)
        p: Params = {
            "embed": L.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
            "supers": jax.vmap(self._init_superblock)(
                jax.random.split(k_sb, self.n_super)
            ),
            "ln_f": L.rmsnorm_init(cfg.d_model, dt),
            "head": L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
        }
        if self.n_tail:
            p["tail"] = jax.vmap(self._init_recurrent)(
                jax.random.split(k_tail, self.n_tail)
            )
        return p

    # ------------------------------------------------------------------
    # recurrent block (train path: associative scan over time)
    # ------------------------------------------------------------------

    def _recurrent_block(
        self, p: Params, x: jax.Array,
        h0: jax.Array, conv_buf: jax.Array,
    ) -> tuple[jax.Array, jax.Array, jax.Array]:
        """x [B, T, D]; h0 [B, R]; conv_buf [B, CONV_WIDTH-1, R].

        Returns (out, h_final, new_conv_buf).
        """
        cfg = self.cfg
        xn = L.rmsnorm(p["ln"], x, cfg.norm_eps)
        gate = jax.nn.gelu(xn @ p["w_gate"])                 # [B, T, R]
        u = xn @ p["w_x"]                                     # [B, T, R]
        # causal conv1d over time (width 4), carrying the previous tail
        u_ext = jnp.concatenate([conv_buf.astype(u.dtype), u], axis=1)
        conv = sum(
            u_ext[:, i : i + u.shape[1], :] * p["conv_w"][i]
            for i in range(CONV_WIDTH)
        )
        new_conv_buf = u_ext[:, -(CONV_WIDTH - 1):, :]
        # RG-LRU
        conv32 = conv.astype(jnp.float32)
        r = jax.nn.sigmoid(conv32 @ p["w_r"].astype(jnp.float32))
        i = jax.nn.sigmoid(conv32 @ p["w_i"].astype(jnp.float32))
        log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r      # [B, T, R] f32
        a = jnp.exp(log_a)
        bx = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * conv32)
        h = _rglru_scan(a, bx, h0.astype(jnp.float32))        # [B, T, R]
        out = (gate * h.astype(gate.dtype)) @ p["w_out"]
        x = x + out
        x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, h[:, -1, :], new_conv_buf

    def _attention_block_train(self, p, x, positions):
        cfg = self.cfg
        h = L.attention_train(
            p["attn"], L.rmsnorm(p["ln"], x, cfg.norm_eps), positions, cfg,
            window=cfg.local_window,
        )
        x = x + h
        return x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))

    # ------------------------------------------------------------------
    # training forward
    # ------------------------------------------------------------------

    def forward(self, params: Params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        b, t = tokens.shape
        r = self.rdim
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        x = params["embed"][tokens]
        n_rec_per_super = sum(
            1 for k in cfg.block_pattern if k == "rglru"
        )
        h0 = jnp.zeros((n_rec_per_super, b, r), jnp.float32)
        conv0 = jnp.zeros((n_rec_per_super, b, CONV_WIDTH - 1, r), self.dtype)

        def body(carry, sb_params):
            x = carry
            x = self.shard(x, "act_btd")
            rec_i = 0
            for kind in cfg.block_pattern:
                if kind == "rglru":
                    x, _, _ = self._recurrent_block(
                        sb_params[f"rec{rec_i}"], x,
                        h0[rec_i], conv0[rec_i],
                    )
                    rec_i += 1
                else:
                    x = self._attention_block_train(
                        sb_params["attn"], x, positions
                    )
            return x, None

        f = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(f, x, params["supers"])
        if self.n_tail:
            def tail_body(carry, tp):
                out, _, _ = self._recurrent_block(
                    tp, carry, h0[0], conv0[0]
                )
                return out, None
            ft = jax.checkpoint(tail_body) if self.remat else tail_body
            x, _ = jax.lax.scan(ft, x, params["tail"])
        return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)

    def loss(self, params: Params, batch: dict[str, jax.Array]):
        h = self.forward(params, batch["tokens"])
        logits = self.shard(h @ params["head"], "logits")
        xent = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
        return xent, {"xent": xent, "aux": jnp.float32(0.0)}

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def init_state(self, batch: int, num_pages: int, page_size: int,
                   max_pages: int) -> HybridState:
        cfg = self.cfg
        r = self.rdim
        return HybridState(
            rg_h=jnp.zeros((self.n_rec, batch, r), jnp.float32),
            conv_buf=jnp.zeros(
                (self.n_rec, batch, CONV_WIDTH - 1, r), self.dtype
            ),
            k_pools=jnp.zeros(
                (self.n_att, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim), self.dtype,
            ),
            v_pools=jnp.zeros(
                (self.n_att, num_pages, page_size, cfg.num_kv_heads,
                 cfg.head_dim), self.dtype,
            ),
            page_table=jnp.full((batch, max_pages), -1, jnp.int32),
            seq_lens=jnp.zeros((batch,), jnp.int32),
        )

    def _attention_block_serve(
        self, p, x, k_pool, v_pool, page_table, kv_lens, positions,
        prompt_lens=None,
    ):
        """Serve-path attention. x [B, T, D].  For prefill (T>1) writes KV
        bursts + windowed flash; for decode (T==1) writes one row + paged
        windowed attention."""
        cfg = self.cfg
        b, t, _ = x.shape
        hkv, hd, g = cfg.num_kv_heads, cfg.head_dim, cfg.q_per_kv
        page = k_pool.shape[1]
        q, k, v = L.qkv_project(p["attn"], L.rmsnorm(p["ln"], x, cfg.norm_eps), cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        if t > 1:  # prefill
            k_pool = ops.paged_copy(
                k.reshape(b, t, hkv * hd), k_pool.reshape(-1, page, hkv * hd),
                page_table, prompt_lens, page_size=page,
                use_kernel=self.use_kernels,
            ).reshape(k_pool.shape)
            v_pool = ops.paged_copy(
                v.reshape(b, t, hkv * hd), v_pool.reshape(-1, page, hkv * hd),
                page_table, prompt_lens, page_size=page,
                use_kernel=self.use_kernels,
            ).reshape(v_pool.shape)
            qt, kt, vt = (z.swapaxes(1, 2) for z in (q, k, v))
            from repro.kernels import ref as _ref
            o = _ref.chunked_attention_ref(
                qt, kt, vt, causal=True, window=cfg.local_window
            )
            o = o.swapaxes(1, 2).reshape(b, t, -1)
        else:  # decode
            pos = kv_lens - 1  # new token position (kv_lens includes it)
            frames = jnp.take_along_axis(
                page_table, (pos // page)[:, None], axis=1
            )[:, 0]
            # inactive slots -> reserved scratch row (see transformer.py)
            n_rows = k_pool.shape[0] * page
            rows = jnp.where(
                frames < 0, n_rows - 1, frames * page + pos % page
            )
            k_pool = k_pool.reshape(-1, hkv, hd).at[rows].set(
                k[:, 0]
            ).reshape(k_pool.shape)
            v_pool = v_pool.reshape(-1, hkv, hd).at[rows].set(
                v[:, 0]
            ).reshape(v_pool.shape)
            qh = q[:, 0].reshape(b, hkv, g, hd)
            o = ops.paged_decode_attention(
                qh, k_pool, v_pool, page_table, kv_lens,
                page_size=page, window=cfg.local_window,
                use_kernel=self.use_kernels,
            ).reshape(b, 1, hkv * g * hd)
        x = x + o @ p["attn"]["wo"]
        x = x + L.swiglu(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x, k_pool, v_pool

    def _serve_pass(self, params, x, state: HybridState, positions,
                    prompt_lens=None, kv_lens=None):
        """Shared prefill/decode layer sweep (host-unrolled; 38 layers)."""
        cfg = self.cfg
        rg_h, conv_buf = [], []
        k_pools, v_pools = [], []
        rec_i = att_i = 0
        pattern = cfg._full_pattern()
        for li, kind in enumerate(pattern):
            if kind == "rglru":
                si, pi = divmod(rec_i, sum(
                    1 for k in cfg.block_pattern if k == "rglru"
                ))
                if li < self.n_super * self.pattern_len:
                    p = jax.tree.map(
                        lambda z: z[si], params["supers"][f"rec{pi}"]
                    )
                else:
                    p = jax.tree.map(
                        lambda z: z[li - self.n_super * self.pattern_len],
                        params["tail"],
                    )
                x, h_fin, cb = self._recurrent_block(
                    p, x, state.rg_h[rec_i], state.conv_buf[rec_i]
                )
                rg_h.append(h_fin)
                conv_buf.append(cb)
                rec_i += 1
            else:
                si = att_i
                p = jax.tree.map(lambda z: z[si], params["supers"]["attn"])
                x, kp, vp = self._attention_block_serve(
                    p, x, state.k_pools[att_i], state.v_pools[att_i],
                    state.page_table, kv_lens, positions, prompt_lens,
                )
                k_pools.append(kp)
                v_pools.append(vp)
                att_i += 1
        new_state = HybridState(
            rg_h=jnp.stack(rg_h),
            conv_buf=jnp.stack(conv_buf),
            k_pools=jnp.stack(k_pools),
            v_pools=jnp.stack(v_pools),
            page_table=state.page_table,
            seq_lens=kv_lens,
        )
        return L.rmsnorm(params["ln_f"], x, cfg.norm_eps), new_state

    @functools.partial(jax.jit, static_argnums=(0,))
    def prefill(self, params, tokens, prompt_lens, state: HybridState):
        b, t = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(t), (b, t))
        x = params["embed"][tokens]
        h, new_state = self._serve_pass(
            params, x, state, positions,
            prompt_lens=prompt_lens.astype(jnp.int32),
            kv_lens=prompt_lens.astype(jnp.int32),
        )
        last = jnp.take_along_axis(
            h, jnp.maximum(prompt_lens - 1, 0)[:, None, None], axis=1
        )[:, 0]
        return last @ params["head"], new_state

    @functools.partial(jax.jit, static_argnums=(0,))
    def decode_step(self, params, tokens, state: HybridState):
        b = tokens.shape[0]
        pos = state.seq_lens                        # new token position
        x = params["embed"][tokens][:, None, :]
        h, new_state = self._serve_pass(
            params, x, state, pos[:, None], kv_lens=pos + 1,
        )
        return h[:, 0] @ params["head"], new_state
