"""Sharded-executor acceptance tests (marker: ``sharded``).

The tentpole contract of the mesh-aware executor: laying the KV pools out
over the ('kv', 'hd') serve mesh — with the page table and every
scalar-plane operand replicated — must be INVISIBLE to the serving
semantics.  Token streams (greedy and temperature), scheduler counters and
preempt/fork/restore behavior must all match the single-device executor
exactly; only the data-plane layout changes.  The Scheduler is untouched
by construction (the PR 1 split), so any divergence here is an executor
sharding bug.

These tests need more than one XLA device.  On CPU, force host devices
BEFORE the process first touches jax:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m pytest -q -m sharded

With a single visible device every test skips cleanly (the guarded stage
in ``scripts/check.sh`` and the CI ``multidevice`` job set the flag).
"""

import copy

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_serve_mesh
from repro.models import build_model
from repro.serve import Engine, ServeConfig, ServeRequest

pytestmark = [
    pytest.mark.sharded,
    pytest.mark.skipif(
        jax.device_count() < 2,
        reason="needs >1 XLA device; set "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8",
    ),
]

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)
    return cfg, model, model.init(KEY), mesh


def workload(cfg, n, seed, max_new=12, lo=4, hi=14, share=False):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(lo, hi))
                                         ).astype(np.int32),
                     max_new_tokens=max_new, share_prefix=share)
        for i in range(n)
    ]


def run_engine(model, params, serve_cfg, reqs, mesh=None, prefix=None):
    eng = Engine(model, params, serve_cfg, mesh=mesh)
    if prefix is not None:
        eng.preload_prefix(prefix)
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    done = eng.run()
    return eng, {i: [int(x) for x in done[i].output] for i in done}


def assert_actually_sharded(eng):
    """The mesh run must really span devices — a silently degraded 1x1
    mesh would make every identity assertion vacuous."""
    assert len(eng.executor.kv.k_pools.sharding.device_set) > 1
    eng.executor.check_sharding_invariants()


class TestMeshFactorization:
    def test_axes_divide_model_dims(self, setup):
        cfg, _, _, mesh = setup
        assert mesh.axis_names == ("kv", "hd")
        assert cfg.num_kv_heads % mesh.shape["kv"] == 0
        assert cfg.head_dim % mesh.shape["hd"] == 0
        assert 1 < mesh.size <= jax.device_count()

    def test_degrades_to_single_device(self):
        # prime dims no device count > 1 can divide: must fall back to 1x1
        mesh = make_host_serve_mesh(1, 1)
        assert mesh.size == 1


class TestTokenIdentity:
    @pytest.mark.parametrize("k", [1, 4])
    def test_greedy_identity_forced_horizons(self, setup, k):
        """Roomy pool, batch admitted in one wave, horizon forced to K —
        the fused sharded dispatch must reproduce the single-device
        stream for both the unfused and fused ladder rungs."""
        cfg, model, params, mesh = setup
        reqs = workload(cfg, n=3, seed=7, lo=5, hi=10)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3,
                                max_horizon=k)
        single, out_s = run_engine(model, params, serve_cfg, reqs)
        shard, out_m = run_engine(model, params, serve_cfg, reqs, mesh=mesh)
        assert out_s == out_m
        assert_actually_sharded(shard)
        # sharding must not change a single scheduler-visible event
        for c in ("decode_tokens", "decode_dispatches", "decode_horizon",
                  "host_syncs", "ptab_syncs", "page_faults"):
            assert single.counters.get(c) == shard.counters.get(c), c
        if k > 1:
            assert (shard.counters.get("decode_dispatches")
                    < shard.counters.get("decode_horizon"))

    def test_temperature_stream_identity(self, setup):
        """On-device categorical sampling: the PRNG key threading (one
        split per inner step) must survive sharding bit-for-bit."""
        cfg, model, params, mesh = setup
        reqs = workload(cfg, n=3, seed=11, lo=5, hi=10)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3,
                                greedy=False, temperature=0.8)
        _, out_s = run_engine(model, params, serve_cfg, reqs)
        shard, out_m = run_engine(model, params, serve_cfg, reqs, mesh=mesh)
        assert out_s == out_m
        assert_actually_sharded(shard)


class TestSpillRestoreSharded:
    def test_preempting_workload_identity(self, setup):
        """Tight pool: page-granular spill/restore moves sharded pool
        slices through host swap records and back; layouts and token
        streams must both survive."""
        cfg, model, params, mesh = setup
        reqs = workload(cfg, n=7, seed=13)
        serve_cfg = ServeConfig(page_size=4, num_pages=16,
                                max_pages_per_seq=16, max_batch=3)
        single, out_s = run_engine(model, params, serve_cfg, reqs)
        shard, out_m = run_engine(model, params, serve_cfg, reqs, mesh=mesh)
        # the workload must actually exercise the context-switch path
        assert shard.counters.get("preemptions") > 0
        assert (single.counters.get("preemptions")
                == shard.counters.get("preemptions"))
        assert (single.counters.get("restores")
                == shard.counters.get("restores"))
        assert out_s == out_m
        assert_actually_sharded(shard)
        st = shard.executor.switcher.stats
        assert st.bytes_spilled > 0 and st.bytes_restored > 0

    def test_forked_prefix_workload_identity(self, setup):
        """Shared-prefix forks: COW tail-page copies + batched
        continuation prefill run through the sharded dispatches."""
        cfg, model, params, mesh = setup
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
        reqs = workload(cfg, n=5, seed=17, max_new=10, lo=4, hi=10,
                        share=True)
        serve_cfg = ServeConfig(page_size=4, num_pages=32,
                                max_pages_per_seq=16, max_batch=3)
        single, out_s = run_engine(model, params, serve_cfg, reqs,
                                   prefix=prefix)
        shard, out_m = run_engine(model, params, serve_cfg, reqs, mesh=mesh,
                                  prefix=prefix)
        assert shard.counters.get("forked_admissions") > 0
        assert (single.counters.get("fork_batches")
                == shard.counters.get("fork_batches"))
        assert out_s == out_m
        assert_actually_sharded(shard)


class TestShardedKernelDispatch:
    """Kernels stay LIVE on the mesh (the PR that killed the ref-path
    fallback).  A kernel-built model under a >1-device mesh dispatches the
    real Pallas kernels through shard_map on each device's local pool
    slice; the jnp twin survives only behind the explicit
    ``ServeConfig.use_ref_path`` escape hatch."""

    def test_kernels_stay_live_on_mesh(self, setup):
        cfg, model, params, mesh = setup
        kmodel = build_model(cfg, remat=False, use_kernels=True)
        reqs = workload(cfg, n=3, seed=23, lo=5, hi=10)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3)
        ksingle, out_s = run_engine(kmodel, params, serve_cfg, reqs)
        shard, out_m = run_engine(kmodel, params, serve_cfg, reqs, mesh=mesh)
        # the step model is a mesh twin with kernels ON, not the jnp twin
        assert shard.executor._step_model is not kmodel
        assert shard.executor._step_model.use_kernels is True
        assert shard.executor._step_model.kernel_mesh is mesh
        assert kmodel.kernel_mesh is None          # original untouched
        # every compute step went through the kernel path on both sides
        assert shard.counters.get("ref_path_dispatches") == 0
        assert shard.counters.get("kernel_dispatches") > 0
        assert (shard.counters.get("kernel_dispatches")
                == ksingle.counters.get("kernel_dispatches"))
        # ...and the sharded kernels reproduce the single-device kernels
        assert out_s == out_m
        assert_actually_sharded(shard)

    def test_explicit_ref_path_escape_hatch(self, setup):
        """``use_ref_path=True`` (--no-kernels) is the ONLY remaining way
        to get the jnp twin, and every step it serves is counted."""
        cfg, model, params, mesh = setup
        kmodel = build_model(cfg, remat=False, use_kernels=True)
        reqs = workload(cfg, n=3, seed=23, lo=5, hi=10)
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=3,
                                use_ref_path=True)
        shard, out_m = run_engine(kmodel, params, serve_cfg, reqs, mesh=mesh)
        assert shard.executor._step_model.use_kernels is False
        assert shard.counters.get("ref_path_dispatches") > 0
        assert shard.counters.get("kernel_dispatches") == 0
        # the hatch must agree with the kernels-off fixture model's stream
        _, out_ref = run_engine(
            model, params,
            ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=32,
                        max_batch=3), reqs)
        assert out_m == out_ref
        assert_actually_sharded(shard)

    def test_kernel_path_spill_restore_sharded(self, setup):
        """Satellite regression: page-granular spill of a shard-local pool
        slice under the LIVE kernel path.  ``spill`` now re-checks the
        sharding invariants right after ``switcher.spill_kv`` (previously
        only restore did), so a spill that de-shards the pools fails here
        rather than corrupting layouts silently."""
        cfg, model, params, mesh = setup
        kmodel = build_model(cfg, remat=False, use_kernels=True)
        reqs = workload(cfg, n=7, seed=13)
        serve_cfg = ServeConfig(page_size=4, num_pages=16,
                                max_pages_per_seq=16, max_batch=3)
        ksingle, out_s = run_engine(kmodel, params, serve_cfg, reqs)
        shard, out_m = run_engine(kmodel, params, serve_cfg, reqs, mesh=mesh)
        assert shard.counters.get("preemptions") > 0
        assert shard.counters.get("ref_path_dispatches") == 0
        assert out_s == out_m
        assert_actually_sharded(shard)
        st, ss = shard.executor.switcher.stats, ksingle.executor.switcher.stats
        # spill stayed page-granular: same victim pages and bytes as the
        # single-device kernel run, and bytes = pages x per-page KV bytes
        assert (st.pages_spilled, st.bytes_spilled) == \
               (ss.pages_spilled, ss.bytes_spilled)
        page_bytes = (cfg.num_layers * serve_cfg.page_size
                      * cfg.num_kv_heads * cfg.head_dim
                      * shard.executor.kv.k_pools.dtype.itemsize)
        assert st.bytes_spilled == st.pages_spilled * page_bytes
