"""Benchmark-harness validation: RiVEC kernels vs NumPy oracles, cycle-model
sanity, TLB-sweep paper claims, HLO cost-model parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import bench_rivec
from benchmarks.rivec_kernels import KERNELS


class TestRiVECKernels:
    """Numerical correctness of the vectorized kernels (simtiny size)."""

    def test_axpy(self):
        out, _ = KERNELS["axpy"]("simtiny")
        assert out.shape == (1024,)
        assert np.isfinite(np.asarray(out)).all()

    def test_blackscholes_positive_prices(self):
        out, _ = KERNELS["blackscholes"]("simtiny")
        assert (np.asarray(out) >= -1e-4).all()  # f32 rounding at the ATM edge

    def test_matmul_vs_numpy(self):
        c, _ = KERNELS["matmul"]("simtiny")
        # regenerate inputs the same way
        from benchmarks.rivec_kernels import _key
        k = _key("matmul", "simtiny")
        a = jax.random.normal(k, (64, 64))
        b = jax.random.normal(jax.random.fold_in(k, 1), (64, 64))
        np.testing.assert_allclose(np.asarray(c), np.asarray(a) @ np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    def test_spmv_vs_numpy(self):
        out, w = KERNELS["spmv"]("simtiny")
        rng = np.random.default_rng(42)
        n, nnz = 64, 5
        cols = rng.integers(0, n, size=(n, nnz)).astype(np.int32)
        vals = rng.normal(size=(n, nnz)).astype(np.float32)
        x = rng.normal(size=(n,)).astype(np.float32)
        expect = (vals * x[cols]).sum(1)
        np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5,
                                   atol=1e-5)
        assert w.indexed_elems == n * nnz  # per-element translation counted

    def test_pathfinder_monotone(self):
        out, _ = KERNELS["pathfinder"]("simtiny")
        assert (np.asarray(out) >= 0).all()

    def test_all_kernels_run_and_report_work(self):
        for name, fn in KERNELS.items():
            out, w = fn("simtiny")
            jax.block_until_ready(out)
            assert w.elems > 0, name
            assert w.avg_vl >= 1, name


class TestBenchRegressSections:
    """The BENCH_serve.json regression gate compares like with like: the
    trajectory interleaves ``serve`` and ``router`` records, and each
    section must be gated against its OWN previous record (a serve record
    compared against a router record would gate nothing — or the wrong
    thing)."""

    @pytest.fixture(scope="class")
    def regress(self):
        import importlib.util
        import pathlib
        path = (pathlib.Path(__file__).resolve().parent.parent
                / "scripts" / "bench_regress.py")
        spec = importlib.util.spec_from_file_location("bench_regress", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    @staticmethod
    def _serve_metrics(syncs):
        return {"host_syncs_per_token": syncs, "mean_horizon": 3.0,
                "sweep": {"auto": {"ptab_syncs_per_tok": syncs}}}

    @staticmethod
    def _router_metrics(syncs):
        return {"host_syncs_per_token": syncs, "mean_horizon": 2.0,
                "sweep": {"2": {"ptab_syncs_per_tok": syncs}}}

    def _history(self, tmp_path, records):
        import json
        p = tmp_path / "BENCH_serve.json"
        p.write_text(json.dumps(records))
        return str(p)

    def test_sections_compared_independently(self, regress, tmp_path,
                                             capsys):
        # serve improves while router regresses: only [router] must fail
        path = self._history(tmp_path, [
            {"t": "t0", "section": "serve",
             "metrics": self._serve_metrics(0.5)},
            {"t": "t1", "section": "router",
             "metrics": self._router_metrics(0.3)},
            {"t": "t2", "section": "serve",
             "metrics": self._serve_metrics(0.4)},
            {"t": "t3", "section": "router",
             "metrics": self._router_metrics(0.9)},
        ])
        assert regress.main(["bench_regress", path]) == 1
        out = capsys.readouterr().out
        assert "[router] host_syncs_per_token regressed" in out
        assert "[serve]" not in out

    def test_untagged_legacy_records_read_as_serve(self, regress, tmp_path,
                                                   capsys):
        path = self._history(tmp_path, [
            {"t": "t0", "metrics": self._serve_metrics(0.4)},   # legacy
            {"t": "t1", "section": "serve",
             "metrics": self._serve_metrics(0.6)},
        ])
        assert regress.main(["bench_regress", path]) == 1
        assert "[serve] host_syncs_per_token regressed" in \
            capsys.readouterr().out

    def test_checked_in_trajectory_has_no_untagged_records(self):
        """The read-as-serve fallback above is for OTHER people's old
        files; the repo's own trajectory was migrated in place and every
        record ``benchmarks/run.py`` appends carries ``section`` — an
        untagged record here means ``_record_serve_trajectory`` regressed
        (or someone hand-edited the file)."""
        import json
        import pathlib
        path = (pathlib.Path(__file__).resolve().parent.parent
                / "BENCH_serve.json")
        if not path.exists():
            pytest.skip("no trajectory checked in")
        history = json.loads(path.read_text())
        assert isinstance(history, list) and history
        untagged = [r.get("t") for r in history if "section" not in r]
        assert untagged == [], \
            f"untagged BENCH_serve.json records at t={untagged}"

    def test_single_record_per_section_passes(self, regress, tmp_path):
        path = self._history(tmp_path, [
            {"t": "t0", "section": "serve",
             "metrics": self._serve_metrics(0.4)},
            {"t": "t1", "section": "router",
             "metrics": self._router_metrics(0.3)},
        ])
        assert regress.main(["bench_regress", path]) == 0


class TestCycleModel:
    def test_canneal_slower_than_scalar(self):
        _, w = KERNELS["canneal"]("simtiny")
        s = bench_rivec.scalar_cycles("canneal", w)
        v = bench_rivec.vector_cycles("canneal", w, unordered=False)
        assert s / v < 1.0  # the paper's headline regression

    def test_unordered_never_slower(self):
        for name, fn in KERNELS.items():
            _, w = fn("simtiny")
            v = bench_rivec.vector_cycles(name, w, unordered=False)
            vu = bench_rivec.vector_cycles(name, w, unordered=True)
            assert vu <= v * 1.0001, name

    def test_spmv_speedup_grows_with_size(self):
        sp = {}
        for size in ("simtiny", "simlarge"):
            _, w = KERNELS["spmv"](size)
            sp[size] = (bench_rivec.scalar_cycles("spmv", w)
                        / bench_rivec.vector_cycles("spmv", w, True))
        assert sp["simlarge"] > sp["simtiny"]  # longer rows vectorize better

    def test_geomean_in_paper_band(self):
        rows = bench_rivec.run_table()
        gm = bench_rivec.geomean(
            [r["simlarge"]["V_speedup"] for r in rows]
        )
        assert 2.0 < gm < 4.5  # paper: 2.7-3.2x


class TestTLBSweepClaims:
    def test_paper_claims_hold(self):
        from benchmarks.bench_tlb_sweep import sweep

        results = sweep()
        for label, by in results.items():
            for entries in (16, 32, 64, 128):
                assert by[entries]["total"] < 0.035, (label, entries)
            assert by[128]["total"] < 0.01, label
        # bigger problems need more PTEs before the TLB covers the dataset
        # (longer vectors hide the misses, so compare hit rates, not stalls)
        assert results["96p"][16]["hit_rate"] < results["6p"][16]["hit_rate"]
        assert results["24p"][8]["hit_rate"] < results["6p"][8]["hit_rate"]


class TestHloCostModel:
    HLO = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %y = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%i2, %y)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %i3 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i3, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  %r = f32[8,8] get-tuple-element(%w), index=1
  %ag = f32[16,8] all-gather(%r), replica_groups={}
  %red = f32[8,8] slice(%ag), slice={[0:8], [0:8]}
  ROOT %out = f32[8,8] add(%red, %r)
}
"""

    def test_loop_multiplied_flops(self):
        from repro.launch.hlo_cost import analyze

        r = analyze(self.HLO)
        # dot: 2*8*8*8 = 1024 flops x 5 trips
        assert r["flops"] >= 1024 * 5
        assert r["flops"] < 1024 * 5 + 2000  # adds only elementwise slack

    def test_collectives_counted(self):
        from repro.launch.hlo_cost import analyze

        r = analyze(self.HLO)
        assert r["collective_bytes"] == 16 * 8 * 2  # f32 @ bf16-wire rule
        assert r["collective_counts"]["all-gather"] == 1

    def test_shape_parsing(self):
        from repro.launch.hlo_cost import _bytes_of, _elems_of

        assert _bytes_of("bf16[4,4]") == 32
        assert _bytes_of("(f32[2,2], s32[3])") == 28
        assert _elems_of("pred[7]") == 7
