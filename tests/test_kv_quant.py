"""Quantized int8 KV-pool suite (marker: ``quant``).

Four contracts of the dequant-in-kernel quantization path:

  1. DIFFERENTIAL (``quant`` + ``kernels``) — the decode and prefill Pallas
     kernels reading int8 pools with a scalar ``kv_scale`` in the prefetch
     plane must match the jnp oracle bit-for-bit in policy (same dequant,
     same online softmax) across page size x GQA x start/length offsets,
     including a bf16-query variant pinning the oracle's upcast-to-q.dtype
     behaviour (a hard-coded float32 dequant would diverge there).
  2. SHARDED (``quant`` + ``kernels`` + ``sharded``) — the same grids
     through the shard_map wrappers over a real ('kv', 'hd') mesh: int8
     pools shard like fp pools and the replicated ``kv_scale`` survives
     into every shard body.
  3. SPILL BIT-IDENTITY (``quant``) — ``ContextSwitcher.spill_kv`` /
     ``restore_kv`` move quantized pages VERBATIM: the swap record is
     int8, ``bytes_spilled`` counts narrow bytes exactly
     (``2 * n_pages * page_bytes_int8``, a 4x cut vs a float32 pool), and
     the restored frames are bit-identical — no dequant-requant round
     trip anywhere in the preemption path.
  4. ENGINE DISPATCH (``quant``) — an engine handed a natively-built model
     plus ``ServeConfig(kv_dtype="int8")`` rebinds through the cached
     kv-dtype twin: pools come out int8, every step still dispatches the
     kernels (``ref_path_dispatches == 0``), ``quant_dispatches`` tracks
     every quantized step, and the outputs are token-identical to an
     engine whose model was built with ``kv_dtype="int8"`` directly.

Run just this suite:  PYTHONPATH=src python -m pytest -q -m quant
"""

import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import VirtualMemory, VMemConfig
from repro.core.context_switch import ContextSwitcher
from repro.kernels import ops
from repro.models import build_model
from repro.models.transformer import TransformerLM
from repro.serve import Engine, ServeConfig, ServeRequest

pytestmark = pytest.mark.quant

KEY = jax.random.PRNGKey(11)

#: the serving fixed-point scale (transformer.py quantizes with
#: round(x * 24) so the oracle/kernel pair must agree under the inverse)
KV_SCALE = 1.0 / TransformerLM.KV_INT8_SCALE


def make_int8_case(page_size, lens_or_starts, chunks=None, *, hkv=2, g=2,
                   d=16, extra_frames=3, q_dtype=jnp.float32, seed=0):
    """Random INT8 pools + a shuffled page table.

    ``chunks is None`` builds a decode case (``lens_or_starts`` are seq
    lens, q is [B, Hkv, G, D]); otherwise a prefill case (starts + chunk
    lens, q is [B, S, Hkv, G, D]).  Pool values span the full int8 range
    so the dequant multiply is load-bearing, not a no-op near zero.
    """
    lens = np.asarray(lens_or_starts, np.int32)
    b = len(lens)
    totals = lens if chunks is None else lens + np.asarray(chunks, np.int32)
    max_pages = int(max(-(-int(t) // page_size) for t in totals)) + 1
    n_frames = b * max_pages + extra_frames
    rng = np.random.default_rng(seed)
    k_pool = jnp.asarray(rng.integers(
        -127, 128, size=(n_frames, page_size, hkv, d)), jnp.int8)
    v_pool = jnp.asarray(rng.integers(
        -127, 128, size=(n_frames, page_size, hkv, d)), jnp.int8)
    frames = rng.permutation(n_frames)
    table = np.full((b, max_pages), -1, np.int32)
    fi = 0
    for row in range(b):
        need = -(-int(totals[row]) // page_size)
        table[row, :need] = frames[fi: fi + need]
        fi += need
    key = jax.random.fold_in(KEY, seed)
    if chunks is None:
        q = jax.random.normal(key, (b, hkv, g, d), jnp.float32)
    else:
        s = int(np.max(chunks))
        q = jax.random.normal(key, (b, s, hkv, g, d), jnp.float32)
    return (q.astype(q_dtype), k_pool, v_pool, jnp.asarray(table),
            jnp.asarray(lens))


@pytest.mark.kernels
class TestInt8DecodeDifferential:
    """Decode kernel vs oracle over int8 pools (rides the fail-fast
    ``kernels`` stage in scripts/check.sh)."""

    @pytest.mark.parametrize("page_size", [4, 8, 16])
    @pytest.mark.parametrize("lens", [[1, 5, 9], [16, 3, 31]])
    def test_matches_ref(self, page_size, lens):
        q, kp, vp, table, seq_lens = make_int8_case(
            page_size, lens, seed=page_size)
        out_k = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=page_size,
            kv_scale=KV_SCALE, use_kernel=True)
        out_r = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=page_size,
            kv_scale=KV_SCALE, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("hkv,g", [(1, 4), (2, 2), (4, 1)])
    def test_gqa_shapes(self, hkv, g):
        q, kp, vp, table, seq_lens = make_int8_case(
            8, [7, 12], hkv=hkv, g=g, seed=hkv * 10 + g)
        out_k = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=8,
            kv_scale=KV_SCALE, use_kernel=True)
        out_r = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=8,
            kv_scale=KV_SCALE, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)

    def test_fp_path_unchanged_by_quant_plumbing(self):
        """kv_scale=None on fp pools must still match the oracle — the
        static ``quantized`` flag keeps the fp kernel body bit-unchanged."""
        rng = np.random.default_rng(0)
        q, kp, vp, table, seq_lens = make_int8_case(4, [6, 10], seed=1)
        kp = jnp.asarray(rng.normal(size=kp.shape), jnp.float32)
        vp = jnp.asarray(rng.normal(size=vp.shape), jnp.float32)
        out_k = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=4, use_kernel=True)
        out_r = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=4, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


@pytest.mark.kernels
class TestInt8PrefillDifferential:
    """Chunked-prefill kernel vs oracle over int8 pools, including offsets
    mid-page and chunks spanning page boundaries."""

    @pytest.mark.parametrize("page_size", [4, 8])
    @pytest.mark.parametrize("start,chunk", [(0, 8), (2, 5), (5, 17), (16, 1)])
    def test_matches_ref(self, page_size, start, chunk):
        starts = [start, max(0, start - 1)]
        chunks = [chunk, chunk + 1]
        q, kp, vp, table, st = make_int8_case(
            page_size, starts, chunks, seed=start * 31 + chunk)
        out_k = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=page_size,
            kv_scale=KV_SCALE, use_kernel=True, bq=4)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=page_size,
            kv_scale=KV_SCALE, use_kernel=False)
        for row, c in enumerate(chunks):
            np.testing.assert_allclose(
                np.asarray(out_k)[row, :c], np.asarray(out_r)[row, :c],
                rtol=2e-5, atol=2e-5, err_msg=f"row {row}")

    def test_bf16_query_pins_ref_upcast(self):
        """bf16 queries: the oracle dequantizes THROUGH float32 but lands
        on q.dtype (bf16) before the dots — exactly what the kernel does
        in VMEM.  A ref that hard-cast dequantized KV to float32 would
        run its dots in a wider dtype than the kernel and drift well past
        bf16 resolution here."""
        q, kp, vp, table, st = make_int8_case(
            4, [2, 0], [6, 9], q_dtype=jnp.bfloat16, seed=5)
        out_k = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=4,
            kv_scale=KV_SCALE, use_kernel=True, bq=4)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=4,
            kv_scale=KV_SCALE, use_kernel=False)
        assert out_k.dtype == out_r.dtype == jnp.bfloat16
        for row, c in enumerate([6, 9]):
            np.testing.assert_allclose(
                np.asarray(out_k, jnp.float32)[row, :c],
                np.asarray(out_r, jnp.float32)[row, :c],
                rtol=2e-2, atol=2e-2, err_msg=f"row {row}")


@pytest.mark.kernels
@pytest.mark.sharded
@pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 XLA device; set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)
class TestInt8Sharded:
    """int8 grids through the ('kv', 'hd') shard_map wrappers: the
    replicated scalar kv_scale must reach every shard body and the
    sharded output must equal the single-device kernel AND the oracle."""

    HKV, G, D = 2, 2, 16  # 8 forced host devices factor as a full 2x4 mesh

    @pytest.fixture(scope="class")
    def mesh(self):
        from repro.launch.mesh import make_host_serve_mesh
        m = make_host_serve_mesh(self.HKV, self.D)
        assert m.size > 1
        return m

    def test_decode_three_way_identity(self, mesh):
        q, kp, vp, table, seq_lens = make_int8_case(
            8, [5, 13, 20], hkv=self.HKV, g=self.G, d=self.D, seed=2)
        out_s = ops.paged_decode_attention_sharded(
            q, kp, vp, table, seq_lens, page_size=8, mesh=mesh,
            kv_scale=KV_SCALE, use_kernel=True)
        out_k = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=8,
            kv_scale=KV_SCALE, use_kernel=True)
        out_r = ops.paged_decode_attention(
            q, kp, vp, table, seq_lens, page_size=8,
            kv_scale=KV_SCALE, use_kernel=False)
        np.testing.assert_allclose(
            np.asarray(out_s), np.asarray(out_k), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(out_s), np.asarray(out_r), rtol=2e-5, atol=2e-5)

    def test_prefill_three_way_identity(self, mesh):
        q, kp, vp, table, st = make_int8_case(
            4, [2, 6], [9, 5], hkv=self.HKV, g=self.G, d=self.D, seed=3)
        out_s = ops.paged_prefill_attention_sharded(
            q, kp, vp, table, st, page_size=4, mesh=mesh,
            kv_scale=KV_SCALE, use_kernel=True, bq=4)
        out_k = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=4,
            kv_scale=KV_SCALE, use_kernel=True, bq=4)
        out_r = ops.paged_prefill_attention(
            q, kp, vp, table, st, page_size=4,
            kv_scale=KV_SCALE, use_kernel=False)
        for row, c in enumerate([9, 5]):
            np.testing.assert_allclose(
                np.asarray(out_s)[row, :c], np.asarray(out_k)[row, :c],
                rtol=2e-5, atol=2e-5, err_msg=f"row {row} vs kernel")
            np.testing.assert_allclose(
                np.asarray(out_s)[row, :c], np.asarray(out_r)[row, :c],
                rtol=2e-5, atol=2e-5, err_msg=f"row {row} vs ref")


class TestSpillBitIdentity:
    """spill_kv/restore_kv over int8 pools: narrow bytes verbatim."""

    def test_round_trip_bit_identical_and_bytes_exact(self):
        L, hkv, d = 2, 2, 4
        cfg = VMemConfig(page_size=4, num_pages=8, max_pages_per_seq=4,
                         max_seqs=3)
        vm = VirtualMemory(cfg)
        vm.map_seq(0, 10)                       # -> 3 pages
        n_pages = len(vm.seq(0).pages)
        rng = np.random.default_rng(7)
        shape = (L, cfg.num_pages, cfg.page_size, hkv, d)
        k_pools = jnp.asarray(rng.integers(-127, 128, size=shape), jnp.int8)
        v_pools = jnp.asarray(rng.integers(-127, 128, size=shape), jnp.int8)
        old_pages = np.asarray(vm.seq(0).pages, np.int32)
        k_before = np.asarray(jnp.take(k_pools, jnp.asarray(old_pages),
                                       axis=1))
        v_before = np.asarray(jnp.take(v_pools, jnp.asarray(old_pages),
                                       axis=1))

        cs = ContextSwitcher(vm, page_axis=1)
        cs.spill_kv(0, k_pools, v_pools, extra_state="sampler")

        # the swap record holds the quantized bytes, never a widened copy
        assert cs._swap[0].page_data.dtype == np.int8
        page_bytes_int8 = L * cfg.page_size * hkv * d  # itemsize 1
        assert cs.stats.bytes_spilled == 2 * n_pages * page_bytes_int8
        # vs a float32 pool of the same geometry: exactly 4x fewer bytes
        assert 4 * cs.stats.bytes_spilled == 2 * n_pages * (
            L * cfg.page_size * hkv * d * 4)

        # dirty the freed frames and force a re-framing before restore
        k_pools = jnp.zeros_like(k_pools)
        v_pools = jnp.zeros_like(v_pools)
        vm.map_seq(5, 8)
        k_pools, v_pools, extra = cs.restore_kv(0, k_pools, v_pools)
        assert extra == "sampler"
        new_pages = np.asarray(vm.seq(0).pages, np.int32)
        assert list(new_pages) != list(old_pages)  # landed on new frames
        np.testing.assert_array_equal(
            np.asarray(jnp.take(k_pools, jnp.asarray(new_pages), axis=1)),
            k_before)
        np.testing.assert_array_equal(
            np.asarray(jnp.take(v_pools, jnp.asarray(new_pages), axis=1)),
            v_before)
        assert cs.stats.bytes_restored == cs.stats.bytes_spilled
        vm.check_invariants()


class TestEngineDispatch:
    """ServeConfig(kv_dtype="int8") + a native model: the executor's
    kv-dtype twin must quantize the pools and KEEP the kernels live."""

    @pytest.fixture(scope="class")
    def cfg_model_params(self):
        cfg = get_config("qwen2-7b", reduced=True)
        model = build_model(cfg, remat=False, use_kernels=True)
        return cfg, model, model.init(KEY)

    def _workload(self, cfg, n=4, seed=13, max_new=8):
        rng = np.random.default_rng(seed)
        return [
            ServeRequest(req_id=i,
                         prompt=rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(4, 12)))
                         .astype(np.int32),
                         max_new_tokens=max_new)
            for i in range(n)
        ]

    def _run(self, model, params, serve_cfg, reqs):
        eng = Engine(model, params, serve_cfg)
        for r in reqs:
            eng.submit(copy.deepcopy(r))
        done = eng.run()
        return eng, done

    def test_int8_pools_kernels_live_counters(self, cfg_model_params):
        cfg, model, params = cfg_model_params
        serve_cfg = ServeConfig(page_size=4, num_pages=32,
                                max_pages_per_seq=8, max_batch=4,
                                kv_dtype="int8")
        eng, done = self._run(model, params, serve_cfg,
                              self._workload(cfg))
        assert eng.kv.k_pools.dtype == jnp.int8
        assert eng.kv.v_pools.dtype == jnp.int8
        assert eng.counters.get("ref_path_dispatches") == 0
        assert eng.counters.get("kernel_dispatches") > 0
        # every step was quantized AND kernel-dispatched — the counter
        # that makes a silent fallback (either direction) observable
        assert eng.counters.get("quant_dispatches") == \
            eng.counters.get("kernel_dispatches")
        assert all(len(r.output) > 0 for r in done.values())

    def test_twin_matches_explicitly_quantized_model(self, cfg_model_params):
        """The cached kv-dtype twin is a rebind, not a different model:
        outputs must be token-identical to building with kv_dtype="int8"."""
        cfg, model, params = cfg_model_params
        reqs = self._workload(cfg, seed=29)
        serve_cfg = ServeConfig(page_size=4, num_pages=32,
                                max_pages_per_seq=8, max_batch=4,
                                kv_dtype="int8")
        model_q = build_model(cfg, remat=False, use_kernels=True,
                              kv_dtype="int8")
        _, done_twin = self._run(model, params, serve_cfg, reqs)
        _, done_direct = self._run(model_q, params, serve_cfg, reqs)
        assert len(done_twin) == len(done_direct) == len(reqs)
        for i in range(len(reqs)):
            assert [int(x) for x in done_twin[i].output] == \
                [int(x) for x in done_direct[i].output], i

    def test_native_default_stays_native(self, cfg_model_params):
        """Default ServeConfig must not quantize anything: fp pools, zero
        quant_dispatches — the twin only binds on an explicit opt-in."""
        cfg, model, params = cfg_model_params
        serve_cfg = ServeConfig(page_size=4, num_pages=32,
                                max_pages_per_seq=8, max_batch=4)
        eng, _ = self._run(model, params, serve_cfg,
                           self._workload(cfg, n=2, max_new=4))
        assert eng.kv.k_pools.dtype != jnp.int8
        assert eng.counters.get("quant_dispatches") == 0
        assert eng.counters.get("ref_path_dispatches") == 0
