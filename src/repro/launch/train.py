"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on the locally available devices
(tests/laptops use reduced configs; a real cluster launches one process per
host with the same entry point — the mesh derives from jax.device_count()).
"""

import argparse
import os

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.data import DataConfig, SyntheticLMStream, make_global_batch
from repro.launch.mesh import dp_axes, make_host_mesh, use_mesh
from repro.launch.sharding import make_shard_hook
from repro.models import build_model
from repro.models.config import ShapeConfig
from repro.optim import AdamWConfig
from repro.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1, help="data mesh axis")
    ap.add_argument("--model", type=int, default=1, help="model mesh axis")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh(args.data, args.model)
    shape = ShapeConfig("cli", args.seq_len, args.global_batch, "train")
    stream = SyntheticLMStream(cfg, shape, DataConfig())
    opt_cfg = AdamWConfig(
        base_lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps,
    )

    with use_mesh(mesh):
        model = build_model(cfg, remat=True, shard=make_shard_hook(mesh))
        trainer = Trainer(
            model, opt_cfg,
            ckpt_dir=os.path.join(args.ckpt_dir, cfg.name),
            ckpt_every=args.ckpt_every, accum_steps=args.accum_steps,
            heartbeat=lambda step, dt: (
                print(f"  step {step}: {dt*1e3:.0f} ms") if step % 20 == 0
                else None
            ),
        )
        params, opt_state, start = trainer.init_or_restore(
            jax.random.PRNGKey(0)
        )
        if start:
            print(f"resumed from step {start}")

        from jax.sharding import PartitionSpec as P
        dp = dp_axes(mesh)

        def batches(step):
            return make_global_batch(stream.batch(step), mesh, P(dp))

        params, opt_state, hist = trainer.run(
            params, opt_state, batches, start, args.steps
        )
    for h in hist:
        print({k: round(v, 4) for k, v in h.items()})
    print(f"final loss: {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
