"""Pallas TPU kernels (validated on CPU with interpret=True).

Layout per DESIGN.md: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jit'd public wrappers, ref.py the pure-jnp oracles.
"""

from repro.kernels import ops, ref  # noqa: F401
