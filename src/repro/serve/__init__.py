"""Serving: continuous batching over paged virtual memory (the "OS").

Split per the AraOS architecture, one layer per plane:

  **Router -> Scheduler(ReplicaState) -> DataPlane.**
  :class:`ReplicaRouter` (:mod:`repro.serve.router`) is the multi-replica
  control plane: it owns the global admission queue and places requests
  over N replicas (fork-affinity keeps COW forks on the prefix-holding
  replica; least-loaded-pages / round-robin rank the rest).  Each replica
  is a :class:`Scheduler` — the host-side CVA6/OS plane (policy, no
  device arrays), with every piece of per-replica mutable state factored
  into :class:`ReplicaState` — driving a :class:`DataPlane`: in
  production the device-resident :class:`Executor` (optionally sharded
  over a ('kv','hd') mesh), in tests a host-only fake.  Replicas share no
  mutable state, and the single-replica :class:`Engine` (the thin
  Scheduler+Executor facade) is exactly the N=1 instance of the layering:
  a one-replica router with the default unbounded backlog is
  call-for-call, token-for-token the plain engine — the equivalence the
  router test suite gates on for N in {1, 2, 4}.

  **Radix prefix layer.**  Each Scheduler carries a
  :class:`PrefixCache` (:mod:`repro.serve.prefix_cache`) — a
  page-granularity radix trie over the token content of resident mapped
  runs.  Admissions whose prompts share leading whole pages with a
  registered run COW-map those pages automatically (no fork API) and
  prefill only the divergent chunk; the router generalizes fork affinity
  into an additive longest-matching-prefix score when ranking replicas.

  **The portable-swap contract.**  A preempted request's swap record is
  pure host memory in the pool's storage dtype (int8 pools stay narrow)
  plus a pinned-prefix provenance carried as a page COUNT — nothing in it
  references the pool that spilled it.  That makes residency a POLICY
  decision rather than a property of whichever data plane held the
  pages: the router migrates a starved or about-to-fail swap victim to
  any replica whose pinned-prefix-adjusted demand fits
  (``Scheduler.export_swapped`` / ``import_swapped`` over
  ``DataPlane.export_swap`` / ``import_swap``, counted as
  ``restore_migrations``), re-resolving the prefix re-share claim against
  the destination's own mapping.  When even the migrated victim's
  unshared tail cannot fit anywhere all at once, the scheduler restores
  the longest page-aligned prefix that does fit and re-enqueues the
  request to re-prefill only the evicted tail through the continuation
  path (``partial_restores`` / ``pages_refilled``) — so the "failed as
  unreachable" verdict survives only when NO replica could ever host the
  request.

  **The public client API** (:mod:`repro.serve.api`) is the SUPPORTED
  entrypoint: build a validated :class:`ServeConfig` (one flag surface —
  ``ServeConfig.add_args``/``from_args``/``describe``), construct an
  :class:`Engine` (or a :class:`ReplicaRouter` over N of them), then
  ``submit()`` typed :class:`ServeRequest` records and ``drain()`` typed
  :class:`ServeResult` records — tokens, terminal status, per-request
  TTFT/TPOT timestamps captured at the scheduler's host-visible commit
  points, peak page footprint.  Per-token streaming rides an optional
  ``stream_callback``, invoked in global commit order by the
  :class:`AsyncDetokenizer` background thread (:mod:`repro.serve.
  detokenize`) so host post-processing overlaps device work; callback
  exceptions surface on ``drain()``.  The internal scheduler-plane
  :class:`Request` remains public for fake-plane harnesses — they build
  it and drive ``Scheduler.submit`` directly — but submitting it to an
  Engine/Router is a hard ``TypeError`` (the one-PR deprecation shim is
  gone).  With ``ServeConfig.aot_buckets`` the Executor
  pre-compiles bucketed prefill/continuation executables at build time so
  no request pays a first-hit jit stall (``aot_hits``/``aot_misses``/
  ``bucket_pad_tokens``; the open-loop SLO gate in
  ``benchmarks/bench_serve_slo.py`` holds ``aot_misses == 0``).

:class:`ReferenceEngine` is the frozen pre-split seed implementation kept
for equivalence testing and before/after benchmarks.
"""
from repro.serve.api import (
    RequestTiming,
    SamplingParams,
    ServeRequest,
    ServeResult,
    StreamEvent,
    to_internal,
)
from repro.serve.detokenize import AsyncDetokenizer
from repro.serve.engine import Engine
from repro.serve.executor import Executor
from repro.serve.prefix_cache import PrefixCache
from repro.serve.reference import ReferenceEngine
from repro.serve.router import Replica, ReplicaRouter
from repro.serve.scheduler import (
    DataPlane,
    DecodePlan,
    HostOnlyPlane,
    ReplicaState,
    Request,
    RestoreFailure,
    Scheduler,
    ServeConfig,
    SwapExport,
)

__all__ = [
    "AsyncDetokenizer",
    "DataPlane",
    "DecodePlan",
    "Engine",
    "Executor",
    "HostOnlyPlane",
    "PrefixCache",
    "ReferenceEngine",
    "Replica",
    "ReplicaRouter",
    "ReplicaState",
    "Request",
    "RequestTiming",
    "RestoreFailure",
    "SamplingParams",
    "Scheduler",
    "ServeConfig",
    "ServeRequest",
    "ServeResult",
    "StreamEvent",
    "SwapExport",
    "to_internal",
]
