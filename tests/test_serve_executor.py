"""Executor/engine-split acceptance tests.

Three contracts of the Scheduler/Executor refactor:
  1. EQUIVALENCE — the refactored engine produces token-for-token identical
     greedy outputs to the frozen seed engine (``serve/reference.py``) on a
     mixed prefill/decode/preempt workload, and on a forked shared-prefix
     workload.
  2. DELTA-ONLY page-table uploads — the decode hot path never re-uploads
     the whole satp array; device updates scale with dirty rows (page
     boundary crossings), not steps x slots.
  3. PAGE-GRANULAR context switches — spill/restore move only the victim
     sequence's pages, asserted via the bytes-moved counter in
     ``ContextSwitcher.stats``.
"""

import copy

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, ReferenceEngine, ServeConfig, ServeRequest
from repro.serve.api import to_internal

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    return cfg, model, model.init(KEY)


def mixed_workload(cfg, n=7, seed=13, max_new=12):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            req_id=i,
            prompt=rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(4, 14))
            ).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def run_engine(eng_cls, model, params, serve_cfg, reqs, prefix=None):
    eng = eng_cls(model, params, serve_cfg)
    if prefix is not None:
        eng.preload_prefix(prefix)
    for r in reqs:
        r = copy.deepcopy(r)
        # the frozen seed engine predates the typed surface: lower explicitly
        eng.submit(to_internal(r) if eng_cls is ReferenceEngine else r)
    done = eng.run()
    return eng, done


class TestSeedEquivalence:
    def test_mixed_preempt_workload_token_identical(self, model_and_params):
        """Tight pool -> admission queuing, page faults, preemptions and
        restores all fire; outputs must match the seed engine exactly."""
        cfg, model, params = model_and_params
        reqs = mixed_workload(cfg)
        serve_cfg = ServeConfig(page_size=4, num_pages=16,
                                max_pages_per_seq=16, max_batch=3)
        new_eng, done_n = run_engine(Engine, model, params, serve_cfg, reqs)
        ref_eng, done_r = run_engine(
            ReferenceEngine, model, params, serve_cfg, reqs)
        # the workload must actually exercise the preempt path
        assert new_eng.counters.get("preemptions") > 0
        # identical policy decisions...
        for c in ("preemptions", "restores", "page_faults", "completed"):
            assert new_eng.counters.get(c) == ref_eng.counters.get(c), c
        # ...and token-for-token identical outputs
        assert len(done_n) == len(done_r) == len(reqs)
        for i in range(len(reqs)):
            a = [int(x) for x in done_n[i].output]
            b = [int(x) for x in done_r[i].output]
            assert a == b, f"req {i} diverged from the seed engine"
        new_eng.vmem.check_invariants()

    def test_forked_prefix_workload_token_identical(self, model_and_params):
        """Continuation prefill (one chunked device step) must reproduce
        the seed's one-token-at-a-time teacher forcing exactly."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(17)
        prefix = rng.integers(0, cfg.vocab_size, size=22).astype(np.int32)
        reqs = [
            ServeRequest(req_id=i,
                         prompt=rng.integers(0, cfg.vocab_size, size=int(l))
                         .astype(np.int32),
                         max_new_tokens=8, share_prefix=True)
            for i, l in enumerate([3, 6, 9])
        ]
        serve_cfg = ServeConfig(page_size=4, num_pages=64,
                                max_pages_per_seq=32, max_batch=4)
        new_eng, done_n = run_engine(Engine, model, params, serve_cfg, reqs,
                                     prefix=prefix)
        ref_eng, done_r = run_engine(ReferenceEngine, model, params,
                                     serve_cfg, reqs, prefix=prefix)
        assert new_eng.counters.get("forked_admissions") == 3
        # the chunk ran as continuation prefill, not per-token decode
        assert new_eng.counters.get("continuation_prefill_tokens") == 3 + 6 + 9
        for i in range(len(reqs)):
            assert [int(x) for x in done_n[i].output] == \
                [int(x) for x in done_r[i].output], i


class TestBatchedForkAdmission:
    def test_same_step_forks_run_as_one_batched_call(self, model_and_params):
        """Same-step forked admissions must run as ONE batched continuation
        prefill (B=3, per-row start offsets, padded chunks) and still be
        token-identical to the seed's per-token teacher forcing — the
        page-8 unaligned-prefix mirror of the page-4 equivalence test,
        with a 1-token chunk riding in the batch."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(23)
        prefix = rng.integers(0, cfg.vocab_size, size=13).astype(np.int32)
        reqs = [
            ServeRequest(req_id=i,
                         prompt=rng.integers(0, cfg.vocab_size, size=int(l))
                         .astype(np.int32),
                         max_new_tokens=6, share_prefix=True)
            for i, l in enumerate([1, 7, 12])
        ]
        serve_cfg = ServeConfig(page_size=8, num_pages=64,
                                max_pages_per_seq=16, max_batch=4)
        new_eng, done_n = run_engine(Engine, model, params, serve_cfg, reqs,
                                     prefix=prefix)
        ref_eng, done_r = run_engine(ReferenceEngine, model, params,
                                     serve_cfg, reqs, prefix=prefix)
        # all three forks admitted in the same step -> exactly one batched
        # continuation prefill covering 1+7+12 chunk tokens
        assert new_eng.counters.get("forked_admissions") == 3
        assert new_eng.counters.get("fork_batches") == 1
        assert new_eng.counters.get("continuation_prefill_tokens") == 1 + 7 + 12
        for i in range(len(reqs)):
            assert [int(x) for x in done_n[i].output] == \
                [int(x) for x in done_r[i].output], i


class TestRestoreLivelock:
    def test_spilled_fork_restores_shared_instead_of_failing(
            self, model_and_params):
        """ROADMAP regression (observed via ``repro.launch.serve
        --prefix-len 10 --num-pages 10``), updated for shared-page
        restore: a fork spilled near the end of its decode carries
        pages_for(len) = 8 frames, one of which is the still-resident
        pinned prefix page.  The original engine spun until
        ``run(max_steps)`` expired; the first fix failed the victim as
        unreachable (its UNSHARED demand of 8 exceeds the 7 attainable
        frames); the shared restore re-shares the pinned frame by
        refcount, scatters only the 7 unshared pages back, and the
        request finishes."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(3)
        serve_cfg = ServeConfig(page_size=8, num_pages=10,
                                max_pages_per_seq=12, max_batch=4)
        eng = Engine(model, params, serve_cfg)
        eng.preload_prefix(
            rng.integers(0, cfg.vocab_size, size=10).astype(np.int32))
        # mapped lifetime 10+30+23 = 63 tokens = 8 pages; 7 own while
        # sharing (admissible), 8 unshared (beyond the 7 attainable frames)
        eng.submit(ServeRequest(
            req_id=0,
            prompt=rng.integers(0, cfg.vocab_size, size=30).astype(np.int32),
            max_new_tokens=24, share_prefix=True))
        for _ in range(100):
            eng.step()
            a = eng.scheduler.running.get(0)
            if a is not None and a.remaining == 1:
                break
        assert 0 in eng.scheduler.running   # nearly done, still resident
        # late pressure forces the spill at ~63 tokens
        eng.submit(ServeRequest(
            req_id=1,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=4))
        budget = eng.scheduler.step_i + 50
        done = eng.run(max_steps=budget)
        assert eng.scheduler.step_i < budget        # terminated, no livelock
        assert not eng.scheduler.has_work
        assert done[0].status == "done"
        assert done[1].status == "done"
        assert eng.counters.get("preemptions") == 1
        assert eng.counters.get("failed_unreachable") == 0
        assert eng.counters.get("restores") == 1
        assert eng.counters.get("shared_restores") == 1
        # the restored request's host-side swap record is consumed
        assert eng.switcher.swapped_out == []
        eng.vmem.check_invariants()


class TestHotPathContracts:
    def test_page_table_uploads_are_delta_only(self, model_and_params):
        # max_horizon=1: this asserts the PR-1 delta-sync contract against
        # the seed's per-STEP full upload, so the step count must mean one
        # token per lane (the fused horizon's per-token sync amortization
        # has its own coverage in test_decode_horizon.py)
        cfg, model, params = model_and_params
        serve_cfg = ServeConfig(page_size=4, num_pages=256,
                                max_pages_per_seq=16, max_batch=4,
                                max_horizon=1)
        reqs = mixed_workload(cfg, n=4, seed=5, max_new=16)
        eng, done = run_engine(Engine, model, params, serve_cfg, reqs)
        assert len(done) == 4
        steps = eng.scheduler.step_i
        uploaded = eng.counters.get("ptab_rows_uploaded")
        # the seed engine re-uploaded all max_batch rows every decode step;
        # delta sync only uploads rows whose PTEs changed (page-boundary
        # crossings every page_size steps + map/unmap events)
        full_upload_rows = steps * serve_cfg.max_batch
        assert 0 < uploaded < full_upload_rows / 2
        # decode steps with no dirty rows perform no upload at all
        assert eng.counters.get("ptab_syncs") < steps

    def test_incremental_ptab_equals_from_scratch_rebuild(
            self, model_and_params):
        """After a fork + spill/restore workload, the executor's
        delta-updated persistent device table must equal a from-scratch
        rebuild from the host table (``vmem.device_page_table()``)."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(41)
        serve_cfg = ServeConfig(page_size=4, num_pages=13,
                                max_pages_per_seq=16, max_batch=3)
        eng = Engine(model, params, serve_cfg)
        eng.preload_prefix(
            rng.integers(0, cfg.vocab_size, size=6).astype(np.int32))
        for i, (l, fork) in enumerate(
                [(5, True), (9, False), (7, True), (11, False), (6, True)]):
            eng.submit(ServeRequest(
                req_id=i,
                prompt=rng.integers(0, cfg.vocab_size, size=l)
                .astype(np.int32),
                max_new_tokens=10, share_prefix=fork))
        done = eng.run()
        # the workload must actually exercise fork AND spill/restore deltas
        assert eng.counters.get("forked_admissions") > 0
        assert eng.counters.get("preemptions") > 0
        assert len(done) == 5
        assert all(r.status == "done" for r in done.values())
        eng.executor.sync_page_table()
        np.testing.assert_array_equal(
            np.asarray(eng.executor.device_page_table),
            np.asarray(eng.vmem.device_page_table()),
        )
        eng.vmem.check_invariants()

    def test_spill_moves_only_victim_pages(self, model_and_params):
        cfg, model, params = model_and_params
        serve_cfg = ServeConfig(page_size=4, num_pages=16,
                                max_pages_per_seq=16, max_batch=3)
        reqs = mixed_workload(cfg)     # same mix as the equivalence test:
        eng, done = run_engine(Engine, model, params, serve_cfg, reqs)
        assert len(done) == len(reqs)  # it preempts under this tight pool
        st = eng.switcher.stats
        assert st.switches > 0
        kp = eng.kv.k_pools                    # [L, P, page, Hkv, hd]
        n_layers, n_frames, page, hkv, hd = kp.shape
        per_page_bytes = n_layers * page * hkv * hd * kp.dtype.itemsize
        # bytes moved == victim pages x per-page bytes, exactly
        assert st.bytes_spilled == st.pages_spilled * per_page_bytes
        assert st.bytes_restored == st.pages_restored * per_page_bytes
        assert st.bytes_spilled == st.bytes_restored
        # and strictly less than ONE full-pool copy per switch (the seed
        # data plane stacked both full pools on every spill AND restore)
        full_pool_bytes = 2 * n_frames * per_page_bytes
        assert st.bytes_spilled < st.switches * full_pool_bytes
        # a victim holds at most max_pages_per_seq pages in each pool
        assert st.pages_spilled <= st.switches * 2 * serve_cfg.max_pages_per_seq


class TestMeshModeSingleDevice:
    """Mesh-mode executor on however many devices this process has.

    With one visible device ``make_host_serve_mesh`` degrades to a 1x1
    mesh, so this runs in the tier-1 fast suite everywhere and keeps the
    sharded code path (explicit in/out shardings, donated sharded pools,
    the layout-invariant check) covered; the real multi-device identity
    suite is ``tests/test_serve_sharded.py`` (marker ``sharded``).
    """

    def test_mesh_engine_token_identical_and_layout_stable(
            self, model_and_params):
        from repro.launch.mesh import make_host_serve_mesh

        cfg, model, params = model_and_params
        mesh = make_host_serve_mesh(cfg.num_kv_heads, cfg.head_dim)
        reqs = mixed_workload(cfg)
        serve_cfg = ServeConfig(page_size=4, num_pages=16,
                                max_pages_per_seq=16, max_batch=3)
        plain, done_p = run_engine(Engine, model, params, serve_cfg, reqs)
        eng = Engine(model, params, serve_cfg, mesh=mesh)
        for r in reqs:
            eng.submit(copy.deepcopy(r))
        done_m = eng.run()
        assert eng.counters.get("preemptions") > 0
        assert {i: [int(x) for x in done_m[i].output] for i in done_m} == {
            i: [int(x) for x in done_p[i].output] for i in done_p}
        # layouts survived every update path of the preempting workload
        eng.executor.check_sharding_invariants()
        assert eng.executor.kv.k_pools.sharding.is_equivalent_to(
            eng.executor._pool_sh, eng.executor.kv.k_pools.ndim)
