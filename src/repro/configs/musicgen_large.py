"""MusicGen-Large — decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284; hf].  Stub audio frontend per assignment (precomputed
frame embeddings / codebook token streams)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio", num_layers=48, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=2048,
    head_dim=64, num_codebooks=4, frontend="audio", rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="musicgen-large-reduced", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64, head_dim=16,
    num_codebooks=4, param_dtype="float32",
)
