"""Launch layer: production meshes, sharding rules, dry-run, drivers."""
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
