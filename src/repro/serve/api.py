"""The stable typed client surface of :mod:`repro.serve`.

Everything a *client* of the serving stack touches lives here, decoupled
from the internal policy/data-plane types:

  :class:`ServeRequest`
      What a client submits — prompt, token budget, optional per-request
      :class:`SamplingParams`, optional ``stream_callback`` (invoked with
      :class:`StreamEvent` records from the background detokenize thread,
      in commit order), optional explicit ``req_id`` (auto-allocated when
      omitted).
  :class:`ServeResult`
      What a client gets back from ``Engine.drain()`` /
      ``ReplicaRouter.drain()`` — the sampled tokens, terminal status, a
      :class:`RequestTiming` (enqueue / first-token / last-token
      timestamps captured at ``commit_decode``, the host-visible commit
      point — never at detokenize, so async streaming cannot skew the SLO
      numbers) and the request's peak page footprint.

The internal :class:`~repro.serve.scheduler.Request` dataclass remains
the *scheduler-plane* type (fake data planes, scheduler unit tests build
it directly and drive ``Scheduler.submit``); passing one to
``Engine.submit`` / ``ReplicaRouter.submit`` is a hard :class:`TypeError`
— every client-facing path — benchmarks, the launch driver, the SLO
harness — speaks :class:`ServeRequest`/:class:`ServeResult` (lowered via
:func:`to_internal`).

Sampling is engine-global (one PRNG stream, one temperature per fused
dispatch), so per-request :class:`SamplingParams` are *validated* against
the engine's :class:`~repro.serve.scheduler.ServeConfig` rather than
applied per-lane: a mismatch raises at submit instead of silently
sampling with the wrong knobs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.serve.scheduler import Request, ServeConfig

__all__ = [
    "SamplingParams",
    "ServeRequest",
    "ServeResult",
    "RequestTiming",
    "StreamEvent",
    "to_internal",
]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs, validated against the engine config.

    The executor samples batches with one PRNG stream and one temperature
    per dispatch (on-device inside fused horizons), so these cannot vary
    *within* an engine — requests may state what they need and the engine
    enforces agreement at submit time.
    """

    greedy: bool = True
    temperature: float = 1.0

    def validate_for(self, cfg: ServeConfig) -> None:
        if self.greedy != cfg.greedy or (
            not self.greedy and self.temperature != cfg.temperature
        ):
            raise ValueError(
                f"sampling {self} conflicts with the engine's "
                f"ServeConfig(greedy={cfg.greedy}, "
                f"temperature={cfg.temperature}): sampling is engine-"
                "global (one PRNG stream / temperature per fused "
                "dispatch) — build an engine with matching config"
            )


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One streamed token, delivered by the async detokenize thread."""

    req_id: int
    index: int                    # position in the request's output
    token: Any                    # the committed token (None on failure)
    text: str                     # detokenized text for this token
    final: bool                   # True on the request's last event
    t_commit: float               # perf_counter stamp of the host commit


@dataclasses.dataclass
class ServeRequest:
    """A client submission (``Engine.submit`` / ``ReplicaRouter.submit``).

    ``req_id`` is optional — the engine/router allocates the next free id
    when omitted.  ``stream_callback`` is invoked once per committed
    token with a :class:`StreamEvent`, from the background detokenize
    thread, in global commit order; exceptions it raises surface on
    ``drain()``.
    """

    prompt: np.ndarray
    max_new_tokens: int
    req_id: int | None = None
    sampling: SamplingParams | None = None
    stream_callback: Callable[[StreamEvent], None] | None = None
    share_prefix: bool = False

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.size == 0:
            raise ValueError("ServeRequest.prompt must be non-empty")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}"
            )


@dataclasses.dataclass(frozen=True)
class RequestTiming:
    """Per-request latency stamps (``time.perf_counter`` seconds).

    All three are captured by the *scheduler* at host-visible commit
    points — ``submit`` / ``finish_prefill`` / ``commit_decode`` — never
    by the detokenize thread, so asynchronous streaming can lag
    arbitrarily without skewing TTFT/TPOT.
    """

    enqueue: float
    first_token: float
    last_token: float

    @property
    def ttft(self) -> float:
        """Time to first token: queue wait + prefill."""
        return self.first_token - self.enqueue


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Terminal record for one request (``Engine.drain`` /
    ``ReplicaRouter.drain``)."""

    req_id: int
    tokens: tuple
    status: str                    # "done" | "failed"
    timing: RequestTiming
    pages_peak: int                # peak mapped-page footprint

    @property
    def ttft(self) -> float:
        return self.timing.ttft

    @property
    def tpot(self) -> float:
        """Mean time per output token over the decode tail."""
        n = len(self.tokens)
        return (self.timing.last_token - self.timing.first_token) \
            / max(n - 1, 1)

    @classmethod
    def from_request(cls, req: Request) -> "ServeResult":
        toks = tuple(
            int(t) if np.ndim(t) == 0 else np.asarray(t)
            for t in req.output
        )
        return cls(
            req_id=req.req_id, tokens=toks, status=req.status,
            timing=RequestTiming(enqueue=req.t_enqueue,
                                 first_token=req.t_first_token,
                                 last_token=req.t_last_token),
            pages_peak=req.pages_peak,
        )


def to_internal(sreq: ServeRequest, req_id: int | None = None,
                cfg: ServeConfig | None = None) -> Request:
    """Lower a client :class:`ServeRequest` onto the scheduler-plane
    :class:`Request` (sampling validated against ``cfg`` when given;
    ``req_id`` supplies the auto-allocated id when the client omitted
    one)."""
    if sreq.sampling is not None and cfg is not None:
        sreq.sampling.validate_for(cfg)
    rid = sreq.req_id if sreq.req_id is not None else req_id
    if rid is None:
        raise ValueError("req_id required: pass one explicitly or submit "
                         "through an Engine/ReplicaRouter (auto-allocates)")
    return Request(
        req_id=int(rid),
        prompt=sreq.prompt,
        max_new_tokens=sreq.max_new_tokens,
        share_prefix=sreq.share_prefix,
        stream_callback=sreq.stream_callback,
    )
