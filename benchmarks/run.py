"""Benchmark harness: one section per paper table/figure + the roofline.

Invoke as ``python -m benchmarks.run`` from the repo root (the package
import form; plain ``python benchmarks/run.py`` also works via the
``__main__`` sys.path guard at the bottom of this file).

Prints a ``name,us_per_call,derived`` CSV block at the end (harness
contract).  Sections (select a subset with ``--only``):
  fig2     — matmul VM overhead vs DTLB size x problem size (bench_tlb_sweep)
  table1   — RiVEC suite scalar vs vector speedups           (bench_rivec)
  s31      — scheduler ticks + context switches              (bench_context_switch)
  serve    — seed vs Scheduler/Executor serving split        (bench_serve_throughput)
  sharded  — executor over the ('kv','hd') serve mesh        (bench_serve_sharded)
  router   — ReplicaRouter over N engines vs N=1             (bench_serve_router)
  prefix   — radix prefix cache: multi-turn chat, warm/cold  (bench_prefix_cache)
  quant    — int8 KV pools: accuracy envelope + bytes halved (bench_kv_quant)
  slo      — open-loop Poisson vs AOT-bucketed router        (bench_serve_slo)
  migrate  — swap migration + partial restore       (bench_restore_migration)
  c2       — burst vs element translation (+ coalescing)     (bench_translation)
  prefill  — gathered vs streamed continuation prefill       (bench_prefill_continue)
  pagesize — page-size sweep (TPU dual of the TLB sweep)     (bench_page_size)
  roof     — dry-run roofline table                          (roofline)

Seven sections double as CI gates when explicitly selected:
  * ``--only prefill`` exits nonzero if the chunked-prefill kernel path
    gathers at least as many bytes as the gathered-pages reference path;
  * ``--only serve`` exits nonzero unless auto-horizon greedy outputs are
    token-identical to the seed engine AND host syncs per decoded token
    are strictly below 1.0 AND the mean fused horizon exceeds 1.0 (batched
    K=1 decode already syncs less than once per token, so the sync ratio
    alone cannot detect the horizon silently regressing to K=1);
  * ``--only sharded`` exits nonzero unless the mesh-sharded executor is
    token-identical to the single-device KERNEL executor with the Pallas
    kernels LIVE (``kernel_dispatches > 0`` and ``ref_path_dispatches ==
    0`` — the jnp twin is reserved for the explicit ``--no-kernels``
    hatch), the scheduler counters are unchanged, AND the sharded kernel
    path gathers strictly fewer continuation-prefill KV bytes than the
    ref-path baseline engine.  Multi-device coverage needs
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    ``multidevice`` job); with one device the mesh degrades to 1x1 and
    the gate still checks the kernel dispatch path;
  * ``--only router`` exits nonzero unless the replica sweep (a
    ReplicaRouter over N in {1,2,4} engines) is per-request
    token-identical to the N=1 reference AND the router's global
    page/counter accounting equals the sum of the per-replica
    accounting;
  * ``--only prefix`` exits nonzero unless the multi-turn chat workload
    skips more than half the cold engine's prefill tokens
    (``prefill_tokens_skipped / prefill_tokens_cold > 0.5``) while every
    (session, turn) stream stays token-identical to the cold-admission
    reference;
  * ``--only quant`` exits nonzero unless int8 KV pools keep the kernels
    live (``ref_path_dispatches == 0``, ``quant_dispatches > 0`` on both
    the single-device and mesh engines), stay token-identical to the jnp
    ref oracle and the mesh engine, hold greedy top-1 agreement vs the
    fp-pool engine at or above the fixed threshold, shrink bytes-per-page
    and bytes_spilled by exactly the pool itemsize ratio (>= 2x) over the
    SAME spilled pages, and still gather strictly fewer continuation-
    prefill bytes than the int8 ref baseline;
  * ``--only slo`` exits nonzero unless the open-loop Poisson runs (each
    QPS level on a fresh AOT-bucketed engine behind an N=1 router) stay
    per-request token-identical to a closed-loop unbucketed reference,
    the streamed events match the drained results, and after warmup
    ``aot_misses == 0`` with ``aot_hits > 0``.  TTFT/TPOT p50/p99 and
    queue depth are recorded, never wall-clock-gated;
  * ``--only migrate`` exits nonzero unless the skewed heterogeneous
    two-replica fleet with migration ON completes EVERY request
    token-identically to the roomy single-replica reference with
    ``failed_unreachable == 0``, ``reach_redirects > 0`` and
    ``restore_migrations > 0`` (real KV pages exported from the starved
    small pool and adopted by the roomy one), while the reach-blind
    ``migrate=False`` baseline on the SAME load shows
    ``failed_unreachable > 0`` (the stranding being fixed — a baseline
    that stops failing means the scenario went vacuous, which is also a
    gate failure); the tight-pool partial-restore phase must show
    ``partial_restores > 0`` / ``pages_refilled > 0`` token-identically,
    and no engine may leak a swap record
    (``ContextSwitcher.swapped_out`` empty at every drain).

The serve, sharded, router, prefix, quant, slo and migrate sections also
append their metrics (tagged
with a ``section`` field) to ``BENCH_serve.json`` at the repo root — the
machine-readable perf trajectory across PRs, which
``scripts/bench_regress.py`` gates on per section (counters only, never
tok/s).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time


def section(title: str):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}")


def _fig2():
    from benchmarks import bench_tlb_sweep
    return bench_tlb_sweep.main()


def _table1():
    from benchmarks import bench_rivec
    return bench_rivec.main()


def _s31():
    from benchmarks import bench_context_switch
    return bench_context_switch.main()


def _record_serve_trajectory(metrics: dict, section: str = "serve") -> None:
    """Append the metrics to ``BENCH_serve.json`` (repo root): a JSON
    array, one record per benchmark run, so the perf trajectory across PRs
    is machine-readable instead of buried in CI logs.  Records are tagged
    with their ``section`` (``serve``, ``router``, ...) so
    ``scripts/bench_regress.py`` compares like with like; untagged legacy
    records read as ``serve``."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (OSError, ValueError):
            history = None
        if not isinstance(history, list):
            # never silently overwrite an existing trajectory: move the
            # unreadable/malformed file aside and start a fresh history
            backup = path.with_name(path.name + ".corrupt")
            path.replace(backup)
            print(f"WARNING: {path.name} was unreadable; moved to "
                  f"{backup.name}, starting a fresh trajectory")
            history = []
    history.append(
        {"t": time.strftime("%Y-%m-%dT%H:%M:%S"), "section": section,
         "metrics": metrics}
    )
    path.write_text(json.dumps(history, indent=2) + "\n")
    print(f"trajectory -> {path} ({len(history)} records)")


def _serve(gate: bool = False):
    from benchmarks import bench_serve_throughput
    csv, metrics = bench_serve_throughput.run()
    _record_serve_trajectory(metrics)
    failures = []
    if not metrics["token_identical"]:
        failures.append("auto-horizon greedy outputs diverged from the "
                        "seed engine")
    if metrics["host_syncs_per_token"] >= 1.0:
        failures.append(
            f"host syncs per decoded token = "
            f"{metrics['host_syncs_per_token']:.3f} (must be < 1.0: the "
            "fused horizon must amortize the per-token host round-trip)")
    if metrics["mean_horizon"] <= 1.0:
        failures.append(
            f"mean fused horizon = {metrics['mean_horizon']:.2f} (must be "
            "> 1.0: the auto horizon never opened on the quiet sweep "
            "workload — fusion is silently disabled)")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only serve: act as a CI gate
        sys.exit(1)
    return csv


def _sharded(gate: bool = False):
    from benchmarks import bench_serve_sharded
    csv, metrics = bench_serve_sharded.run()
    _record_serve_trajectory(metrics, section="sharded")
    failures = []
    if not metrics["token_identical"]:
        failures.append(
            f"sharded executor ({metrics['mesh_devices']} mesh devices) "
            "diverged from the single-device kernel token stream")
    if not metrics["counters_identical"]:
        failures.append(
            "scheduler counters changed under sharding — the data-plane "
            "layout leaked into policy decisions")
    if not metrics["kernels_live"]:
        failures.append(
            f"kernels not live on the mesh: kernel_dispatches="
            f"{metrics['kernel_dispatches']}, ref_path_dispatches="
            f"{metrics['ref_path_dispatches']} (every compute step must "
            "dispatch the Pallas kernels through shard_map; the jnp twin "
            "is reserved for the explicit --no-kernels hatch)")
    if not metrics["bytes_win"]:
        failures.append(
            f"continuation prefill gathered "
            f"{metrics['prefill_bytes_gathered_kernel']} B on the kernel "
            f"path vs {metrics['prefill_bytes_gathered_ref']} B on the ref "
            "path — the sharded kernel must gather strictly fewer KV bytes")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only sharded: act as a CI gate
        sys.exit(1)
    return csv


def _router(gate: bool = False):
    from benchmarks import bench_serve_router
    csv, metrics = bench_serve_router.run()
    _record_serve_trajectory(metrics, section="router")
    failures = []
    if not metrics["token_identical"]:
        failures.append(
            "replica-sweep outputs diverged from the N=1 reference run "
            "(or done statuses are not a permutation of it)")
    if not metrics["accounting_identical"]:
        failures.append(
            "router global page/counter accounting != sum of per-replica "
            "accounting")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only router: act as a CI gate
        sys.exit(1)
    return csv


def _prefix(gate: bool = False):
    from benchmarks import bench_prefix_cache
    csv, metrics = bench_prefix_cache.run()
    _record_serve_trajectory(metrics, section="prefix")
    failures = []
    if not metrics["token_identical"]:
        failures.append(
            "radix-hit streams diverged from the cold-admission reference "
            "(a COW-mapped prefix must reproduce full-prefill state "
            "exactly)")
    if metrics["skip_ratio"] <= 0.5:
        failures.append(
            f"skip ratio = {metrics['skip_ratio']:.2f} (must be > 0.5: "
            "the multi-turn chat workload re-prefills history the radix "
            "cache should be serving from resident pages)")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only prefix: act as a CI gate
        sys.exit(1)
    return csv


def _quant(gate: bool = False):
    from benchmarks import bench_kv_quant
    csv, metrics = bench_kv_quant.run()
    _record_serve_trajectory(metrics, section="quant")
    failures = []
    if not metrics["kernels_live"]:
        failures.append(
            f"kernels not live under int8 pools: "
            f"kernel={metrics['kernel_dispatches_int8']}/"
            f"ref={metrics['ref_path_dispatches_int8']}/"
            f"quant={metrics['quant_dispatches_int8']} single-device, "
            f"kernel={metrics['kernel_dispatches_int8_mesh']}/"
            f"ref={metrics['ref_path_dispatches_int8_mesh']}/"
            f"quant={metrics['quant_dispatches_int8_mesh']} mesh "
            "(quantization must ride the kernel dispatch, not the ref "
            "hatch)")
    if not metrics["token_identical_ref"]:
        failures.append(
            "int8 kernel tokens diverged from the int8 jnp ref oracle — "
            "the in-kernel dequant disagrees with the differential "
            "baseline")
    if not metrics["token_identical_mesh"]:
        failures.append(
            f"int8 mesh engine ({metrics['mesh_devices']} devices) "
            "diverged from the single-device int8 kernel stream")
    if metrics["top1_agreement"] < metrics["agreement_threshold"]:
        failures.append(
            f"greedy top-1 agreement vs the fp engine = "
            f"{metrics['top1_agreement']:.3f} (threshold "
            f"{metrics['agreement_threshold']}: the accuracy envelope "
            "collapsed)")
    if not metrics["bytes_halved"]:
        failures.append(
            f"bytes-per-page {metrics['bytes_per_page_fp']} -> "
            f"{metrics['bytes_per_page_int8']} is not the exact itemsize "
            "ratio (>= 2x) — quantized pools are not actually narrow")
    if not metrics["spill_halved"]:
        failures.append(
            f"bytes_spilled {metrics['bytes_spilled_fp']} -> "
            f"{metrics['bytes_spilled_int8']} over "
            f"{metrics['pages_spilled_fp']} vs "
            f"{metrics['pages_spilled_int8']} pages — spills must move "
            "the SAME pages at the itemsize-ratio fewer bytes (and the "
            "workload must actually spill)")
    if not metrics["bytes_win"]:
        failures.append(
            f"continuation prefill gathered "
            f"{metrics['prefill_bytes_gathered_int8']} B on the int8 "
            f"kernel path vs {metrics['prefill_bytes_gathered_int8_ref']} "
            "B on the int8 ref path — quantization must not forfeit the "
            "page-streaming win")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only quant: act as a CI gate
        sys.exit(1)
    return csv


def _slo(gate: bool = False):
    from benchmarks import bench_serve_slo
    csv, metrics = bench_serve_slo.run()
    _record_serve_trajectory(metrics, section="slo")
    failures = []
    if not metrics["token_identical"]:
        failures.append(
            "open-loop token streams diverged from the closed-loop "
            "unbucketed reference (AOT padding or arrival-time scheduling "
            "leaked into the tokens)")
    if not metrics["streams_identical"]:
        failures.append(
            "streamed events disagree with the drained results — the "
            "async detokenize pipeline dropped/reordered tokens")
    if metrics["aot_misses"] != 0:
        failures.append(
            f"aot_misses = {metrics['aot_misses']} after warmup (must be "
            "0: every serving prefill must hit a build-time-compiled "
            "executable — a miss is a potential jit stall under load)")
    if metrics["aot_hits"] <= 0:
        failures.append(
            "aot_hits == 0: the bucketed path never dispatched — the "
            "gate is vacuous")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only slo: act as a CI gate
        sys.exit(1)
    return csv


def _migrate(gate: bool = False):
    from benchmarks import bench_restore_migration
    csv, metrics = bench_restore_migration.run()
    _record_serve_trajectory(metrics, section="migrate")
    failures = []
    if not metrics["token_identical"]:
        failures.append(
            "migrating-fleet outputs diverged from the roomy single-replica "
            "reference (or a request did not finish) — migration must be a "
            "timing policy, never a token policy")
    if not metrics["partial_token_identical"]:
        failures.append(
            "partial-restore outputs diverged from the roomy reference (or "
            "a request did not finish) — the re-prefilled tail must "
            "reproduce the evicted KV exactly")
    if not metrics["accounting_identical"]:
        failures.append(
            "router global page/counter accounting != sum of per-replica "
            "accounting after migration")
    if metrics["failed_unreachable_migrate"] != 0:
        failures.append(
            f"failed_unreachable = {metrics['failed_unreachable_migrate']} "
            "with migration ON (must be 0: no request may fail while any "
            "replica can host it)")
    if metrics["failed_unreachable_baseline"] <= 0:
        failures.append(
            "the migrate=False baseline stranded nothing — the skewed "
            "workload no longer exercises the failure the gate exists to "
            "prevent (vacuous scenario)")
    if metrics["restore_migrations"] <= 0:
        failures.append(
            "restore_migrations == 0: no starved victim ever moved through "
            "the portable-swap path — the migration machinery went inert")
    if metrics["reach_redirects"] <= 0:
        failures.append(
            "reach_redirects == 0: placement never overrode a reach-blind "
            "choice on the heterogeneous fleet")
    if metrics["partial_restores"] <= 0 or metrics["pages_refilled"] <= 0:
        failures.append(
            f"partial_restores = {metrics['partial_restores']}, "
            f"pages_refilled = {metrics['pages_refilled']} (both must be "
            "> 0: the capacity-blocked head never came back early)")
    if metrics["swap_record_leaks"] != 0:
        failures.append(
            f"{metrics['swap_record_leaks']} swap records left on a "
            "ContextSwitcher at drain — a terminal path forgot to "
            "restore/export/discard its spill")
    for f in failures:
        print(f"FAIL: {f}")
    if failures and gate:          # --only migrate: act as a CI gate
        sys.exit(1)
    return csv


def _c2():
    from benchmarks import bench_translation
    return bench_translation.main()


def _prefill(gate: bool = False):
    from benchmarks import bench_prefill_continue
    csv, metrics = bench_prefill_continue.run()
    if metrics["kernel_bytes"] >= metrics["ref_bytes"]:
        print(f"FAIL: kernel path gathered {metrics['kernel_bytes']} B, "
              f"reference gathered {metrics['ref_bytes']} B — the streamed "
              "path must touch strictly fewer bytes")
        if gate:              # --only prefill: act as a CI gate
            sys.exit(1)
    return csv


def _pagesize():
    from benchmarks import bench_page_size
    return bench_page_size.main()


def _roof():
    from benchmarks import roofline
    return roofline.main()


SECTIONS: list[tuple[str, str, object]] = [
    ("fig2", "Fig. 2(b,c,d): matmul VM overhead vs DTLB size", _fig2),
    ("table1", "Table 1: RiVEC suite (S / V / Vu)", _table1),
    ("s31", "§3.1: scheduler interrupts + context switches", _s31),
    ("serve", "Serving split: seed vs Scheduler/Executor (decode + switches)",
     _serve),
    ("sharded",
     "Sharded executor over the ('kv','hd') serve mesh vs single-device",
     _sharded),
    ("router",
     "Replica sweep: ReplicaRouter over N engines vs the N=1 reference",
     _router),
    ("prefix",
     "Radix prefix cache: multi-turn chat, warm (radix) vs cold admission",
     _prefix),
    ("quant",
     "Quantized int8 KV pools: accuracy envelope + bytes-per-page halving",
     _quant),
    ("slo",
     "Open-loop SLO: Poisson arrivals vs AOT-bucketed router (TTFT/TPOT)",
     _slo),
    ("migrate",
     "Swap migration: skewed heterogeneous fleet + partial restore",
     _migrate),
    ("c2", "C2: translation counts (burst / element / coalesced)", _c2),
    ("prefill",
     "Chunked prefill: gathered-pages oracle vs page-streaming kernel",
     _prefill),
    ("pagesize",
     "Beyond-paper: page-size sweep (the TPU dual of the TLB sweep)",
     _pagesize),
    ("roof", "Roofline (from dry-run artifacts)", _roof),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=[k for k, _, _ in SECTIONS],
                    action="append", default=None,
                    help="run only the named section(s); repeatable")
    args = ap.parse_args(argv)
    t0 = time.time()
    csv: list[str] = ["name,us_per_call,derived"]
    for key, title, fn in SECTIONS:
        if args.only is not None and key not in args.only:
            continue
        section(title)
        if key in ("prefill", "serve", "sharded", "router", "prefix",
                   "quant", "slo", "migrate"):
            # the gates abort only when explicitly selected; a full run
            # must still emit the complete CSV block
            csv += fn(gate=args.only is not None)
        else:
            csv += fn()
    section(f"CSV (total {time.time() - t0:.0f}s)")
    for line in csv:
        print(line)


if __name__ == "__main__":
    if __package__ in (None, ""):
        # `python benchmarks/run.py`: the script's own directory is on
        # sys.path but the repo root is not, so the `from benchmarks
        # import ...` inside each section would fail with a confusing
        # ModuleNotFoundError.  Put the repo root (and src/, for `repro`
        # itself when PYTHONPATH is unset) first so both invocation forms
        # work (`python -m benchmarks.run` is the canonical one).
        _root = pathlib.Path(__file__).resolve().parent.parent
        sys.path.insert(0, str(_root / "src"))
        sys.path.insert(0, str(_root))
    main()
