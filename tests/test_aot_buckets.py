"""AOT-bucketed prefill (``ServeConfig.aot_buckets``): bucket selection
boundaries, exact pad accounting, token identity vs the unbucketed
engine, and module-cache keying across model twins.

The contract (see ``repro/serve/executor.py``): every prefill /
continuation dispatch whose burst-aligned width fits a configured bucket
runs through an executable compiled AT ENGINE BUILD (``aot_hits``), pads
are numerically inert (greedy streams bit-identical to the plain
shape-keyed jit path), wider batches fall back loudly (``aot_misses``),
and executables are shared module-wide per (model twin, mesh, kind,
bucket, geometry) — never re-lowered per engine.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serve import Engine, ServeConfig, ServeRequest
from repro.serve.executor import _AOT_CACHE, select_bucket

pytestmark = pytest.mark.slo

KEY = jax.random.PRNGKey(0)

GEOM = dict(page_size=4, num_pages=64, max_pages_per_seq=16, max_batch=3)


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    return cfg, model, model.init(KEY)


def _reqs(cfg, lens, max_new=6):
    rng = np.random.default_rng(11)
    return [
        ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
            max_new_tokens=max_new, req_id=i,
        )
        for i, n in enumerate(lens)
    ]


def _tokens(results):
    return {rid: [int(np.asarray(t)) for t in r.tokens]
            for rid, r in results.items()}


class TestSelectBucket:
    def test_boundaries(self):
        assert select_bucket(8, (8, 16)) == 8      # exact fit
        assert select_bucket(9, (8, 16)) == 16     # next bucket up
        assert select_bucket(16, (8, 16)) == 16
        assert select_bucket(17, (8, 16)) is None  # beyond every bucket
        assert select_bucket(1, (8, 16)) == 8

    def test_no_buckets(self):
        assert select_bucket(4, None) is None
        assert select_bucket(4, ()) is None


class TestAotEngine:
    def test_token_identity_and_no_misses(self, model_and_params):
        """Bucket padding must be invisible in the greedy streams, and
        every dispatch must hit a build-time executable."""
        cfg, model, params = model_and_params
        lens = (5, 7, 4, 11, 8)                  # spans both buckets
        plain = Engine(model, params, ServeConfig(**GEOM))
        for r in _reqs(cfg, lens):
            plain.submit(r)
        want = _tokens(plain.drain())
        assert plain.counters.get("aot_hits") == 0    # unbucketed: no counting
        assert plain.counters.get("aot_misses") == 0

        aot = Engine(model, params,
                     ServeConfig(aot_buckets=(8, 16), **GEOM))
        for r in _reqs(cfg, lens):
            aot.submit(r)
        got = _tokens(aot.drain())
        assert got == want
        assert aot.counters.get("aot_hits") > 0
        assert aot.counters.get("aot_misses") == 0
        assert aot.counters.get("bucket_pad_tokens") > 0

    def test_exact_pad_accounting_single_request(self, model_and_params):
        """One 5-token prompt under bucket 8, max_batch 3: the dispatch
        pads 1 row of burst-aligned width 8 up to 3 rows x 8 columns —
        exactly max_batch*bucket - nrows*aligned == 16 pad tokens."""
        cfg, model, params = model_and_params
        eng = Engine(model, params, ServeConfig(aot_buckets=(8,), **GEOM))
        eng.submit(_reqs(cfg, (5,))[0])
        eng.drain()
        assert eng.counters.get("aot_hits") == 1
        assert eng.counters.get("bucket_pad_tokens") == 3 * 8 - 1 * 8

    def test_overlong_prompt_is_a_counted_miss(self, model_and_params):
        """A prompt whose aligned width exceeds every bucket falls back
        to the shape-keyed jit — counted, completed, token-identical."""
        cfg, model, params = model_and_params
        plain = Engine(model, params, ServeConfig(**GEOM))
        for r in _reqs(cfg, (9,)):
            plain.submit(r)
        want = _tokens(plain.drain())

        eng = Engine(model, params, ServeConfig(aot_buckets=(8,), **GEOM))
        for r in _reqs(cfg, (9,)):                # aligned width 12 > 8
            eng.submit(r)
        got = _tokens(eng.drain())
        assert got == want
        assert eng.counters.get("aot_misses") == 1
        assert eng.counters.get("aot_hits") == 0
        assert eng.counters.get("bucket_pad_tokens") == 0

    def test_continuation_prefill_rides_the_buckets(self, model_and_params):
        """share_prefix forks prefill only the divergent chunk through
        ``admit_forked_batch`` — that continuation dispatch must hit the
        'continue' executable, and streams must match the unbucketed
        forked engine."""
        cfg, model, params = model_and_params
        rng = np.random.default_rng(5)
        prefix = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)

        def forked(serve_cfg):
            eng = Engine(model, params, serve_cfg)
            eng.preload_prefix(prefix)
            for r in _reqs(cfg, (5, 6)):
                r.share_prefix = True
                eng.submit(r)
            return eng

        plain = forked(ServeConfig(**GEOM))
        want = _tokens(plain.drain())
        aot = forked(ServeConfig(aot_buckets=(8,), **GEOM))
        got = _tokens(aot.drain())
        assert got == want
        assert aot.counters.get("aot_misses") == 0
        assert aot.counters.get("aot_hits") > 0
        assert aot.counters.get("continuation_prefill_tokens") == \
            plain.counters.get("continuation_prefill_tokens")


class TestModuleCacheKeying:
    def test_same_twin_shares_new_twin_recompiles(self, model_and_params):
        """The module cache keys on (step-model twin, mesh, kind, bucket,
        geometry): a second identical engine adds NOTHING and binds the
        same executables; an int8-KV engine (a different model twin with
        different pool dtypes) adds exactly its own entries; a new bucket
        size adds exactly one entry per kind.  A geometry no other test
        uses (max_batch=2), so the entry-count deltas are exact
        regardless of what ran before in this process."""
        cfg, model, params = model_and_params
        geom = dict(GEOM, max_batch=2)
        a = Engine(model, params, ServeConfig(aot_buckets=(8,), **geom))
        n0 = len(_AOT_CACHE)

        b = Engine(model, params, ServeConfig(aot_buckets=(8,), **geom))
        assert len(_AOT_CACHE) == n0              # full reuse
        assert all(b.executor._aot[k] is a.executor._aot[k]
                   for k in a.executor._aot)

        wider = Engine(model, params,
                       ServeConfig(aot_buckets=(8, 16), **geom))
        assert len(_AOT_CACHE) == n0 + 2          # (prefill,16), (continue,16)
        assert wider.executor._aot[("prefill", 8)] is \
            a.executor._aot[("prefill", 8)]

        n1 = len(_AOT_CACHE)
        q = Engine(model, params,
                   ServeConfig(aot_buckets=(8,), kv_dtype="int8", **geom))
        assert len(_AOT_CACHE) == n1 + 2          # int8 twin: own executables
        assert q.executor._aot[("prefill", 8)] is not \
            a.executor._aot[("prefill", 8)]
