"""MXU-tiled matmul kernel — the paper's central benchmark kernel (§3.1).

AraOS evaluates virtual-memory overhead on matrix multiplication "as an
example of a vector kernel that heavily requires the cooperation of the
scalar core".  This is its TPU restatement: a classic three-level blocked
matmul with

  * grid ``(M/bm, N/bn, K/bk)`` — K innermost so the f32 accumulator tile
    lives in VMEM scratch across the K sweep (the vector-register working
    set of the RVV kernel);
  * ``(bm, bk) x (bk, bn)`` VMEM blocks feeding the 128x128 MXU;
  * accumulation in f32 regardless of input dtype (bf16 in, f32 acc).

The TLB-sweep benchmark replays this kernel's *address stream* (one burst
per page-bounded block row) through the shared-MMU simulator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import should_interpret
from repro.kernels import common


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype", "interpret")
)
def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    out_dtype: jnp.dtype | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """``x @ y`` with explicit VMEM tiling.

    Shapes must be multiples of the block shape (``ops.matmul`` pads).
    """
    if interpret is None:
        interpret = should_interpret()
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
    )
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=common.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, y)
