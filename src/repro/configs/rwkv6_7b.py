"""RWKV-6 (Finch) 7B — attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv6", num_layers=32, d_model=4096,
    num_heads=0, num_kv_heads=0, d_ff=14336, vocab_size=65536,
    rwkv_head_size=64,
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced", family="rwkv6", num_layers=2, d_model=32,
    num_heads=0, num_kv_heads=0, d_ff=64, vocab_size=128,
    rwkv_head_size=16, param_dtype="float32",
)
