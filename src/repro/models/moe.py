"""Mixture-of-Experts FFN with sort-based (ragged) dispatch.

Two dispatch implementations with identical semantics:

  * ``moe_apply_sorted`` (default) — production path: tokens are argsorted by
    expert id, packed into per-expert capacity buffers by rank, processed with
    a grouped einsum ``[E, C, D] x [E, D, F]``, and combined by gather +
    gate-weighted sum.  No [T, E, C] one-hot tensor is ever materialized.
    Tokens beyond an expert's capacity are dropped (their residual passes
    through), standard Switch/GShard behaviour.

  * ``moe_apply_dense`` — O(E·T) oracle that computes every expert for every
    token and masks.  Used as the correctness reference in tests and as the
    naive baseline of the MoE perf-hillclimb cell (EXPERIMENTS.md §Perf).

Expert-parallelism: expert-indexed weights ``[E, D, F]`` shard E over the
``model`` mesh axis; the scatter/gather around the grouped einsum becomes the
all-to-all in the dry-run's collective schedule.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Params = dict[str, Any]


def moe_init(key, cfg, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router in f32
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) / math.sqrt(f)).astype(dtype),
    }


def router_topk(params: Params, x: jax.Array, k: int):
    """Returns (expert_ids [T, k] int32, gates [T, k] f32, aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ params["router"]          # [T, E]
    e = logits.shape[-1]
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, expert_ids = jax.lax.top_k(gates_all, k)            # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    density = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0
    ) / expert_ids.size
    router_prob = gates_all.mean(axis=0)
    aux = e * jnp.sum(density * router_prob)
    return expert_ids.astype(jnp.int32), gates, aux


def capacity(t: int, k: int, e: int, factor: float = 1.25) -> int:
    return max(1, math.ceil(t * k / e * factor))


def moe_apply_sorted(
    params: Params,
    x: jax.Array,                  # [T, D] (caller flattens batch x seq)
    *,
    num_experts: int,
    k: int,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """Sort-based dispatch. Returns (out [T, D], aux_loss)."""
    t, d = x.shape
    e = num_experts
    c = capacity(t, k, e, capacity_factor)
    expert_ids, gates, aux = router_topk(params, x, k)

    flat_e = expert_ids.reshape(-1)                            # [T*k]
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)     # [T*k]
    order = jnp.argsort(flat_e, stable=True)                   # group by expert
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    # rank of each assignment within its expert group
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts               # exclusive prefix sum
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
    keep = rank < c
    buf_idx = jnp.where(keep, e_sorted * c + rank, e * c)      # drop -> trash row

    # pack expert inputs [E*C+1, D] (last row = trash)
    expert_in = jnp.zeros((e * c + 1, d), x.dtype).at[buf_idx].set(x[t_sorted])
    h = expert_in[:-1].reshape(e, c, d)
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
    h2 = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, params["w_down"])

    # combine: gather back per assignment, weight by gate, sum over k
    flat_out = jnp.concatenate(
        [h2.reshape(e * c, d), jnp.zeros((1, d), h2.dtype)], axis=0
    )[buf_idx]                                                  # [T*k, D] sorted
    inv = jnp.argsort(order)
    per_assign = flat_out[inv].reshape(t, k, d)
    out = (per_assign.astype(jnp.float32) * gates[..., None]).sum(1)
    return out.astype(x.dtype), aux


def moe_apply_ragged(
    params: Params,
    x: jax.Array,                  # [T, D]
    *,
    num_experts: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Drop-free grouped-matmul dispatch via ``jax.lax.ragged_dot``.

    The modern production path (megablocks-style): assignments are sorted by
    expert, the three FFN matmuls run as ragged group GEMMs with *exact*
    per-expert group sizes — no capacity buffers, no token dropping, O(T·k)
    activation memory.  Exactly equal to the dense oracle.  Default dispatch
    for both training and serving; the capacity-based ``moe_apply_sorted``
    remains as the GShard-faithful baseline (§Perf compares them).
    """
    t, d = x.shape
    e = num_experts
    expert_ids, gates, aux = router_topk(params, x, k)
    flat_e = expert_ids.reshape(-1)                            # [T*k]
    order = jnp.argsort(flat_e, stable=True)
    xs = x[order // k]                                         # [T*k, D]
    group_sizes = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    up = jax.lax.ragged_dot(xs, params["w_up"], group_sizes)
    gate = jax.lax.ragged_dot(xs, params["w_gate"], group_sizes)
    h = (jax.nn.silu(gate.astype(jnp.float32)) *
         up.astype(jnp.float32)).astype(x.dtype)
    out_sorted = jax.lax.ragged_dot(h, params["w_down"], group_sizes)
    per_assign = out_sorted[jnp.argsort(order)].reshape(t, k, d)
    out = (per_assign.astype(jnp.float32) * gates[..., None]).sum(1)
    return out.astype(x.dtype), aux


def moe_apply_sorted_rows(
    params: Params,
    x: jax.Array,                  # [B, S, D] — rows stay data-sharded
    *,
    num_experts: int,
    k: int,
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """Per-row (GShard "group") sorted dispatch.

    A single global argsort over all B*S tokens is a *global* sort under
    SPMD — GSPMD materializes cross-shard gathers of every token.  GShard's
    fix is hierarchical dispatch: each data-sharded group (here: a batch
    row) sorts and packs its own tokens locally; only the expert einsum
    crosses shards (the expert-parallel all-to-all).  Capacity is per row.
    """
    def one_row(xr):
        return moe_apply_sorted(
            params, xr, num_experts=num_experts, k=k,
            capacity_factor=capacity_factor,
        )

    out, aux = jax.vmap(one_row)(x)
    return out, aux.mean()


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _ragged_moe_vmappable(num_experts: int, k: int):
    """``moe_apply_ragged`` wrapped for vmap (serving groups).

    ``jax.lax.ragged_dot`` has no batching rule, but MoE routing is purely
    per-token: a batch of G groups is exactly one dispatch over the G*T
    flattened tokens.  The custom_vmap rule flattens, runs the unbatched
    primal once, and unflattens — zero extra compute, and the grouped
    serve path (vmap over the data-group axis) lowers cleanly.
    """

    @jax.custom_batching.custom_vmap
    def fn(params, x):
        return moe_apply_ragged(params, x, num_experts=num_experts, k=k)

    @fn.def_vmap
    def _rule(axis_size, in_batched, params, x):
        params_batched, x_batched = in_batched
        assert not any(jax.tree.leaves(params_batched)), (
            "expert weights must be unbatched across serve groups"
        )
        g, t, d = x.shape
        out, aux = fn(params, x.reshape(g * t, d))
        return (out.reshape(g, t, d), jnp.full((g,), aux)), (True, True)

    return fn


def moe_apply_ragged_batched(params: Params, x: jax.Array, *,
                             num_experts: int, k: int):
    """vmap-safe entry point (used by the serving paths)."""
    return _ragged_moe_vmappable(num_experts, k)(params, x)


def moe_apply_dense(
    params: Params,
    x: jax.Array,                  # [T, D]
    *,
    num_experts: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """O(E·T) oracle: every expert computes every token; mask + combine.

    No capacity, no dropping — exact top-k semantics.  The sorted path
    matches it exactly whenever no token exceeds expert capacity.
    """
    expert_ids, gates, aux = router_topk(params, x, k)
    up = jnp.einsum("td,edf->etf", x, params["w_up"])
    gate = jnp.einsum("td,edf->etf", x, params["w_gate"])
    h2 = jnp.einsum("etf,efd->etd", jax.nn.silu(gate) * up, params["w_down"])
    onehot = jax.nn.one_hot(expert_ids, num_experts, dtype=jnp.float32)  # [T,k,E]
    weights = (onehot * gates[..., None]).sum(1)               # [T, E]
    out = jnp.einsum("te,etd->td", weights, h2.astype(jnp.float32))
    return out.astype(x.dtype), aux
