"""Qwen2-7B — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    head_dim=128, qkv_bias=True, rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen2-7b-reduced", family="dense", num_layers=2, d_model=56,
    num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=128,
    head_dim=14, qkv_bias=True, param_dtype="float32",
)
