"""Deterministic synthetic data pipeline with multi-host sharding."""
from repro.data.pipeline import DataConfig, SyntheticLMStream, make_global_batch
__all__ = ["DataConfig", "SyntheticLMStream", "make_global_batch"]
