"""§3.1 reproduction: scheduler interrupts + vector context switches.

Three measurements mirrored on the paper:
  1. the COST MODEL cross-check: an 8-KiB vector register file moved at
     64 bit/cycle => ~3.2 k-cycle context switch (vs ~1 k scalar);
  2. the FUNCTIONAL path: the serving engine preempts live requests with a
     deliberately undersized page pool; we report real bytes moved and
     modeled cycles per switch, plus preemption transparency;
  3. scheduler interference: 100 Hz ticks at ~20 k cycles and TLB pollution
     < 0.5 % of runtime (replayed through the simulator with pollution).
"""

from __future__ import annotations

import copy
import time

import numpy as np

from repro.core import CostModel, SharedMMUSimulator
from repro.core.tlb import VECTOR, AccessEvent


def model_cross_check() -> list[str]:
    cost = CostModel()
    lines = []
    vrf = cost.context_switch_cycles(8 * 1024)
    scalar = cost.scalar_ctx_switch_cycles
    print(f"scalar context switch:          {scalar} cycles (paper ~1k)")
    print(f"vector (8-KiB VRF @ 8 B/cyc):   {vrf} cycles (paper ~3.2k)")
    lines.append(f"ctx_switch_scalar_cycles,0,{scalar}")
    lines.append(f"ctx_switch_vector_cycles,0,{vrf}")
    assert 2_800 <= vrf <= 3_600
    return lines


def engine_preemption() -> list[str]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig, ServeRequest

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [
        ServeRequest(req_id=i,
                     prompt=rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(6, 16))
                                         ).astype(np.int32),
                     max_new_tokens=12)
        for i in range(6)
    ]
    eng = Engine(model, params, ServeConfig(
        page_size=4, num_pages=16, max_pages_per_seq=16, max_batch=3))
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    st = eng.switcher.stats
    cost = CostModel()
    per_switch = st.modeled_cycles / max(st.switches, 1)
    print(f"engine: {st.switches} context switches, "
          f"{st.bytes_spilled} B spilled, "
          f"{per_switch:.0f} modeled cycles/switch "
          f"({cost.seconds(per_switch)*1e6:.1f} us @50 MHz)")
    return [
        f"engine_ctx_switches,{wall*1e6:.0f},{st.switches}",
        f"engine_ctx_cycles_per_switch,0,{per_switch:.0f}",
        f"engine_bytes_per_switch,0,"
        f"{st.bytes_spilled // max(st.switches, 1)}",
    ]


def scheduler_interference() -> list[str]:
    cost = CostModel()
    # 1 second of runtime at 50 MHz with 100 Hz ticks
    tick_frac = cost.tick_overhead_fraction(runtime_cycles=cost.freq_hz)
    # pollution: replay a steady working set with per-tick TLB evictions,
    # then express the per-tick refill cost against the REAL inter-tick
    # interval (freq / tick_hz cycles) — the trace compresses time
    ws = list(range(24)) * 400
    n_ticks = 10
    sim = SharedMMUSimulator(64, cost)
    rep = sim.run(
        [AccessEvent(VECTOR, v, slack=5.0) for v in ws],
        pollution_evictions_per_tick=8,
        num_ticks=n_ticks,
    )
    inter_tick_cycles = cost.freq_hz / cost.sched_tick_hz
    pollution_frac = (rep.mux_pollution_cycles / n_ticks) / inter_tick_cycles
    print(f"tick handling: {tick_frac*100:.2f}% of runtime "
          f"(100 Hz x ~20k cycles)")
    print(f"TLB pollution: {pollution_frac*100:.4f}% of runtime "
          f"(paper: < 0.5%)")
    assert pollution_frac < 0.005
    return [
        f"sched_tick_frac,0,{tick_frac*100:.2f}%",
        f"sched_pollution_frac,0,{pollution_frac*100:.3f}%",
    ]


def main() -> list[str]:
    lines = []
    lines += model_cross_check()
    lines += engine_preemption()
    lines += scheduler_interference()
    return lines


if __name__ == "__main__":
    main()
