"""Deterministic fault-schedule ``DataPlane`` fake for scheduler + router
suites.

:class:`FaultyDataPlane` implements the FULL
:class:`repro.serve.scheduler.DataPlane` protocol — the movement surface
(spill/restore/discard/fork) over a bare :class:`VirtualMemory`, like
``HostOnlyPlane``, AND the compute surface (prefill/decode/decode_multi)
that ``Scheduler.step_plane`` drives — plus a scripted fault schedule:

  ``("hog", step, pages, duration)``
      Seize up to ``pages`` free frames at ``step`` and hold them for
      ``duration`` drive steps: transient memory pressure that induces
      growth stalls, horizon collapses, blocked admissions and deferred
      restores (all of which must degrade, never corrupt).
  ``("force_spill", step, req_id)``
      Preempt ``req_id`` through the scheduler's own spill path if it is
      running at ``step`` (no-op otherwise).
  ``("fail_restore", step, req_id, times)``
      Arm the plane to raise :class:`RestoreFailure` for the next
      ``times`` restore attempts of ``req_id`` from ``step`` on (the
      transient data-plane failure the scheduler must retry, not crash
      or drop).
  ``("delay_done", step, req_id, times)``
      Sugar: force-spill ``req_id`` at ``step`` and fail its next
      ``times`` restores — the request completes late, permuting the
      ``done`` order without changing any token stream.
  ``("submit", step, request)``
      Submit ``request`` to the attached scheduler at ``step`` (scripted
      late arrivals; the router harness submits through the router
      instead).
  ``("reject_import", step, req_id, times)``
      Arm the plane to reject the next ``times`` ``import_swap`` attempts
      for ``req_id`` from ``step`` on (raised BEFORE any side effect, per
      the DataPlane contract — the router must roll the migration back at
      the source).  Composed with ``hog`` on a destination plane this
      also models "destination fills mid-import": the import lands but
      the restore stays capacity-blocked there.

**Token determinism is the harness's core trick**: every sampled token is
``token_for(req_id, output_index)`` — a pure function of the request
identity and position, independent of placement, batching, horizons,
spills or faults.  A correct scheduler/router therefore produces
*bit-identical per-request streams* under ANY replica count and ANY fault
schedule, so the property suites can assert token identity against a
single fault-free N=1 reference run (or the closed form) while faults
scramble all the timing underneath.

Counter mirroring: the plane increments the same accounting the real
``Executor`` does (``host_syncs``, ``ptab_syncs``/``ptab_rows_uploaded``
via real ``drain_dirty_rows`` draining, ``decode_dispatches``,
``decode_horizon``, ``continuation_prefill_tokens``) on the scheduler's
OWN counter object, so counter-invariant tests (monotonicity, N-replica
totals = sum of per-replica values) run without a device.
"""

from __future__ import annotations

import numpy as np

from repro.core import PerfCounters, VirtualMemory, VMemConfig
from repro.serve import (
    Request,
    RestoreFailure,
    Scheduler,
    ServeConfig,
)


def token_for(req_id: int, index: int) -> np.int32:
    """The deterministic token stream: request identity x position only."""
    return np.int32((req_id * 1009 + index * 101 + 7) % 32000)


def expected_output(req: Request) -> list[int]:
    """The closed-form stream a correct run must deliver for ``req``.

    Seed semantics: retirement is checked AFTER the decode append, so
    even a request already satisfied by its prefill token decodes once
    more — the delivered length is ``max(2, max_new_tokens)``.
    """
    return [int(token_for(req.req_id, j))
            for j in range(max(2, req.max_new_tokens))]


class FaultyDataPlane:
    """Fault-injecting, token-deterministic ``DataPlane`` fake."""

    def __init__(self, vmem: VirtualMemory,
                 counters: PerfCounters | None = None,
                 schedule: tuple | list = ()):
        self.vmem = vmem
        self.counters = counters or PerfCounters()
        self.sched: Scheduler | None = None
        self.events: list[tuple] = []
        self._schedule = sorted(schedule, key=lambda e: e[1])
        self._fired = [False] * len(self._schedule)
        self._hogs: list[tuple[int, list[int]]] = []   # (release_at, pages)
        self._deny_restore: dict[int, int] = {}        # req_id -> times left
        self._deny_import: dict[int, int] = {}         # req_id -> times left
        self._exported: set[int] = set()   # rollback imports never rejected
        self._spilled_len: dict[int, int] = {}

    @property
    def swapped_out(self) -> list[int]:
        """Requests whose swap records this plane still holds — mirrors
        ``ContextSwitcher.swapped_out`` for the leak-audit tests (must be
        empty at engine drain)."""
        return sorted(self._spilled_len)

    def attach(self, sched: Scheduler) -> None:
        """Bind the scheduler whose slots/outputs parametrize the token
        streams (and whose counters this plane increments)."""
        self.sched = sched
        self.counters = sched.counters

    # ------------------------------------------------------------------
    # fault schedule
    # ------------------------------------------------------------------

    @property
    def has_pending_submits(self) -> bool:
        return any(ev[0] == "submit" and not f
                   for ev, f in zip(self._schedule, self._fired))

    def tick(self, step: int) -> None:
        """Run the fault schedule for drive-loop iteration ``step`` (call
        once per step, BEFORE ``step_plane`` — the position the old
        hand-rolled test hooks occupied)."""
        still = []
        for release_at, pages in self._hogs:
            if release_at <= step:
                self.vmem.pool.free(pages)
                self.events.append(("hog_release", len(pages)))
            else:
                still.append((release_at, pages))
        self._hogs = still
        for i, ev in enumerate(self._schedule):
            if self._fired[i] or ev[1] > step:
                continue
            self._fired[i] = True
            self._apply(ev, step)

    def _apply(self, ev: tuple, step: int) -> None:
        kind = ev[0]
        if kind == "hog":
            _, _, pages, duration = ev
            n = min(pages, self.vmem.pool.num_free)
            if n > 0:
                held = self.vmem.pool.alloc(n)
                self._hogs.append((step + duration, held))
                self.events.append(("hog", n))
        elif kind == "force_spill":
            _, _, req_id = ev
            if req_id in self.sched.running:
                self.sched.spill(self.sched.running[req_id])
                self.events.append(("forced_spill", req_id))
        elif kind == "fail_restore":
            _, _, req_id, times = ev
            self._deny_restore[req_id] = (
                self._deny_restore.get(req_id, 0) + times
            )
        elif kind == "delay_done":
            _, _, req_id, times = ev
            if req_id in self.sched.running:
                self._deny_restore[req_id] = (
                    self._deny_restore.get(req_id, 0) + times
                )
                self.sched.spill(self.sched.running[req_id])
                self.events.append(("delay_done", req_id))
        elif kind == "reject_import":
            _, _, req_id, times = ev
            self._deny_import[req_id] = (
                self._deny_import.get(req_id, 0) + times
            )
        elif kind == "submit":
            _, _, req = ev
            self.sched.submit(req)
            self.events.append(("scripted_submit", req.req_id))
        else:
            raise ValueError(f"unknown fault event {ev!r}")

    # ------------------------------------------------------------------
    # accounting shared with the real executor
    # ------------------------------------------------------------------

    def _sync(self) -> None:
        """Mirror ``Executor.sync_page_table``: really drain the dirty
        rows so ptab accounting matches the device plane's cadence."""
        rows, _vals = self.vmem.drain_dirty_rows()
        if rows.size:
            self.counters.inc("ptab_rows_uploaded", int(rows.size))
            self.counters.inc("ptab_syncs")

    # ------------------------------------------------------------------
    # movement surface (HostOnlyPlane-compatible event tuples)
    # ------------------------------------------------------------------

    def spill(self, req: Request) -> None:
        self.events.append(("spill", req.req_id))
        self._spilled_len[req.req_id] = self.vmem.seq_len(req.req_id)
        self.vmem.spill_seq(req.req_id)

    def restore(self, req: Request, num_tokens: int,
                shared_pages=None) -> None:
        if self._deny_restore.get(req.req_id, 0) > 0:
            # raised BEFORE any side effect (the RestoreFailure contract)
            self._deny_restore[req.req_id] -= 1
            self.events.append(("restore_failed", req.req_id))
            raise RestoreFailure(f"injected restore failure: {req.req_id}")
        # partial restores legally re-map a page-aligned prefix of the
        # spilled length; the record is CONSUMED either way (no tail leak)
        assert num_tokens <= self._spilled_len.pop(req.req_id)
        self.events.append(("restore", req.req_id))
        self.vmem.restore_seq(req.req_id, num_tokens, shared_pages)

    def discard(self, req: Request) -> None:
        self.events.append(("discard", req.req_id))
        self._spilled_len.pop(req.req_id, None)

    def export_swap(self, req: Request):
        """Detach the swap record for migration — after this the plane
        holds nothing for ``req`` (asserted by the leak-audit tests)."""
        self.events.append(("export_swap", req.req_id))
        self._exported.add(req.req_id)
        return ("swap_record", req.req_id,
                self._spilled_len.pop(req.req_id))

    def import_swap(self, req: Request, record) -> None:
        """Adopt a migrated record; injected rejections raise BEFORE any
        side effect (the contract the router's rollback relies on).
        Re-imports of a record THIS plane just exported (the router's
        rollback after a destination rejection) are never rejected —
        re-attaching what the source detached moments ago cannot fail,
        only the destination's adoption gate can."""
        rollback = req.req_id in self._exported
        if not rollback and self._deny_import.get(req.req_id, 0) > 0:
            self._deny_import[req.req_id] -= 1
            self.events.append(("import_rejected", req.req_id))
            raise RuntimeError(f"injected import rejection: {req.req_id}")
        kind, rid, spilled_len = record
        assert kind == "swap_record" and rid == req.req_id
        self._exported.discard(rid)
        self.events.append(("import_swap", req.req_id))
        self._spilled_len[req.req_id] = spilled_len

    def admit_forked_batch(self, reqs, start_lens, tail_copies):
        self._sync()
        self.events.append(("admit_forked_batch", [r.req_id for r in reqs]))
        for req, start, tail in zip(reqs, start_lens, tail_copies):
            self.events.append(("admit_forked", req.req_id, start, tail))
        self.counters.inc("host_syncs")
        self.counters.inc(
            "continuation_prefill_tokens", sum(len(r.prompt) for r in reqs)
        )
        return [token_for(r.req_id, 0) for r in reqs]

    # ------------------------------------------------------------------
    # compute surface (token_for streams)
    # ------------------------------------------------------------------

    def prefill(self, reqs):
        self._sync()
        self.events.append(("prefill", [r.req_id for r in reqs]))
        self.counters.inc("host_syncs")
        return [token_for(r.req_id, 0) for r in reqs]

    def decode(self, tokens, pre_lens, active):
        self._sync()
        out = np.zeros(np.shape(tokens), np.int32)
        for req_id, slot in self.sched.slot_of.items():
            out[slot] = token_for(
                req_id, len(self.sched.running[req_id].output)
            )
        self.counters.inc("host_syncs")
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon")
        return out

    def decode_multi(self, plan):
        self._sync()
        block = np.zeros((plan.horizon,) + np.shape(plan.tokens), np.int32)
        for req_id, slot in self.sched.slot_of.items():
            j0 = len(self.sched.running[req_id].output)
            for t in range(plan.horizon):
                # rows past a lane's retirement are scratch, like the
                # device block; the scheduler must never consume them
                block[t][slot] = token_for(req_id, j0 + t)
        self.counters.inc("host_syncs")
        self.counters.inc("decode_dispatches")
        self.counters.inc("decode_horizon", plan.horizon)
        return block


# ---------------------------------------------------------------------------
# harness constructors / drivers
# ---------------------------------------------------------------------------


def make_replica(page_size=4, usable_pages=15, max_pages=8, max_batch=3,
                 max_horizon=8, schedule=(), replica_id=0,
                 prefix_cache=True, **cfg_kw):
    """A Scheduler wired to a FaultyDataPlane over a fresh vmem.

    Extra keyword arguments pass through to :class:`ServeConfig`
    (e.g. ``restore_patience`` / ``restore_scan_limit``)."""
    cfg = ServeConfig(page_size=page_size, num_pages=usable_pages + 1,
                      max_pages_per_seq=max_pages, max_batch=max_batch,
                      max_horizon=max_horizon, prefix_cache=prefix_cache,
                      **cfg_kw)
    vmem = VirtualMemory(VMemConfig(
        page_size=page_size, num_pages=usable_pages,
        max_pages_per_seq=max_pages, max_seqs=max_batch,
    ))
    sched = Scheduler(cfg, vmem, replica_id=replica_id)
    plane = FaultyDataPlane(vmem, schedule=schedule)
    plane.attach(sched)
    sched.attach_plane(plane)
    return sched, plane


def drive(sched, plane, max_steps=500):
    """``Engine.run`` restated on a scheduler + fault plane: tick the
    fault schedule, then run the canonical ``step_plane`` loop.  Returns
    the number of drive iterations (== engine steps dispatched)."""
    steps = 0
    while (sched.has_work or plane.has_pending_submits) and \
            sched.step_i < max_steps:
        steps += 1
        plane.tick(steps)
        sched.step_plane()
    return steps


def drive_router(router, planes, max_steps=500, submits=()):
    """``ReplicaRouter.run`` with per-replica fault schedules ticked in
    drive-loop time (before each router step, mirroring ``drive``).

    ``submits``: scripted late arrivals as ``(step, request)`` pairs,
    delivered through ``router.submit`` so placement accounting holds
    (plane-level ``submit`` events would bypass the router).
    """
    submits = sorted(submits, key=lambda e: e[0])
    steps = 0
    while (router.has_work or submits
           or any(p.has_pending_submits for p in planes)) and \
            steps < max_steps:
        steps += 1
        while submits and submits[0][0] <= steps:
            router.submit(submits.pop(0)[1])
        for plane in planes:
            plane.tick(steps)
        router.step()
    return steps
