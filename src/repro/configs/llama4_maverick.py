"""Llama-4-Maverick-400B-A17B — MoE 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, num_experts=128, experts_per_token=1,
    moe_d_ff=8192, moe_every=2, rope_theta=500_000.0,
)

REDUCED = ModelConfig(
    name="llama4-maverick-reduced", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128, head_dim=16,
    num_experts=8, experts_per_token=1, moe_d_ff=128, moe_every=2,
    param_dtype="float32",
)
