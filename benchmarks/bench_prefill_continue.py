"""Continuation-prefill before/after: gathered pages vs streamed pages.

``prefill_continue`` used to attend through a *gathered-pages* jnp path
that materializes the whole logical KV prefix (``max_pages x page_size``
tokens, per layer, per chunk) — the software equivalent of taking a TLB
miss on every page of the table whether or not it is live.  The Pallas
kernel (``kernels/paged_prefill_attention.py``) instead streams exactly
the pages each query block can see, translated through the scalar-
prefetched page table one burst at a time.

Reported per (start offset, chunk) point:

  * ``us_per_call`` — attention-step latency of each path.  On CPU the
    kernel runs in interpret mode (Python per grid step), so absolute
    kernel numbers are meaningless off-TPU; the BYTES column is the
    hardware-independent signal (paper C2: translations and bytes moved
    are what the TLB/MMU sees).
  * bytes gathered — ref: ``2 * B * maxT * Hkv * D * itemsize`` per call
    (K+V, the whole table reach); kernel: the analytical page count from
    ``pages_touched`` (exact: pages above the block diagonal are skipped
    by ``pl.when``) times the page burst size.

``run()`` returns ``(csv_lines, metrics)``; ``benchmarks/run.py --only
prefill`` exits nonzero unless the kernel path touches strictly fewer
bytes than the gather path (acceptance gate).
"""

from __future__ import annotations

import time

import numpy as np


def _time_call(fn, iters=3):
    fn()                                   # warm (compile / first trace)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters * 1e6


def run(hkv: int = 2, g: int = 2, d: int = 32, page: int = 16,
        max_pages: int = 16) -> tuple[list[str], dict]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.paged_prefill_attention import pages_touched

    key = jax.random.PRNGKey(0)
    n_frames = 2 * max_pages + 1
    max_t = max_pages * page
    itemsize = 4                           # fp32 pools
    k_pool = jax.random.normal(key, (n_frames, page, hkv, d))
    v_pool = jax.random.normal(jax.random.fold_in(key, 1), k_pool.shape)

    csv: list[str] = []
    total_ref_bytes = 0
    total_kernel_bytes = 0
    # (start, chunk): short continuation deep in the cache (the fork-admission
    # shape), chunk spanning a page boundary, and a near-empty cache
    cases = [(100, 32), (37, 16), (5, 8)]
    bq = 32
    for start, chunk in cases:
        b = 2
        starts = np.full((b,), start, np.int32)
        total = start + chunk
        need = -(-total // page)
        rng = np.random.default_rng(start)
        table = np.full((b, max_pages), -1, np.int32)
        for row in range(b):
            table[row, :need] = rng.permutation(n_frames)[:need]
        q = jax.random.normal(
            jax.random.fold_in(key, start), (b, chunk, hkv, g, d))
        tab = jnp.asarray(table)
        sts = jnp.asarray(starts)

        def gather():
            ops.paged_prefill_attention(
                q, k_pool, v_pool, tab, sts, page_size=page,
                use_kernel=False).block_until_ready()

        def kernel():
            ops.paged_prefill_attention(
                q, k_pool, v_pool, tab, sts, page_size=page,
                use_kernel=True, bq=bq).block_until_ready()

        us_ref = _time_call(gather)
        us_ker = _time_call(kernel)
        ref_bytes = 2 * b * max_t * hkv * d * itemsize
        ker_pages = b * pages_touched(start, chunk, max_pages,
                                      page_size=page, bq=bq)
        ker_bytes = 2 * ker_pages * page * hkv * d * itemsize
        total_ref_bytes += ref_bytes
        total_kernel_bytes += ker_bytes
        tag = f"s{start}_c{chunk}"
        print(f"start={start:4d} chunk={chunk:3d}: "
              f"gather {us_ref:9.1f} us / {ref_bytes:9d} B   "
              f"kernel {us_ker:9.1f} us / {ker_bytes:9d} B   "
              f"(bytes x{ref_bytes / ker_bytes:.2f} less)")
        csv.append(f"prefill_continue_gather_{tag},{us_ref:.1f},"
                   f"bytes={ref_bytes}")
        csv.append(f"prefill_continue_kernel_{tag},{us_ker:.1f},"
                   f"bytes={ker_bytes}")

    ratio = total_ref_bytes / total_kernel_bytes
    print(f"total bytes gathered: ref {total_ref_bytes} vs kernel "
          f"{total_kernel_bytes} ({ratio:.2f}x reduction)")
    csv.append(f"prefill_continue_bytes_reduction,0,{ratio:.3f}x")
    metrics = dict(ref_bytes=total_ref_bytes, kernel_bytes=total_kernel_bytes)
    return csv, metrics


def main() -> list[str]:
    return run()[0]


if __name__ == "__main__":
    main()
