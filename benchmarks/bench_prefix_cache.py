"""Radix prefix cache: multi-turn chat, warm (radix) vs cold admission.

The workload is the traffic shape the radix cache exists for: S chat
sessions, each T turns deep, all sharing one page-aligned system prefix.
Every turn's prompt is the FULL transcript so far (system prefix + each
user message + each model reply) — the stateless-API convention — so a
cold engine re-prefills the whole history every turn while the radix
engine COW-maps the matched leading pages and prefills only the divergent
chunk through the batched continuation-prefill dispatch.

Both engines preload the same system prefix (so pinned-pool pressure is
identical) and submit PLAIN requests — no ``share_prefix`` fork API — the
whole point being that page reuse falls out of token content alone.  Each
engine builds turn t+1's prompt from its OWN turn-t reply, so any stream
divergence compounds into prompt divergence and cannot cancel.

Reported (and gated by ``benchmarks/run.py --only prefix``):

  * token identity per (session, turn) vs the cold engine — the radix
    hit must produce exactly the state a full prefill would (causal KV
    content is a pure function of the token prefix), so greedy streams
    must match bit for bit;
  * ``skip_ratio`` = warm ``prefill_tokens_skipped`` / cold
    ``prefill_tokens`` — the gate requires > 0.5 on this workload
    (every turn skips at least the 96-token system prefix);
  * the reuse counters the trajectory tracks: ``prefix_hits``,
    ``pages_reused``, ``prefill_tokens_skipped`` (deterministic
    scheduler events — never wall tok/s).
"""

from __future__ import annotations

import time

import numpy as np

SESSIONS = 3
TURNS = 3
PREFIX_LEN = 96          # 12 whole pages at page_size=8
USER_LEN = 6
MAX_NEW = 4


def _chat(engine, cfg, rng_seed: int) -> dict[tuple[int, int], list[int]]:
    """Drive S sessions x T turns through ``engine``, each turn's prompt
    the session transcript so far, and return the per-turn streams."""
    rng = np.random.default_rng(rng_seed)
    from repro.serve import ServeRequest

    # identical user messages for every engine: the generator is seeded,
    # and replies are appended from the engine's OWN outputs
    user = {
        (s, t): rng.integers(0, cfg.vocab_size, size=USER_LEN)
        .astype(np.int32)
        for s in range(SESSIONS) for t in range(TURNS)
    }
    prefix = rng.integers(0, cfg.vocab_size, size=PREFIX_LEN).astype(np.int32)
    engine.preload_prefix(prefix)

    transcript = {s: [prefix] for s in range(SESSIONS)}
    streams: dict[tuple[int, int], list[int]] = {}
    req_id = 0
    for t in range(TURNS):
        for s in range(SESSIONS):
            transcript[s].append(user[(s, t)])
            prompt = np.concatenate(transcript[s])
            engine.submit(ServeRequest(req_id=req_id, prompt=prompt,
                                       max_new_tokens=MAX_NEW))
            done = engine.run()
            out = [int(x) for x in done[req_id].output]
            streams[(s, t)] = out
            transcript[s].append(np.asarray(out, np.int32))
            req_id += 1
    return streams


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    # one page-aligned system prefix + the deepest transcript must fit:
    # 96 + 3*(6+4) = 126 tokens = 16 pages at page_size 8
    mk = lambda radix: Engine(model, params, ServeConfig(
        page_size=8, num_pages=64, max_pages_per_seq=32, max_batch=3,
        prefix_cache=radix,
    ))

    t0 = time.perf_counter()
    cold_eng = mk(False)
    cold = _chat(cold_eng, cfg, rng_seed=7)
    wall_cold = time.perf_counter() - t0

    t0 = time.perf_counter()
    warm_eng = mk(True)
    warm = _chat(warm_eng, cfg, rng_seed=7)
    wall_warm = time.perf_counter() - t0

    token_identical = warm == cold
    c_warm, c_cold = warm_eng.counters, cold_eng.counters
    skipped = c_warm.get("prefill_tokens_skipped")
    cold_tokens = c_cold.get("prefill_tokens")
    skip_ratio = skipped / max(cold_tokens, 1)

    for (s, t) in sorted(warm):
        mark = "" if warm[(s, t)] == cold[(s, t)] else "   <-- DIVERGED"
        print(f"session {s} turn {t}: warm {warm[(s, t)]} "
              f"cold {cold[(s, t)]}{mark}")
    print(f"prefill tokens: cold engine committed {cold_tokens}, radix "
          f"engine skipped {skipped} of them (ratio {skip_ratio:.2f}) in "
          f"{c_warm.get('prefix_hits')} hits, "
          f"{c_warm.get('pages_reused')} pages reused")
    print(f"wall: cold {wall_cold:.1f}s, warm {wall_warm:.1f}s "
          "(CPU-interpret; counters are the signal)")

    metrics = {
        "token_identical": bool(token_identical),
        "skip_ratio": float(skip_ratio),
        "prefix_hits": int(c_warm.get("prefix_hits")),
        "pages_reused": int(c_warm.get("pages_reused")),
        "prefill_tokens_skipped": int(skipped),
        "prefill_tokens_cold": int(cold_tokens),
        "prefix_routed": 0,   # single engine: the router dimension is 0
    }
    csv = [
        f"prefix_token_identical,0,{int(token_identical)}",
        f"prefix_skip_ratio,0,{skip_ratio:.4f}",
        f"prefix_hits,0,{metrics['prefix_hits']}",
        f"prefix_pages_reused,0,{metrics['pages_reused']}",
        f"prefix_prefill_tokens_skipped,0,{skipped}",
    ]
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
