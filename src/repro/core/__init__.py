"""repro.core — the paper's contribution: paged virtual tensor memory.

Layers (DESIGN.md §3):
  vmem            page tables, frame allocator, translation, traces
  tlb             tree-PLRU TLB + shared-MMU trace simulator
  faults          precise page faults, vstart resume protocol
  context_switch  preemption spill/restore of vector state
  counters        perf counters + snapshot FIFO
  costmodel       AraOS cycle constants + TPU roofline constants
"""

from repro.core.context_switch import ContextSwitcher, SpilledState, SwitchStats
from repro.core.costmodel import CostModel
from repro.core.counters import PerfCounters
from repro.core.faults import OutOfPagesError, PageFault, ResumeCursor
from repro.core.tlb import (
    SCALAR,
    VECTOR,
    AccessEvent,
    OverheadReport,
    SharedMMUSimulator,
    TLB,
    interleave,
)
from repro.core.vmem import (
    INVALID_PAGE,
    PagePool,
    SeqState,
    VMemConfig,
    VirtualMemory,
    burst_trace,
    element_trace,
    gather_pages,
    logical_to_physical,
)

__all__ = [
    "AccessEvent",
    "ContextSwitcher",
    "CostModel",
    "INVALID_PAGE",
    "OutOfPagesError",
    "OverheadReport",
    "PageFault",
    "PagePool",
    "PerfCounters",
    "ResumeCursor",
    "SCALAR",
    "SeqState",
    "SharedMMUSimulator",
    "SpilledState",
    "SwitchStats",
    "TLB",
    "VECTOR",
    "VMemConfig",
    "VirtualMemory",
    "burst_trace",
    "element_trace",
    "gather_pages",
    "interleave",
    "logical_to_physical",
]
