#!/usr/bin/env bash
# Tier-1 verification without the multi-minute sharding subprocesses:
#   1. byte-compile the whole tree (catches syntax/indent errors fast);
#   2. import the package surface (catches broken module wiring);
#   3. run the kernel differential grid (which includes the int8
#      dequant-in-kernel grids — they carry both `kernels` and `quant`
#      marks), the `router` suite (multi-replica fault-injection harness,
#      fake planes — pure host policy, fail fast), the `prefix` suite
#      (radix prefix-cache properties, host-only planes), the non-kernel
#      `quant` suite (spill bit-identity, engine dispatch counters), then
#      the `fast` pytest subset;
#   4. serve gate (`benchmarks/run.py --only serve`) + router replica-
#      sweep gate (`--only router`: token identity vs N=1 + global-vs-
#      per-replica accounting) + prefix-cache gate (`--only prefix`:
#      >50% of cold prefill tokens skipped on the multi-turn chat
#      workload, streams token-identical to cold admission) + quant gate
#      (`--only quant`: int8 pools keep the kernels live with the
#      accuracy envelope held and bytes-per-page/spill bytes shrunk by
#      the itemsize ratio) + slo gate (`--only slo`: open-loop Poisson
#      arrivals vs the AOT-bucketed router — token identity vs the
#      closed-loop unbucketed reference, aot_misses == 0 after warmup)
#      + migrate gate (`--only migrate`: skewed heterogeneous fleet —
#      the reach-blind baseline must strand requests, migration +
#      partial restore must complete all of them token-identically with
#      restore_migrations > 0 / partial_restores > 0 and no leaked swap
#      records)
#      + the counter-based regression gate
#      (`scripts/bench_regress.py` over BENCH_serve.json, per section);
#   5. IF >1 host device is advertised: the sharded-kernel differential
#      subset first (fail fast if a shard_map wrapper diverges from the
#      single-device kernel / jnp oracle), then the full `sharded` pytest
#      subset (including the router-over-sharded-executors tests) and the
#      sharded-executor gate (kernels LIVE on the mesh: token identity,
#      ref_path_dispatches == 0, strict prefill bytes-gathered win vs the
#      jnp ref-path baseline).
# The full gate (including sharding dry-runs) stays:
#   PYTHONPATH=src python -m pytest -q
#
# Running under CI / forcing host devices:
#   This script is what the CI `fast` job runs verbatim (see
#   .github/workflows/ci.yml; PYTHONPATH=src is set once at the workflow
#   level, and exporting it below keeps local runs identical).  The
#   `multidevice` job additionally sets
#       XLA_FLAGS=--xla_force_host_platform_device_count=8
#   which makes one CPU process present 8 XLA host devices — enough to lay
#   the executor's KV pools out over a real ('kv','hd') serve mesh with
#   cross-device collectives, with no accelerator anywhere.  Stage 5 below
#   keys off that flag, so plain single-device local runs stay fast and a
#   flagged run (local or CI) gets the sharded coverage automatically.
#   Reproduce the CI multidevice job locally with:
#       XLA_FLAGS=--xla_force_host_platform_device_count=8 scripts/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== byte-compile"
python -m compileall -q src benchmarks examples tests scripts

echo "== import surface"
python - <<'PY'
import repro.core, repro.kernels.ops, repro.models, repro.serve
import repro.launch.sharding, repro.launch.mesh
print("imports OK")
PY

echo "== kernel differential grids (fail fast on kernel regressions)"
python -m pytest -q -m kernels "$@"

echo "== router suite (multi-replica fault-injection harness, fake planes)"
python -m pytest -q -m "router and not sharded" "$@"

echo "== prefix-cache property suite (radix sharing, host-only planes)"
python -m pytest -q -m "prefix and not sharded" "$@"

echo "== quant suite (int8 KV differentials + spill bit-identity)"
python -m pytest -q -m "quant and not sharded and not kernels" "$@"

echo "== slo suite (AOT buckets, async detokenize, open-loop determinism)"
python -m pytest -q -m "slo and not sharded" "$@"

echo "== fast tests"
python -m pytest -q -m "fast and not kernels and not sharded and not router and not prefix and not quant and not slo" "$@"

echo "== serve gate (fused decode horizon must amortize host syncs)"
python -m benchmarks.run --only serve

echo "== router replica-sweep gate (token identity vs N=1 + accounting)"
python -m benchmarks.run --only router

echo "== prefix-cache gate (>50% prefill skipped, token-identical to cold)"
python -m benchmarks.run --only prefix

echo "== quant gate (int8 pools: kernels live, accuracy envelope, bytes halved)"
python -m benchmarks.run --only quant

echo "== slo gate (open-loop Poisson: token identity, aot_misses == 0)"
python -m benchmarks.run --only slo

echo "== migrate gate (swap migration + partial restore: nothing strands)"
python -m benchmarks.run --only migrate

echo "== serve counter regression gate (BENCH_serve.json trajectory)"
python scripts/bench_regress.py

# sharded stage: only when this environment actually presents >1 XLA
# device (forced host devices via XLA_FLAGS, or real accelerators) —
# single-device runs skip it fast.  The probe is a subprocess so the jax
# device count it locks in dies with it.
ndev=$(python - <<'PY'
import jax
print(jax.device_count())
PY
)
if [ "$ndev" -gt 1 ]; then
  echo "== sharded kernel differentials ($ndev XLA devices; fail fast)"
  python -m pytest -q -x -m "sharded and kernels" "$@"
  echo "== sharded serving tests"
  python -m pytest -q -m "sharded and not kernels" "$@"
  echo "== sharded executor gate (kernels live on the mesh)"
  python -m benchmarks.run --only sharded
else
  echo "== sharded stage skipped (single host device; set XLA_FLAGS=--xla_force_host_platform_device_count=8)"
fi
