"""C2 reproduction: burst vs per-element translation (+ the coalescing fix).

AraOS translates unit-stride vector accesses once per page-bounded AXI
burst but indexed accesses once per ELEMENT (precise exceptions) — the
reason spmv/canneal lose to scalar code (§3.2).  This benchmark measures
the translation counts of our actual paged kernels on real access streams,
models the cycle cost, and quantifies the beyond-paper sort-coalescing
optimization (`ops.paged_gather_coalesced`): per-PAGE translation for
indexed reads at the cost of a sort.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CostModel,
    SharedMMUSimulator,
    VMemConfig,
    VirtualMemory,
    burst_trace,
    element_trace,
)
from repro.core.tlb import VECTOR, AccessEvent
from repro.kernels import ops

PAGE = 16
TOKENS = 4096


def main() -> list[str]:
    cost = CostModel()
    vm = VirtualMemory(VMemConfig(
        page_size=PAGE, num_pages=TOKENS // PAGE + 8,
        max_pages_per_seq=TOKENS // PAGE + 4, max_seqs=1,
    ))
    vm.map_seq(0, TOKENS)
    pool = jax.random.normal(jax.random.PRNGKey(0),
                             (TOKENS // PAGE + 8, PAGE, 8))
    row = vm.device_page_table()[0]
    rng = np.random.default_rng(0)
    lines = []

    streams = {
        "unit_stride": np.arange(TOKENS),
        "strided_4": np.arange(0, TOKENS, 4),
        "random": rng.integers(0, TOKENS, size=TOKENS),
        "sorted_random": np.sort(rng.integers(0, TOKENS, size=TOKENS)),
    }
    print(f"{'stream':14s} {'burst tx':>9s} {'element tx':>11s} "
          f"{'coalesced tx':>13s} {'elem/burst':>11s}")
    for name, pos in streams.items():
        bursts = burst_trace(pos, PAGE)
        elems = element_trace(pos, PAGE)
        coalesced = burst_trace(np.sort(pos), PAGE)
        print(f"{name:14s} {bursts.size:9d} {elems.size:11d} "
              f"{coalesced.size:13d} {elems.size / bursts.size:11.1f}")
        # modeled visible stall through a 16-entry shared TLB
        for label, tr in (("burst", bursts), ("element", elems),
                          ("coalesced", coalesced)):
            sim = SharedMMUSimulator(16, cost)
            rep = sim.run([AccessEvent(VECTOR, int(v), slack=4.0)
                           for v in tr])
            lines.append(
                f"translation_{name}_{label},0,"
                f"tx={tr.size} stall={rep.total_cycles:.0f}cyc"
            )

    # functional check + wall time of the three gather paths
    pos = jnp.asarray(rng.integers(0, TOKENS, size=512), jnp.int32)
    for label, fn in (
        ("per_element", lambda: ops.paged_gather(
            pool, row, pos, page_size=PAGE)),
        ("coalesced", lambda: ops.paged_gather_coalesced(
            pool, row, pos, page_size=PAGE)),
        ("xla_ref", lambda: ops.paged_gather(
            pool, row, pos, page_size=PAGE, use_kernel=False)),
    ):
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        lines.append(f"gather_{label},{dt*1e6:.0f},n=512")
    print("\ncoalescing: indexed streams translate per page after a sort —")
    sorted_tx = burst_trace(np.sort(streams["random"]), PAGE).size
    print(f"  random 4096-element gather: {TOKENS} -> {sorted_tx} "
          f"translations ({TOKENS / sorted_tx:.0f}x fewer)")
    return lines


if __name__ == "__main__":
    main()
