"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
device query.
"""

from __future__ import annotations

import jax


def compat_make_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` across JAX versions.

    Newer JAX (>= 0.6) wants explicit ``axis_types``; 0.4.x has neither the
    kwarg nor ``jax.sharding.AxisType``.  Auto axes are the 0.4.x default,
    so falling back to the bare call is semantically identical.
    """
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def use_mesh(mesh: jax.sharding.Mesh):
    """Context manager setting the ambient mesh across JAX versions.

    ``jax.set_mesh`` (>= 0.6) or the Mesh's own context manager (0.4.x).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh  # 0.4.x: Mesh is its own context manager


def _mk(shape, axes) -> jax.sharding.Mesh:
    return compat_make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over the locally available devices (tests, examples)."""
    return _mk((data, model), ("data", "model"))


def make_serve_mesh(
    num_kv_heads: int, head_dim: int, *, multi_pod: bool = False,
    model_size: int = 16,
) -> jax.sharding.Mesh:
    """Serving mesh: the production topology with the model axis viewed as
    a 2-D ('kv', 'hd') tile.

    Same devices, same order, same physical 16x16(x2) topology as
    ``make_production_mesh`` — only the *logical* factorization of the
    model axis changes, so KV pools can shard jointly over KV heads and
    head_dim without GSPMD's "involuntary full rematerialization" (it
    cannot reshard a 1-D hd-sharding into the (kv x hd) tiling attention
    needs; see EXPERIMENTS.md §Perf iteration 1).
    """
    kv = 1
    for cand in (16, 8, 4, 2, 1):
        if cand <= model_size and num_kv_heads % cand == 0:
            kv = cand
            break
    hd = model_size // kv
    if head_dim % hd != 0:  # degrade: replicate the remainder onto kv
        kv, hd = 1, model_size
        if head_dim % hd != 0:
            raise ValueError(
                f"cannot factor model axis for Hkv={num_kv_heads}, "
                f"head_dim={head_dim}"
            )
    shape = (2, 16, kv, hd) if multi_pod else (16, kv, hd)
    axes = (("pod",) if multi_pod else ()) + ("data", "kv", "hd")
    return _mk(shape, axes)


def make_host_serve_mesh(
    num_kv_heads: int, head_dim: int, num_devices: int | None = None,
) -> jax.sharding.Mesh:
    """('kv', 'hd') serving mesh over the *locally visible* devices.

    The executor-facing dual of :func:`make_serve_mesh`: same logical
    factorization of the model axis into a 2-D (kv x hd) tile, but sized
    to whatever this process can see — 8 forced host devices under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI, a TPU
    slice in production, 1 CPU device in plain local runs (a 1x1 mesh:
    the sharded code path with replicated layouts).

    Picks the factorization using the MOST devices such that ``kv``
    divides ``num_kv_heads`` and ``hd`` divides ``head_dim`` (ties prefer
    the kv axis — head-parallel attention needs no cross-device
    reductions, so it tracks the single-device float stream closest);
    devices beyond ``kv * hd`` are simply left out of the mesh.
    ``num_devices`` caps the search (clamped to what is visible).
    """
    visible = len(jax.devices())
    n = min(num_devices, visible) if num_devices is not None else visible
    if n < 1:
        raise ValueError("need at least one device")
    best: tuple[int, int] | None = None
    for size in range(n, 0, -1):
        for kv in range(min(size, num_kv_heads), 0, -1):
            if size % kv or num_kv_heads % kv:
                continue
            hd = size // kv
            if head_dim % hd == 0:
                best = (kv, hd)
                break
        if best is not None:
            break
    kv, hd = best  # (1, 1) always factors, so best is never None
    return _mk((kv, hd), ("kv", "hd"))


def kv_partition_axes(
    mesh: jax.sharding.Mesh, num_kv_heads: int, head_dim: int,
) -> tuple[str | None, str | None]:
    """Per-dim mesh axes ``(kv_axis, hd_axis)`` for KV-shaped operands.

    THE single source of truth for how (Hkv, head_dim) dims map onto a
    ('kv', 'hd') serve mesh: an axis is used only when it exists on the
    mesh AND its extent divides the dim; otherwise that dim degrades to
    replicated (``None``).  ``launch.specs.executor_state_shardings``
    (the executor's persistent-state layout) and the shard_map kernel
    dispatch wrappers in ``kernels.ops`` both derive their specs from
    this, so the per-device pool slice a Pallas kernel sees is by
    construction the same slice the executor committed.
    """
    def ok(dim: int, ax: str) -> str | None:
        if ax not in mesh.axis_names or dim % mesh.shape[ax]:
            return None
        return ax

    return ok(num_kv_heads, "kv"), ok(head_dim, "hd")


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh: ('pod', 'data') or ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axis(mesh: jax.sharding.Mesh) -> str | None:
    """Axis used for parameter sharding (FSDP): intra-pod 'data' only —
    cross-pod parameter all-gathers would traverse DCI every layer."""
    return "data" if "data" in mesh.axis_names else None
