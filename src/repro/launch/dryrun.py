import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init) — they give this process 512 placeholder CPU devices
# so the production meshes can be built.  Only the dry-run gets this flag.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build ShapeDtypeStruct inputs (no allocation) and the jitted step
    function with production shardings (launch/specs.py);
  * ``.lower().compile()`` on the 16x16 single-pod mesh and the 2x16x16
    multi-pod mesh;
  * record ``memory_analysis()`` (proves it fits), ``cost_analysis()``
    (FLOPs/bytes for the roofline) and the collective traffic parsed from
    the post-SPMD HLO;
  * append the result to ``experiments/dryrun/<cell>.json`` — incremental:
    finished cells are skipped on re-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.launch import hlo_analysis, hlo_cost
from repro.launch.mesh import make_production_mesh, make_serve_mesh, use_mesh
from repro.launch.specs import build_case, skip_reason
from repro.models.config import SHAPES

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def cell_path(arch: str, shape: str, mesh_name: str, serve_mode: str,
              variant: str | None = None) -> str:
    suffix = "" if serve_mode == "2d" else f"__{serve_mode}"
    if variant:
        suffix += f"__{variant}"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}{suffix}.json")


def _mesh_for(arch: str, shape: str, multi_pod: bool, serve_mode: str):
    if SHAPES[shape].kind == "train" or serve_mode == "flat":
        return make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if cfg.family == "rwkv6":
        kv, hd = cfg.num_rwkv_heads, cfg.rwkv_head_size
    else:
        kv, hd = cfg.num_kv_heads, cfg.head_dim
    return make_serve_mesh(kv, hd, multi_pod=multi_pod)


def run_cell(arch: str, shape: str, multi_pod: bool, force: bool = False,
             serve_mode: str = "2d", variant: str | None = None) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    path = cell_path(arch, shape, mesh_name, serve_mode, variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    reason = skip_reason(arch, shape)
    result: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "serve_mode": serve_mode, "variant": variant,
        "chips": 512 if multi_pod else 256,
    }
    if reason:
        result["status"] = "skipped"
        result["reason"] = reason
        _write(path, result)
        return result
    mesh = _mesh_for(arch, shape, multi_pod, serve_mode)
    t0 = time.time()
    try:
        with use_mesh(mesh):
            case = build_case(arch, shape, mesh, serve_mode, variant)
            lowered = case.fn.lower(*case.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo_text = compiled.as_text()
            # loop-aware per-device costs (XLA's cost_analysis counts scan
            # bodies once; this multiplies known_trip_count)
            la = hlo_cost.analyze(hlo_text)
            coll = hlo_analysis.CollectiveStats(
                counts={k: int(v) for k, v in
                        la["collective_counts"].items()},
                bytes_by_kind={k: int(v) for k, v in
                               la["collective_bytes_by_kind"].items()},
            )
            terms = hlo_analysis.roofline(
                {"flops": la["flops"], "bytes accessed": la["bytes"]},
                coll, chips=mesh.size,
                model_flops=case.model_flops_per_step,
            )
        result.update({
            "status": "ok",
            "kind": case.kind,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None
                ),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
            "collectives": {
                "counts": coll.counts,
                "bytes_by_kind": coll.bytes_by_kind,
                "total_bytes_per_device": coll.total_bytes,
            },
            "scopes": {
                "bytes": la["bytes_by_scope"],
                "flops": la["flops_by_scope"],
            },
            "roofline": terms.to_dict(),
        })
    except Exception as e:  # a failing cell is a bug — record it loudly
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _write(path, result)
    return result


def _write(path: str, result: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)


def summarize(results: list[dict]) -> None:
    print(f"\n{'cell':52s} {'status':8s} {'dom':10s} "
          f"{'bound':>9s} {'MFU@roof':>8s} {'mem/chip':>9s}")
    for r in results:
        cell = f"{r['arch']}x{r['shape']}x{r['mesh']}"
        if r["status"] != "ok":
            print(f"{cell:52s} {r['status']:8s} {r.get('reason', r.get('error', ''))[:60]}")
            continue
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        mem = (r["memory"]["argument_bytes"] or 0) + (
            r["memory"]["temp_bytes"] or 0
        )
        print(f"{cell:52s} {r['status']:8s} {t['dominant']:10s} "
              f"{hlo_analysis.fmt_seconds(bound):>9s} "
              f"{t['roofline_fraction']:8.2%} {mem/2**30:8.2f}G")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--serve-mode", choices=("2d", "flat"), default="2d",
                    help="flat = baseline 1-D model axis for serve cells")
    ap.add_argument("--variant", default=None,
                    help="perf-iteration variant (see specs.VARIANTS)")
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for multi in meshes:
        for arch, shape in cells:
            r = run_cell(arch, shape, multi, force=args.force,
                         serve_mode=args.serve_mode, variant=args.variant)
            results.append(r)
            status = r["status"]
            extra = ""
            if status == "ok":
                extra = (f"compile={r['compile_s']}s "
                         f"dom={r['roofline']['dominant']}")
            elif status == "error":
                extra = r["error"][:100]
            print(f"[{status:7s}] {arch} x {shape} x {r['mesh']} {extra}",
                  flush=True)
    summarize(results)


if __name__ == "__main__":
    main()
