"""Table 1 reproduction: RiVEC suite, scalar vs vector vs vector-unordered.

The kernels compute real results in vectorized JAX (``rivec_kernels``);
speedups come from an Ara2 cycle model driven by each kernel's architectural
Work record.  Model constants (documented, calibrated once against the
paper's axpy/blackscholes rows, NOT per-cell):

  scalar: 1 element-op/cycle FPU + 4 cycles/element loop+load/store
          overhead (ld/ld/op/st/addi/bne), transcendental-heavy kernels pay
          ``scalar_flop_penalty``;
  vector (2-lane Ara2): 4 element-ops/cycle, 6-cycle issue overhead per
          vector instruction (dominant for short vectors — canneal),
          8 B/cycle memory floor;
  ordered reductions: vl cycles vs vl/4 + log2(vl) unordered (the V vs Vu
          columns);
  indexed accesses: +2 cycles/element visible translation latency
          (per-element MMU requests, paper §3.2 — spmv/canneal/lavaMD);
  reshuffles: 48 cycles each, unchainable (canneal's EW pathology).

Expected qualitative agreement with the paper: blackscholes highest (~8x),
axpy/jacobi/somier 3.4-4.3x, canneal < 1x, spmv lowest positive and rising
with size, geomean ~2.7-3.2x.
"""

from __future__ import annotations

import math
import time

import jax

from benchmarks.rivec_kernels import KERNELS, SIZES, Work

SCALAR_OVERHEAD_CPE = 4.0
SCALAR_FLOP_PENALTY = {"blackscholes": 1.7, "swaptions": 1.4}
VPU_THROUGHPUT = 4.0          # element-ops / cycle (2 lanes)
ISSUE_OVERHEAD = 6.0          # cycles / vector instruction
MEM_BYTES_PER_CYCLE = 8.0
BYTES_PER_ELEM = 12.0         # 2 loads + 1 store, f32
INDEXED_CPE = 2.0             # visible per-element translation latency
RESHUFFLE_CYCLES = 48.0


def scalar_cycles(name: str, w: Work) -> float:
    pen = SCALAR_FLOP_PENALTY.get(name, 1.0)
    return w.elems * (w.flops_per_elem * pen + SCALAR_OVERHEAD_CPE) + \
        w.scalar_ops


def vector_cycles(name: str, w: Work, unordered: bool) -> float:
    vl = max(w.avg_vl, 1.0)
    n_instr = w.elems * w.flops_per_elem / vl
    compute = w.elems * w.flops_per_elem / VPU_THROUGHPUT
    mem_floor = (w.elems * BYTES_PER_ELEM
                 / max(w.flops_per_elem, 1.0) ** 0.5) / MEM_BYTES_PER_CYCLE
    cycles = max(compute, mem_floor) + n_instr * ISSUE_OVERHEAD
    n_red = w.ordered_red_elems / vl
    if unordered:
        cycles += n_red * (vl / VPU_THROUGHPUT / vl + math.log2(max(vl, 2)))
    else:
        cycles += n_red * vl  # ordered: element-serial
    cycles += w.indexed_elems * INDEXED_CPE
    cycles += w.reshuffles * RESHUFFLE_CYCLES
    # Amdahl: the fraction of the scalar program that never vectorizes
    cycles += w.serial_frac * scalar_cycles(name, w)
    return cycles


def run_table() -> list[dict]:
    rows = []
    for name, fn in KERNELS.items():
        row = {"kernel": name}
        for size in SIZES:
            t0 = time.perf_counter()
            out, w = fn(size)
            jax.block_until_ready(out)
            wall = time.perf_counter() - t0
            s = scalar_cycles(name, w)
            v = vector_cycles(name, w, unordered=False)
            vu = vector_cycles(name, w, unordered=True)
            row[size] = {
                "S_cycles": s,
                "V_speedup": s / v,
                "Vu_speedup": s / vu,
                "wall_s": wall,
            }
        rows.append(row)
    return rows


def geomean(xs):
    return math.exp(sum(math.log(max(x, 1e-9)) for x in xs) / len(xs))


def main() -> list[str]:
    rows = run_table()
    lines = []
    hdr = f"{'kernel':16s}" + "".join(
        f" | {s:>7s} V/Vu" for s in SIZES
    )
    print(hdr)
    print("-" * len(hdr))
    for row in rows:
        cells = "".join(
            f" | {row[s]['V_speedup']:5.2f}/{row[s]['Vu_speedup']:5.2f}"
            for s in SIZES
        )
        print(f"{row['kernel']:16s}{cells}")
        for s in SIZES:
            lines.append(
                f"rivec_{row['kernel']}_{s},"
                f"{row[s]['wall_s'] * 1e6:.0f},"
                f"V={row[s]['V_speedup']:.2f}x Vu={row[s]['Vu_speedup']:.2f}x"
            )
    for s in SIZES:
        gm = geomean([r[s]["V_speedup"] for r in rows])
        gmu = geomean([r[s]["Vu_speedup"] for r in rows])
        print(f"{'geomean ' + s:>24s}: V {gm:.2f}x  Vu {gmu:.2f}x "
              f"(paper: 2.7-3.2x)")
        lines.append(f"rivec_geomean_{s},0,V={gm:.2f}x Vu={gmu:.2f}x")
    return lines


if __name__ == "__main__":
    main()
