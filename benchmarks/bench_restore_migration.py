"""Restore migration + partial restore: no request fails while any
replica can host it.

Two phases, both on real engines (qwen2-7b reduced), both gated:

**Phase 1 — skewed two-replica fleet.**  A heterogeneous pool pair (a
tight 8-page replica next to a roomy 64-page one) takes a mixed load:
"long" requests whose lifetime footprint exceeds the small replica's
attainable pages, interleaved with "short" requests that fit it but
overlap enough to preempt each other.  The ``migrate=False`` baseline is
reach-blind, so least-loaded placement feeds a long request to the small
replica where admission must fail it (``failed_unreachable > 0``) — the
stranding the tentpole exists to kill.  The migrating run must:

  * redirect every unreachable placement to the roomy replica
    (``reach_redirects > 0``) and fail NOTHING
    (``failed_unreachable == 0``);
  * move at least one capacity-starved swap victim off the contended
    small replica through the portable-swap path
    (``restore_migrations > 0`` — export at the source, import + restore
    + decode on the destination's pool, real KV bytes);
  * stay per-request token-identical to a single roomy-replica reference
    (migration is a timing policy, never a token policy);
  * leave no swap record behind on either ``ContextSwitcher`` at drain
    (the leak-audit satellite, on real planes).

**Phase 2 — partial restore on one tight replica.**  Two requests whose
pools overlap by exactly one page fault force a preemption; the runner
then sits at its lifetime maximum, so the victim's full restore can
never fit while it lives.  With ``restore_patience`` armed the scheduler
restores the longest page-aligned prefix that fits and re-prefills only
the evicted tail through the continuation path — the run must show
``partial_restores > 0`` / ``pages_refilled > 0`` with NO full restore
wait, stay token-identical to the roomy reference, and again hold the
empty-switcher leak audit.

``benchmarks/run.py --only migrate`` gates on all of the above and
appends the metrics to ``BENCH_serve.json`` (section ``migrate``);
``scripts/bench_regress.py`` holds ``failed_unreachable`` /
``restore_migrations`` / ``partial_restores`` across PRs — counters
only, never wall-clock.
"""

from __future__ import annotations

import copy

import numpy as np


def _mixed_load(cfg):
    """Long requests (lifetime 8 pages — over the small replica's 7) and
    short ones (6 pages — admit on the small replica but preempt each
    other), submission-ordered so the FIRST placement is a long request:
    least-loaded tie-breaking sends it to replica 0 (the small pool),
    which is exactly the reach-blind stranding the baseline must show."""
    from repro.serve import ServeRequest

    rng = np.random.default_rng(23)

    def sreq(i, plen, max_new):
        return ServeRequest(
            req_id=i,
            prompt=rng.integers(0, cfg.vocab_size, size=plen
                                ).astype(np.int32),
            max_new_tokens=max_new)

    return [
        sreq(0, plen=24, max_new=8),    # long: pf(32) = 8 pages
        sreq(1, plen=10, max_new=12),   # short: pf(22) = 6 pages
        sreq(2, plen=24, max_new=8),    # long
        sreq(3, plen=10, max_new=12),   # short
        sreq(4, plen=10, max_new=12),   # short
    ]


def _outputs(done):
    return {i: [int(x) for x in done[i].output] for i in done}


def run() -> tuple[list[str], dict]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ReplicaRouter, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    # max_horizon=1 on the contended replicas keeps preemption points
    # page-granular (a fused horizon would batch right past the faults
    # this scenario exists to hit); the reference shares it so spill
    # timing differences are the ONLY variable under test
    big_cfg = ServeConfig(page_size=4, num_pages=64, max_pages_per_seq=32,
                          max_batch=4, max_horizon=1)
    small_cfg = ServeConfig(page_size=4, num_pages=8, max_pages_per_seq=8,
                            max_batch=3, max_horizon=1)
    reqs = _mixed_load(cfg)

    # ---- roomy single-replica reference: the token oracle -------------
    ref = Engine(model, params, big_cfg)
    for r in reqs:
        ref.submit(copy.deepcopy(r))
    ref_done = ref.run()
    ref_out = _outputs(ref_done)
    assert all(r.status == "done" for r in ref_done.values())

    def fleet(migrate):
        small = Engine(model, params, small_cfg)
        big = Engine(model, params, big_cfg)
        router = ReplicaRouter([small.as_replica(0), big.as_replica(1)],
                               migrate=migrate, migrate_after=3)
        for r in reqs:
            router.submit(copy.deepcopy(r))
        done = router.run()
        return router, (small, big), done

    # ---- baseline: reach-blind, no migration — must strand -------------
    base_router, base_engines, base_done = base = fleet(migrate=False)
    base_total = base_router.global_counters()
    base_failed = int(base_total["failed_unreachable"])
    base_done_ok = sum(r.status == "done" for r in base_done.values())
    print(f"baseline (migrate=False): {base_failed} failed unreachable, "
          f"{base_done_ok}/{len(reqs)} done")

    # ---- migrating fleet: nothing may fail, tokens must match ----------
    mig_router, mig_engines, mig_done = fleet(migrate=True)
    total = mig_router.global_counters()
    mig_failed = int(total["failed_unreachable"])
    token_identical = (
        _outputs(mig_done) == ref_out
        and all(r.status == "done" for r in mig_done.values())
    )
    accounting_ok = True
    try:
        mig_router.check_invariants()
        base_router.check_invariants()
    except AssertionError as e:
        accounting_ok = False
        print(f"FAIL (accounting): {e}")
    swap_leaks = sum(
        len(eng.switcher.swapped_out)
        for eng in (*base_engines, *mig_engines)
    )
    print(f"migrating fleet: {mig_failed} failed unreachable, "
          f"{int(total['restore_migrations'])} restore migrations "
          f"({int(total['swap_exports'])} exports / "
          f"{int(total['swap_imports'])} imports, "
          f"{int(total['migration_aborts'])} aborts), "
          f"{int(mig_router.counters.get('reach_redirects'))} reach "
          f"redirects, token-identical {token_identical}, "
          f"{swap_leaks} leaked swap records")

    # ---- phase 2: partial restore on one tight replica -----------------
    # P0 (4 pages at admit, 5 lifetime) + P1 (3 pages at admit, 5
    # lifetime) fill the 7 usable pages exactly; the first growth fault
    # preempts one of them, the survivor parks at its 5-page lifetime
    # maximum, and the victim's full restore (4-5 pages) can never fit
    # the 2 remaining frames while it lives — only a partial restore
    # (patience 2) brings it back before the pool drains
    rng = np.random.default_rng(31)
    part_cfg = ServeConfig(page_size=4, num_pages=8, max_pages_per_seq=8,
                           max_batch=3, max_horizon=1, restore_patience=2)
    from repro.serve import ServeRequest
    part_reqs = [
        ServeRequest(req_id=0,
                     prompt=rng.integers(0, cfg.vocab_size, size=16
                                         ).astype(np.int32),
                     max_new_tokens=4),
        ServeRequest(req_id=1,
                     prompt=rng.integers(0, cfg.vocab_size, size=12
                                         ).astype(np.int32),
                     max_new_tokens=8),
    ]
    part_ref = Engine(model, params, big_cfg)
    for r in part_reqs:
        part_ref.submit(copy.deepcopy(r))
    part_ref_out = _outputs(part_ref.run())

    part_eng = Engine(model, params, part_cfg)
    for r in part_reqs:
        part_eng.submit(copy.deepcopy(r))
    part_done = part_eng.run()
    pc = part_eng.counters
    partial_restores = int(pc.get("partial_restores"))
    pages_refilled = int(pc.get("pages_refilled"))
    part_identical = (
        _outputs(part_done) == part_ref_out
        and all(r.status == "done" for r in part_done.values())
    )
    part_leaks = len(part_eng.switcher.swapped_out)
    swap_leaks += part_leaks
    print(f"partial restore: {partial_restores} partial restores, "
          f"{pages_refilled} pages refilled, "
          f"{int(pc.get('restores'))} full restores, "
          f"token-identical {part_identical}, "
          f"{part_leaks} leaked swap records")

    metrics = {
        "token_identical": bool(token_identical),
        "partial_token_identical": bool(part_identical),
        "accounting_identical": bool(accounting_ok),
        "failed_unreachable_baseline": base_failed,
        "failed_unreachable_migrate": mig_failed,
        "restore_migrations": int(total["restore_migrations"]),
        "migration_aborts": int(total["migration_aborts"]),
        "swap_exports": int(total["swap_exports"]),
        "swap_imports": int(total["swap_imports"]),
        "reach_redirects": int(mig_router.counters.get("reach_redirects")),
        "second_chance_restores": int(total["second_chance_restores"]),
        "partial_restores": partial_restores,
        "pages_refilled": pages_refilled,
        "swap_record_leaks": int(swap_leaks),
    }
    csv = [
        f"migrate_token_identical,0,{int(token_identical)}",
        f"migrate_partial_token_identical,0,{int(part_identical)}",
        f"migrate_failed_unreachable_baseline,0,{base_failed}",
        f"migrate_failed_unreachable,0,{mig_failed}",
        f"migrate_restore_migrations,0,{metrics['restore_migrations']}",
        f"migrate_reach_redirects,0,{metrics['reach_redirects']}",
        f"migrate_partial_restores,0,{partial_restores}",
        f"migrate_pages_refilled,0,{pages_refilled}",
        f"migrate_swap_record_leaks,0,{swap_leaks}",
    ]
    del base  # keep the baseline alive through the leak audit above
    return csv, metrics


def main() -> list[str]:
    csv, _ = run()
    return csv


if __name__ == "__main__":
    main()
