"""HLO analysis: collective-traffic parsing + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but not collective
traffic, so we parse the *post-SPMD* optimized HLO (``compiled.as_text()``)
and sum the output-tensor bytes of every collective op.  Convention: bytes
counted are the bytes **received per device** (all-gather: gathered size;
all-reduce: full tensor; reduce-scatter / all-to-all / collective-permute:
output size).  Ring algorithms move ~2x for all-reduce; the roofline reports
note this convention.
"""

from __future__ import annotations

import dataclasses
import math
import re

from repro.core.costmodel import (
    TPU_HBM_BW,
    TPU_ICI_BW_PER_LINK,
    TPU_PEAK_FLOPS_BF16,
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# an HLO instruction line: `%name = <shapes> <opcode>(...)`
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[^\s]+))\s+("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)


def shape_bytes(text: str) -> int:
    """Sum the bytes of every dtype[dims] shape literal in `text`."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic from post-partitioning HLO text."""
    counts: dict[str, int] = {}
    by_kind: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        shapes, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # avoid double counting async start/done pairs
        b = shape_bytes(shapes)
        counts[kind] = counts.get(kind, 0) + 1
        by_kind[kind] = by_kind.get(kind, 0) + b
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind)


@dataclasses.dataclass
class RooflineTerms:
    """Per-device roofline terms, seconds (v5e constants)."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # per device
    model_flops: float          # global 6·N·D (or 2·N·D serve)
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else float("nan")

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the roofline: model-flops time / bound time."""
        ideal = self.model_flops / (self.chips * TPU_PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s else float("nan")

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def roofline(
    cost: dict,
    coll: CollectiveStats,
    *,
    chips: int,
    model_flops: float,
) -> RooflineTerms:
    """cost: ``compiled.cost_analysis()`` of the per-device partitioned
    module (flops/bytes are already per device)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / TPU_PEAK_FLOPS_BF16,
        memory_s=byts / TPU_HBM_BW,
        collective_s=coll.total_bytes / TPU_ICI_BW_PER_LINK,
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=float(coll.total_bytes),
        model_flops=model_flops,
        chips=chips,
    )


def fmt_seconds(s: float) -> str:
    if s == 0 or math.isnan(s):
        return "0"
    for unit, scale in (("s", 1.0), ("ms", 1e-3), ("us", 1e-6)):
        if s >= scale:
            return f"{s / scale:.3g}{unit}"
    return f"{s:.2e}s"
