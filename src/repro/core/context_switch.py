"""Context switches: saving/restoring vector state under preemption.

AraOS §3.1: a context switch between two vector processes saves and restores
the vector state (VRF + vector CSRs) at memory bandwidth — ~3.2 k cycles for
an 8-KiB VRF over a 64-bit/cycle path (vs ~1 k cycles scalar-only).

Serving analogue: when the page pool is exhausted (OutOfPagesError) or the
scheduler quantum expires, a victim request is *preempted*: its vector state
(KV pages / recurrent-state slab + sampler state + resume cursor) is spilled
to a host-side swap area, its frames are freed, and it is re-mapped and
restored later.  The cost is measured in real bytes moved and reported in
modeled AraOS cycles so the §3.1 comparison is direct.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CostModel
from repro.core.vmem import VirtualMemory


@dataclasses.dataclass
class SpilledState:
    """Swap-area record for one preempted request.

    The record is **portable**: ``page_data`` is pure host memory in the
    pool's storage dtype (int8 pools spill narrow bytes and stay narrow
    here), and nothing in it references the pool that spilled it — so a
    record exported from one replica's switcher (:meth:`ContextSwitcher.
    export_swap`) can be imported into another's (:meth:`ContextSwitcher.
    import_swap`) and restored there, provided the destination shares the
    page geometry.  Cross-replica migration of a starved swap victim is
    exactly that move.
    """

    seq_id: int
    num_tokens: int
    page_data: np.ndarray            # [n_pages, ...] copied out of the pool
    extra_state: Any = None          # sampler state, resume cursor, ...
    bytes_moved: int = 0


@dataclasses.dataclass
class SwitchStats:
    """Accounting mirrored on the paper's measurements.

    ``bytes_spilled``/``bytes_restored`` count ONLY the victim sequence's
    pages — the page-granular contract the serving executor asserts against
    (a full-pool copy would show up here as orders of magnitude more bytes).
    """

    switches: int = 0
    bytes_spilled: int = 0
    bytes_restored: int = 0
    pages_spilled: int = 0
    pages_restored: int = 0
    modeled_cycles: float = 0.0

    def modeled_seconds(self, cost: CostModel) -> float:
        return cost.seconds(self.modeled_cycles)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_pages(pool: jax.Array, pages: jax.Array,
                   data: jax.Array) -> jax.Array:
    """``pool[:, pages] = data`` with the pool buffer donated (in-place on
    device — restore touches only the victim's frames)."""
    return pool.at[:, pages].set(data)


class ContextSwitcher:
    """Spill/restore engine over a physical KV pool.

    The pool array layout is ``[num_phys_pages, page_size, ...]`` (kernels
    index it through the page table).  Spill copies the victim's pages out in
    logical order; restore writes them into freshly allocated frames — the
    physical pages may differ, exactly as after an OS swap-in.
    """

    def __init__(self, vmem: VirtualMemory, cost: CostModel | None = None,
                 page_axis: int = 0):
        self.vmem = vmem
        self.cost = cost or CostModel()
        self.stats = SwitchStats()
        self._swap: dict[int, SpilledState] = {}
        #: which axis of the pool array indexes physical pages (stacked
        #: per-layer pools use axis=1: [L, P, page, ...])
        self.page_axis = page_axis

    # ---- page-granular spill/restore (serving hot path) -------------------

    def spill_kv(self, seq_id: int, k_pools: jnp.ndarray,
                 v_pools: jnp.ndarray, extra_state: Any = None) -> None:
        """Preempt ``seq_id`` by copying ONLY its pages out of both pools.

        Unlike :meth:`spill`, the pools are never stacked or reshaped: the
        victim's frames are gathered along the page axis ([L, P, page, ...],
        axis 1) directly, so the bytes moved are exactly
        ``n_victim_pages * page_bytes * 2`` — the paper's §3.1 context-switch
        cost measured in actually-moved bytes.

        The gather is dtype-preserving: quantized pools spill their int8
        bytes verbatim (no dequant–requant round trip), so
        ``bytes_spilled`` per page shrinks by the pool itemsize ratio and
        the restore scatter below puts the identical bits back.
        """
        state = self.vmem.seq(seq_id)
        pages = jnp.asarray(np.asarray(state.pages, dtype=np.int32))
        n = len(state.pages)
        k_data = np.asarray(jnp.take(k_pools, pages, axis=1))
        v_data = np.asarray(jnp.take(v_pools, pages, axis=1))
        page_data = np.stack([k_data, v_data])     # host-side swap record
        nbytes = int(page_data.nbytes)
        self._swap[seq_id] = SpilledState(
            seq_id=seq_id,
            num_tokens=state.length,
            page_data=page_data,
            extra_state=extra_state,
            bytes_moved=nbytes,
        )
        self.vmem.spill_seq(seq_id)
        self.stats.switches += 1
        self.stats.bytes_spilled += nbytes
        self.stats.pages_spilled += 2 * n
        self.stats.modeled_cycles += (
            self.cost.scalar_ctx_switch_cycles
            + self.cost.bytes_move_cycles(nbytes)
        )

    def restore_kv(
        self, seq_id: int, k_pools: jnp.ndarray, v_pools: jnp.ndarray,
        shared_prefix_pages: list[int] | None = None,
        num_tokens: int | None = None,
    ) -> tuple[jnp.ndarray, jnp.ndarray, Any]:
        """Swap ``seq_id`` back in through a page-granular scatter.

        Returns ``(k_pools, v_pools, extra_state)``.  The input pool buffers
        are DONATED: callers must replace their references with the returned
        arrays.  Raises OutOfPagesError if frames are unavailable.

        ``shared_prefix_pages``: leading frames to re-share by refcount
        (``VirtualMemory.restore_seq``) instead of re-mapping — those
        frames are still resident (the pinned prefix) and hold bytes
        identical to the spilled copy, so they are neither allocated nor
        scattered; only the unshared tail moves.  Restore bandwidth
        (``bytes_restored``/``pages_restored``) counts the moved tail only.

        ``num_tokens``: PARTIAL restore — re-map and scatter only the
        leading page-aligned ``num_tokens`` of the record (must cover the
        shared frames).  The evicted tail pages of the record are dropped:
        the caller re-prefills those positions through the continuation
        path (causal KV is a pure function of the token prefix, so the
        recompute is bit-equivalent to the copy).  Either way the swap
        record is CONSUMED — partial restores never leak a tail record.
        """
        spilled = self._swap[seq_id]
        keep = spilled.num_tokens if num_tokens is None else int(num_tokens)
        if not 0 < keep <= spilled.num_tokens:
            raise ValueError(
                f"partial restore of seq {seq_id}: num_tokens={keep} "
                f"outside (0, {spilled.num_tokens}]")
        state = self.vmem.restore_seq(
            seq_id, keep, shared_prefix_pages)  # may raise
        skip = len(shared_prefix_pages or ())
        n_keep = len(state.pages)
        k_data = spilled.page_data[0][:, skip:n_keep]
        v_data = spilled.page_data[1][:, skip:n_keep]
        if n_keep > skip:
            pages = jnp.asarray(np.asarray(state.pages[skip:], np.int32))
            k_pools = _scatter_pages(k_pools, pages, jnp.asarray(k_data))
            v_pools = _scatter_pages(v_pools, pages, jnp.asarray(v_data))
        del self._swap[seq_id]
        nbytes = int(k_data.nbytes + v_data.nbytes)
        self.stats.bytes_restored += nbytes
        self.stats.pages_restored += 2 * (n_keep - skip)
        self.stats.modeled_cycles += self.cost.bytes_move_cycles(nbytes)
        return k_pools, v_pools, spilled.extra_state

    # ---- portable swap records (cross-replica migration) ------------------

    def export_swap(self, seq_id: int) -> SpilledState:
        """Detach ``seq_id``'s swap record for migration to ANOTHER
        replica's switcher.  The record is pure host memory in the pool's
        storage dtype (int8 stays narrow) and its frames were already
        freed at spill time, so nothing on this replica keeps referencing
        the victim after the pop.  KeyError if not spilled."""
        return self._swap.pop(seq_id)

    def import_swap(self, record: SpilledState) -> None:
        """Adopt a swap record exported from another replica's switcher.

        Validates the page geometry against THIS replica's vmem (the page
        count the record carries must be what a restore here would re-map)
        so a mismatched migration fails loudly at import, before any
        bookkeeping moves."""
        need = self.vmem.config.pages_for(record.num_tokens)
        have = int(record.page_data.shape[self.page_axis + 1])
        if have != need:
            raise ValueError(
                f"import_swap of seq {record.seq_id}: record carries "
                f"{have} pages but {record.num_tokens} tokens need {need} "
                f"under page_size={self.vmem.config.page_size}")
        if record.seq_id in self._swap:
            raise ValueError(
                f"import_swap: seq {record.seq_id} already spilled here")
        self._swap[record.seq_id] = record

    # ---- spill (whole-pool legacy API, kept for the reference engine) -----

    def spill(self, seq_id: int, pool: jnp.ndarray,
              extra_state: Any = None) -> jnp.ndarray:
        """Preempt ``seq_id``: copy its pages out, free its frames.

        Returns the pool (unchanged — data in freed frames is dead, exactly
        like freed physical memory).
        """
        state = self.vmem.seq(seq_id)
        pages = np.asarray(state.pages, dtype=np.int32)
        page_data = np.asarray(
            jnp.take(pool, jnp.asarray(pages), axis=self.page_axis)
        )
        nbytes = int(page_data.nbytes)
        self._swap[seq_id] = SpilledState(
            seq_id=seq_id,
            num_tokens=state.length,
            page_data=page_data,
            extra_state=extra_state,
            bytes_moved=nbytes,
        )
        self.vmem.spill_seq(seq_id)
        self.stats.switches += 1
        self.stats.bytes_spilled += nbytes
        self.stats.modeled_cycles += (
            self.cost.scalar_ctx_switch_cycles
            + self.cost.bytes_move_cycles(nbytes)
        )
        return pool

    # ---- restore ------------------------------------------------------------

    def can_restore(self, seq_id: int) -> bool:
        if seq_id not in self._swap:
            return False
        spilled = self._swap[seq_id]
        need = self.vmem.config.pages_for(spilled.num_tokens)
        return self.vmem.pool.num_free >= need and bool(self.vmem._free_slots)

    def restore(self, seq_id: int, pool: jnp.ndarray) -> tuple[jnp.ndarray, Any]:
        """Swap ``seq_id`` back in: new frames, data copied into them.

        Returns the updated pool and the request's ``extra_state``.
        Raises OutOfPagesError if frames are unavailable (caller preempts
        another victim first).
        """
        spilled = self._swap[seq_id]
        state = self.vmem.restore_seq(seq_id, spilled.num_tokens)  # may raise
        new_pages = jnp.asarray(np.asarray(state.pages, dtype=np.int32))
        if self.page_axis == 0:
            pool = pool.at[new_pages].set(jnp.asarray(spilled.page_data))
        elif self.page_axis == 1:
            pool = pool.at[:, new_pages].set(jnp.asarray(spilled.page_data))
        else:
            raise NotImplementedError(f"page_axis={self.page_axis}")
        del self._swap[seq_id]
        nbytes = int(spilled.page_data.nbytes)
        self.stats.bytes_restored += nbytes
        self.stats.modeled_cycles += self.cost.bytes_move_cycles(nbytes)
        return pool, spilled.extra_state

    def spilled_len(self, seq_id: int) -> int:
        """Token length recorded when ``seq_id`` was spilled — the only
        length a restore may legally re-map (KeyError if not spilled)."""
        return self._swap[seq_id].num_tokens

    def discard(self, seq_id: int) -> None:
        """Drop a swap record without restoring it (the request was failed
        by a scheduler reach check) — frees the host-side page copy."""
        self._swap.pop(seq_id, None)

    @property
    def swapped_out(self) -> list[int]:
        return sorted(self._swap)
