"""Scheduler-only unit tests: pure host policy, no device, no model.

The Scheduler (the CVA6/OS plane of the serving split) is driven with a
:class:`HostOnlyPlane` — a data-plane stub that only mirrors page-table
bookkeeping — so admission order, victim policy, preemption/restore
bookkeeping and fork accounting are tested without touching a single
device array."""

import dataclasses

import numpy as np
import pytest

from repro.core import VirtualMemory, VMemConfig
from repro.serve import HostOnlyPlane, Request, Scheduler, ServeConfig


def mk_sched(page_size=4, usable_pages=15, max_pages=8, max_batch=3):
    cfg = ServeConfig(page_size=page_size, num_pages=usable_pages + 1,
                      max_pages_per_seq=max_pages, max_batch=max_batch)
    vmem = VirtualMemory(VMemConfig(
        page_size=page_size, num_pages=usable_pages,
        max_pages_per_seq=max_pages, max_seqs=max_batch,
    ))
    sched = Scheduler(cfg, vmem)
    plane = HostOnlyPlane(vmem)
    sched.attach_plane(plane)
    return sched, plane


def req(i, plen=6, max_new=8, **kw):
    return Request(req_id=i, prompt=np.arange(plen, dtype=np.int32),
                   max_new_tokens=max_new, **kw)


class TestAdmission:
    def test_fifo_order_and_batch_cap(self):
        sched, _ = mk_sched()
        for i in range(5):
            sched.submit(req(i))
        admitted = sched.admit()
        assert [r.req_id for r in admitted] == [0, 1, 2]   # FIFO, max_batch
        sched.finish_prefill(admitted, [np.int32(7)] * 3)
        assert set(sched.running) == {0, 1, 2}
        assert all(len(r.output) == 1 for r in admitted)
        assert sched.admit() == []                          # batch is full
        assert [r.req_id for r in sched.queue] == [3, 4]
        sched.vmem.check_invariants()

    def test_admission_blocked_by_pool(self):
        # 4 usable frames, page 4: a 6-token prompt needs 2 frames
        sched, _ = mk_sched(usable_pages=4)
        for i in range(3):
            sched.submit(req(i, plen=6))
        admitted = sched.admit()
        # two requests fit (2+2 frames); the third must wait
        assert [r.req_id for r in admitted] == [0, 1]
        assert sched.vmem.pool.num_free == 0

    def test_slots_follow_vmem_mapping(self):
        sched, _ = mk_sched()
        sched.submit(req(9))
        admitted = sched.admit()
        sched.finish_prefill(admitted, [np.int32(0)])
        assert sched.slot_of[9] == sched.vmem.seq(9).slot


class TestVictimPolicy:
    def _running(self, sched, triples):
        """(req_id, remaining_work, arrival) -> running request."""
        for rid, remaining, arrival in triples:
            r = req(rid, plen=4, max_new=remaining)
            r.arrival = arrival
            r.status = "running"
            sched.vmem.map_seq(rid, 4)
            sched.running[rid] = r
            sched.slot_of[rid] = sched.vmem.seq(rid).slot

    def test_most_remaining_work_wins(self):
        sched, _ = mk_sched()
        self._running(sched, [(0, 2, 0), (1, 9, 0), (2, 5, 0)])
        assert sched.select_victim().req_id == 1

    def test_tie_broken_by_earliest_arrival(self):
        sched, _ = mk_sched()
        self._running(sched, [(0, 5, 3), (1, 5, 1), (2, 5, 2)])
        assert sched.select_victim().req_id == 1

    def test_protect_excludes_faulting_request(self):
        sched, _ = mk_sched()
        self._running(sched, [(0, 9, 0), (1, 2, 0)])
        assert sched.select_victim(protect=0).req_id == 1

    def test_no_victim_when_all_protected(self):
        sched, _ = mk_sched()
        self._running(sched, [(0, 9, 0)])
        assert sched.select_victim(protect=0) is None


class TestPreemptRestore:
    def test_spill_restore_roundtrip_fifo(self):
        sched, plane = mk_sched(usable_pages=4, max_batch=2)
        for i in range(2):
            sched.submit(req(i, plen=6, max_new=4))
        admitted = sched.admit()
        sched.finish_prefill(admitted, [np.int32(0)] * 2)
        # force both out (full-remaining tie: insertion order wins)
        assert sched.preempt_for(4)
        assert list(sched.swapped) == [0, 1]
        assert plane.events[0][0] == "spill"
        assert sched.running == {} and sched.vmem.num_seqs == 0
        # swap back in, FIFO
        restored = sched.try_restore()
        assert [r.req_id for r in restored] == [0, 1]
        assert ("restore", 1) in plane.events
        assert set(sched.running) == {0, 1}
        assert all(r.status == "running" for r in restored)
        sched.vmem.check_invariants()

    def test_restore_waits_for_free_frames(self):
        sched, _ = mk_sched(usable_pages=4, max_batch=2)
        for i in range(2):
            sched.submit(req(i, plen=6, max_new=4))
        sched.finish_prefill(sched.admit(), [np.int32(0)] * 2)
        sched.spill(sched.running[0])
        # refill the freed frames: victim 0 cannot come back yet
        sched.vmem.map_seq(9, 6)
        assert not sched.try_restore()
        sched.vmem.unmap_seq(9)
        assert [r.req_id for r in sched.try_restore()] == [0]

    def test_preempt_for_gives_up_without_candidates(self):
        sched, _ = mk_sched(usable_pages=4)
        # more frames demanded than exist, nothing running to evict
        assert not sched.preempt_for(5)
        # already-satisfiable demand needs no victim at all
        assert sched.preempt_for(3)


class TestForkAccounting:
    def _with_prefix(self, plen=6, **kw):
        sched, plane = mk_sched(**kw)
        sched.vmem.map_seq(sched.PREFIX_ID, plen)
        sched.prefix_len = plen
        return sched, plane

    def test_forked_admission_shares_whole_pages(self):
        sched, plane = self._with_prefix(plen=6)   # pages [2]: 1 whole+tail
        sched.submit(req(5, plen=3, share_prefix=True))
        assert sched.admit() == []                 # forked handled inline
        assert 5 in sched.running
        assert sched.counters.get("forked_admissions") == 1
        parent = sched.vmem.seq(sched.PREFIX_ID)
        child = sched.vmem.seq(5)
        # whole page 0 shared by refcount; tail page copied
        assert child.pages[0] == parent.pages[0]
        assert sched.vmem.pool.refcount(parent.pages[0]) == 2
        assert child.pages[1] != parent.pages[1]
        # data plane told to COW-copy exactly the parent tail page
        ev = [e for e in plane.events if e[0] == "admit_forked"][0]
        assert ev[2] == 6 and ev[3] == (parent.pages[1], child.pages[1])
        # chunk appended: child covers prefix + prompt
        assert sched.vmem.seq_len(5) == 6 + 3
        assert sched.running[5].prefix_len == 6
        assert len(sched.running[5].output) == 1   # first sampled token
        sched.vmem.check_invariants()

    def test_page_aligned_prefix_needs_no_tail_copy(self):
        sched, plane = self._with_prefix(plen=8)   # 8 % 4 == 0
        sched.submit(req(5, plen=2, share_prefix=True))
        sched.admit()
        ev = [e for e in plane.events if e[0] == "admit_forked"][0]
        assert ev[3] is None
        parent = sched.vmem.seq(sched.PREFIX_ID)
        for p in parent.pages:
            assert sched.vmem.pool.refcount(p) == 2

    def test_fork_rolls_back_cleanly_on_oom(self):
        # prefix holds 2 of 4 frames; a 9-token chunk needs 3 more -> OOM
        sched, _ = self._with_prefix(plen=6, usable_pages=4, max_pages=8)
        sched.submit(req(5, plen=9, share_prefix=True))
        assert sched.admit() == []
        assert 5 not in sched.running
        assert sched.vmem.num_seqs == 1            # only the prefix remains
        assert sched.vmem.pool.refcount(
            sched.vmem.seq(sched.PREFIX_ID).pages[0]) == 1
        sched.vmem.check_invariants()


class TestGrowAndCommit:
    def test_grow_counts_page_faults(self):
        sched, _ = mk_sched(page_size=4)
        sched.submit(req(0, plen=4, max_new=8))
        sched.finish_prefill(sched.admit(), [np.int32(0)])
        # position 4 needs a fresh page -> one fault
        sched.grow_running()
        assert sched.counters.get("page_faults") == 1
        assert sched.counters.get("modeled_fault_cycles") > 0
        plan = sched.decode_plan()
        assert plan.active.sum() == 1
        assert plan.pre_lens[sched.slot_of[0]] == 4

    def test_commit_retires_finished_requests(self):
        sched, _ = mk_sched()
        sched.submit(req(0, plen=4, max_new=2))
        sched.finish_prefill(sched.admit(), [np.int32(0)])
        sched.grow_running()
        sampled = np.zeros((sched.cfg.max_batch,), np.int32)
        sched.commit_decode(sampled)
        assert 0 in sched.done and not sched.running
        assert sched.vmem.num_seqs == 0
        assert sched.counters.get("completed") == 1

    def test_decode_plan_none_when_idle(self):
        sched, _ = mk_sched()
        assert sched.decode_plan() is None


def drive(sched, max_steps=500, hook=None):
    """Engine.step loop restated on the bare scheduler (fake data plane)."""
    steps = 0
    while sched.has_work and steps < max_steps:
        steps += 1
        if hook is not None:
            hook(sched, steps)
        sched.begin_step()
        sched.try_restore()
        admitted = sched.admit()
        if admitted:
            sched.finish_prefill(admitted, [np.int32(0)] * len(admitted))
        sched.grow_running()
        if sched.decode_plan() is not None:
            sched.commit_decode(np.zeros((sched.cfg.max_batch,), np.int32))
    return steps


class TestReachChecks:
    """Livelock prevention (ROADMAP: restore livelock under capacity
    pressure, observed via ``--prefix-len 10 --num-pages 10``): requests
    whose page demand can NEVER be met are failed/parked instead of
    spinning until ``run(max_steps)`` expires."""

    def _with_prefix(self, plen, **kw):
        sched, plane = mk_sched(**kw)
        sched.vmem.map_seq(sched.PREFIX_ID, plen)
        sched.prefix_len = plen
        return sched, plane

    def test_attainable_excludes_pinned_prefix_pages(self):
        sched, _ = self._with_prefix(plen=5, usable_pages=9)   # 2 pinned
        assert sched.attainable_pages() == 7
        sched2, _ = mk_sched(usable_pages=9)
        assert sched2.attainable_pages() == 9

    def test_oversized_plain_request_fails_fast_and_unblocks_queue(self):
        # mapped lifetime 6+7=13 tokens -> 4 pages > 2 attainable: the seed
        # policy would head-of-line block the queue forever (admission
        # needs only pages_for(7)=2, then growth stalls degraded)
        sched, _ = mk_sched(usable_pages=2)
        sched.submit(req(0, plen=6, max_new=8))
        sched.submit(req(1, plen=3, max_new=2))     # feasible: 2 pages
        admitted = sched.admit()
        assert [r.req_id for r in admitted] == [1]
        assert sched.done[0].status == "failed"
        assert sched.counters.get("failed_unreachable") == 1
        sched.vmem.check_invariants()

    def test_oversized_forked_request_fails_at_admission(self):
        # mapped lifetime 5+20+19=44 tokens -> 11 pages, 1 shared -> 10 > 7
        sched, _ = self._with_prefix(plen=5, usable_pages=9, max_pages=16)
        sched.submit(req(7, plen=20, max_new=20, share_prefix=True))
        assert sched.admit() == []
        assert sched.done[7].status == "failed"
        assert sched.vmem.num_seqs == 1             # fork never mapped
        sched.vmem.check_invariants()

    def test_page_boundary_request_is_not_spuriously_failed(self):
        # plen 9, max_new 8: only 16 tokens are ever MAPPED (the final
        # sampled token retires unmapped), which fits 2 pages exactly —
        # a pages_for(prompt + max_new) check would fail it spuriously
        sched, _ = mk_sched(page_size=8, usable_pages=2, max_pages=8)
        sched.submit(req(0, plen=9, max_new=8))
        steps = drive(sched, max_steps=100)
        assert steps < 100 and not sched.has_work
        assert sched.counters.get("failed_unreachable") == 0
        assert sched.done[0].status == "done"
        assert len(sched.done[0].output) == 8
        sched.vmem.check_invariants()

    def test_feasible_forked_workload_has_no_false_positives(self):
        """The exact ``--prefix-len 10 --num-pages 10`` launcher workload
        (16 forked requests) completes; the reach checks must not fail
        anything that can finish."""
        sched, _ = self._with_prefix(plen=10, page_size=8, usable_pages=9,
                                     max_pages=9, max_batch=4)
        rng = np.random.default_rng(0)
        rng.integers(0, 1000, size=10)               # the prefix token draw
        for i in range(16):
            plen = int(rng.integers(12, 25))
            rng.integers(0, 1000, size=plen)         # prompt token draw
            sched.submit(Request(
                req_id=i, prompt=np.arange(plen, dtype=np.int32),
                max_new_tokens=24, share_prefix=True))
        steps = drive(sched, max_steps=1000)
        assert steps < 1000 and not sched.has_work
        assert sched.counters.get("failed_unreachable") == 0
        assert all(r.status == "done" for r in sched.done.values())
        assert len(sched.done) == 16
        sched.vmem.check_invariants()


class TestFaultPlaneLivelockPorts:
    """The two reach-check livelock regressions, ported from hand-rolled
    ``drive(hook=...)`` loops onto the shared fault-injection harness
    (``tests/_fault_plane.py``): scripted ``submit`` events replace the
    stateful hooks, and the canonical ``Scheduler.step_plane`` loop —
    the same one the engine and the multi-replica router drive — replaces
    the bespoke step sequence.  ``max_horizon=1`` keeps one token-step
    per drive step, so the scripted event steps line up with the original
    hook arithmetic."""

    def _forked_replica(self, schedule):
        from _fault_plane import make_replica
        sched, plane = make_replica(page_size=4, usable_pages=9,
                                    max_pages=16, max_batch=3,
                                    max_horizon=1, schedule=schedule)
        sched.vmem.map_seq(sched.PREFIX_ID, 5)
        sched.prefix_len = 5
        return sched, plane

    def test_spilled_fork_restores_by_resharing_pinned_frames(self):
        """The shared-page restore regression: a fork spilled near the end
        of its decode carries pf(spilled) pages, ONE of which is the still-
        resident pinned-prefix frame.  The old restore re-mapped without
        prefix sharing, so its demand (8 frames here) exceeded what
        preemption can ever free next to the pinned prefix (7) — the
        victim was failed as unreachable even though re-sharing makes it
        fit exactly.  Post-fix the restore re-shares the recorded pinned
        frame by refcount, allocates only the 7-frame remainder, and the
        request completes with its exact token stream.  Req 0's remaining
        hits 1 just before step 14 (output = step + 1), so the scripted
        late arrival forces the spill at exactly the old hook's step."""
        from _fault_plane import drive, expected_output
        sched, plane = self._forked_replica(
            (("submit", 14, req(1, plen=8, max_new=4)),)
        )
        r0 = req(0, plen=12, max_new=15, share_prefix=True)
        sched.submit(r0)
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work    # no livelock
        assert sched.done[0].status == "done"
        assert sched.done[1].status == "done"
        assert sched.counters.get("preemptions") == 1
        assert sched.counters.get("restores") == 1
        assert sched.counters.get("shared_restores") == 1
        assert sched.counters.get("pages_reused") == 1
        assert sched.counters.get("failed_unreachable") == 0
        # the swap record was consumed by the restore, never discarded
        assert ("discard", 0) not in plane.events
        assert ("restore", 0) in plane.events
        # re-sharing changed frames moved, never the stream
        assert [int(x) for x in sched.done[0].output] == expected_output(r0)
        sched.vmem.check_invariants()

    def test_genuinely_unreachable_lifetime_still_fails_fast(self):
        """The failure path the re-sharing fix must NOT erode: a fork whose
        lifetime demand exceeds pool reach even WITH its pinned-prefix
        frame deducted (own = pf(5+12+16) - 1 = 8 > 7 attainable) is
        failed at admission — surfaced through ``done`` so ``run()``
        terminates instead of spinning until ``max_steps``."""
        from _fault_plane import drive
        sched, plane = self._forked_replica(())
        sched.submit(req(0, plen=12, max_new=17, share_prefix=True))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.done[0].status == "failed"
        assert sched.counters.get("failed_unreachable") == 1
        assert sched.counters.get("preemptions") == 0
        sched.vmem.check_invariants()

    def test_grow_stall_after_restore_still_terminates(self):
        """A fork spilled EARLY restores fine (small footprint, pinned
        frame re-shared) but may still stall growing to its full lifetime
        next to the pinned prefix under late arrivals.  Growth stalls are
        degraded, not deadlocked (decode proceeds with scratch-routed
        writes, seed semantics) — the run must terminate without tripping
        the reach checks."""
        from _fault_plane import drive
        sched, plane = self._forked_replica(
            (("submit", 3, req(1, plen=16, max_new=4)),)
        )
        sched.submit(req(0, plen=12, max_new=15, share_prefix=True))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.counters.get("preemptions") == 1
        assert sched.counters.get("restores") == 1   # it DID come back
        assert sched.counters.get("failed_unreachable") == 0
        assert sched.done[0].status == "done"
        assert sched.done[1].status == "done"
        sched.vmem.check_invariants()


class TestRestoreFailureHandling:
    """Transient data-plane restore failures (``RestoreFailure``): the
    scheduler must retry from the unchanged swap-queue head — never crash,
    drop the victim, or reorder the FIFO."""

    def _replica(self, schedule, usable_pages=4, max_batch=2, **cfg_kw):
        from _fault_plane import make_replica
        return make_replica(page_size=4, usable_pages=usable_pages,
                            max_pages=8, max_batch=max_batch,
                            max_horizon=1, schedule=schedule, **cfg_kw)

    def test_transient_failure_is_retried_until_it_clears(self):
        from _fault_plane import drive, expected_output
        sched, plane = self._replica(
            (("force_spill", 2, 0), ("fail_restore", 1, 0, 2)),
        )
        r = req(0, plen=6, max_new=6)
        sched.submit(req(0, plen=6, max_new=6))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.counters.get("restore_failures") == 2
        assert sched.counters.get("restores") == 1
        assert sched.done[0].status == "done"
        # the injected failures delayed, never corrupted, the stream
        assert [int(x) for x in sched.done[0].output] == expected_output(r)
        assert plane.events.count(("restore_failed", 0)) == 2
        sched.vmem.check_invariants()

    def test_failing_head_stays_at_front_while_second_chance_rescues(self):
        """A transiently failing FIFO head no longer starves the victims
        behind it: the bounded second-chance scan restores rid 1 DURING
        rid 0's outage, while rid 0 keeps the head position and restores
        the moment its failure clears — completions never reorder the
        FIFO head out of turn."""
        from _fault_plane import drive
        sched, plane = self._replica(
            (("force_spill", 2, 0), ("force_spill", 2, 1),
             ("fail_restore", 1, 0, 3)),
            usable_pages=6,
        )
        for i in range(2):
            sched.submit(req(i, plen=6, max_new=8))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.counters.get("restore_failures") == 3
        # rid 1 came back through the scan while the head was failing...
        restores = [e for e in plane.events if e[0] == "restore"]
        assert restores[0] == ("restore", 1)
        assert sched.counters.get("second_chance_restores") >= 1
        # ...and the head was never dropped: rid 0 restored right after
        assert ("restore", 0) in restores
        assert all(r.status == "done" for r in sched.done.values())
        sched.vmem.check_invariants()

    def test_scan_disabled_preserves_strict_fifo_restore_order(self):
        """``restore_scan_limit=0`` pins the pre-scan contract: the failed
        head blocks and nothing behind it restores first."""
        from _fault_plane import drive
        sched, plane = self._replica(
            (("force_spill", 2, 0), ("force_spill", 2, 1),
             ("fail_restore", 1, 0, 3)),
            usable_pages=6, restore_scan_limit=0,
        )
        for i in range(2):
            sched.submit(req(i, plen=6, max_new=8))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.counters.get("restore_failures") == 3
        assert sched.counters.get("second_chance_restores") == 0
        # FIFO preserved: 1 restores only after the failing head 0 clears
        restores = [e for e in plane.events if e[0] == "restore"]
        assert restores[0] == ("restore", 0)
        assert ("restore", 1) in restores
        assert all(r.status == "done" for r in sched.done.values())
        sched.vmem.check_invariants()


class TestHorizonPlanning:
    """Fused-decode horizon policy: pure host arithmetic, no device.

    ``plan_horizon`` may only open a K>1 horizon when no scheduler event
    can become due mid-horizon; ``grow_horizon`` pre-faults every page the
    horizon touches in one all-or-nothing batch and collapses to 1 (exact
    pre-horizon behavior) under pool pressure."""

    def _start(self, sched, reqs):
        for r in reqs:
            sched.submit(r)
        admitted = sched.admit()
        sched.finish_prefill(admitted, [np.int32(0)] * len(admitted))
        return admitted

    def test_collapses_on_pending_admission(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, max_new=12), req(1, max_new=12),
                            req(2, max_new=12)])
        assert list(sched.queue)               # req 2 waits behind the batch
        assert sched.plan_horizon() == 1

    def test_collapses_on_pending_restore(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, max_new=12), req(1, max_new=12)])
        sched.spill(sched.running[1])
        assert sched.plan_horizon() == 1

    def test_caps_at_longest_lane_rounded_to_pow2(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, max_new=4), req(1, max_new=12)])
        # remaining after prefill: 3 and 11 -> min(cap=8, max=11) = 8
        assert sched.plan_horizon() == 8
        sched2, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched2, [req(0, max_new=4), req(1, max_new=4)])
        # longest lane has 3 steps left -> floor to 2
        assert sched2.plan_horizon() == 2

    def test_disabled_by_config(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        sched.cfg = dataclasses.replace(sched.cfg, max_horizon=1)
        self._start(sched, [req(0, max_new=12)])
        assert sched.plan_horizon() == 1

    def test_grow_horizon_prefaults_every_page_in_one_batch(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, plen=4, max_new=12)])
        # total_len 5, seq_len 4; K=8 -> mapped target 5+8-1 = 12 tokens
        k = sched.grow_horizon(sched.plan_horizon())
        assert k == 8
        assert sched.vmem.seq_len(0) == 12
        assert sched.counters.get("page_faults") == 2   # pages 1 and 2
        plan = sched.decode_plan(k)
        assert plan.horizon == 8
        assert plan.steps_left[sched.slot_of[0]] == 8
        sched.vmem.check_invariants()

    def test_grow_horizon_collapses_under_pool_pressure(self):
        sched, _ = mk_sched(usable_pages=4, max_pages=16, max_batch=2)
        self._start(sched, [req(0, plen=4, max_new=12),
                            req(1, plen=4, max_new=12)])
        # K=8 wants 2+2 more frames but only 2 are free: all-or-nothing
        # growth refuses, the horizon collapses to the exact per-step
        # path (each lane faults one page; nothing was half-grown)
        assert sched.grow_horizon(8) == 1
        assert sched.counters.get("horizon_collapses") == 1
        assert sched.vmem.seq_len(0) == 5 and sched.vmem.seq_len(1) == 5
        assert sched.counters.get("page_faults") == 2
        sched.vmem.check_invariants()

    def test_retiring_lane_grows_one_token_short(self):
        """A lane retiring inside the horizon never maps its FINAL sampled
        token (it retires inside commit_decode) — the -1 in the growth
        target, mirroring the admission reach-check arithmetic."""
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, plen=4, max_new=3),
                            req(1, plen=4, max_new=12)])
        k = sched.grow_horizon(sched.plan_horizon())
        assert k == 8
        # lane 0 participates for its 2 remaining steps only: mapped target
        # total_len(5) + 2 - 1 = 6, not 5 + 8 - 1
        assert sched.vmem.seq_len(0) == 6
        assert sched.vmem.seq_len(1) == 12
        plan = sched.decode_plan(k)
        assert plan.steps_left[sched.slot_of[0]] == 2
        assert plan.steps_left[sched.slot_of[1]] == 8

    def test_commit_block_step_major_retires_mid_horizon(self):
        sched, _ = mk_sched(usable_pages=30, max_pages=16, max_batch=2)
        self._start(sched, [req(0, plen=4, max_new=2),
                            req(1, plen=4, max_new=4)])
        k = sched.grow_horizon(sched.plan_horizon())
        assert k == 2                          # longest lane has 3 left -> 2
        slot0, slot1 = sched.slot_of[0], sched.slot_of[1]
        block = np.arange(2 * sched.cfg.max_batch,
                          dtype=np.int32).reshape(2, -1)
        sched.commit_decode(block, horizon=2)
        # lane 0 retired after inner step 0; its t=1 row was ignored
        assert sched.done[0].status == "done"
        assert [int(x) for x in sched.done[0].output[1:]] == [block[0][slot0]]
        assert [int(x) for x in sched.running[1].output[1:]] == [
            block[0][slot1], block[1][slot1]]
        # step-major accounting: 2 lanes at t=0, 1 lane at t=1
        assert sched.counters.get("decode_tokens") == 3
        sched.vmem.check_invariants()


class TestBatchedForkAdmission:
    def test_same_step_forks_issue_one_plane_call(self):
        sched, plane = mk_sched(page_size=4, usable_pages=20, max_pages=16,
                                max_batch=4)
        sched.vmem.map_seq(sched.PREFIX_ID, 6)
        sched.prefix_len = 6
        for i in range(3):
            sched.submit(req(i, plen=3 + i, share_prefix=True))
        assert sched.admit() == []
        batches = [e for e in plane.events if e[0] == "admit_forked_batch"]
        assert len(batches) == 1 and batches[0][1] == [0, 1, 2]
        assert sched.counters.get("fork_batches") == 1
        assert sched.counters.get("forked_admissions") == 3
        assert set(sched.running) == {0, 1, 2}
        # request-order output commit: every fork got its first token
        assert all(len(sched.running[i].output) == 1 for i in range(3))
        sched.vmem.check_invariants()


class TestSharedPageReachAccounting:
    """The satellite reach-check accounting regression: each PHYSICAL
    frame must be counted once across the pinned deduction and the
    request's own demand.  Pre-fix, a radix-hit admission's demand was
    ``pf(lifetime)`` with no deduction for the pinned frames it shares,
    so an admission that fits exactly was falsely failed as unreachable;
    symmetrically, frames shared with a NON-pinned owner must still count
    in full (the owner is preemptible, so both footprints coexist in the
    preemptible pool)."""

    PREFIX = np.arange(100, 108, dtype=np.int32)     # 8 tokens = 2 pages

    def _replica(self, schedule=()):
        from _fault_plane import make_replica
        return make_replica(page_size=4, usable_pages=9, max_pages=16,
                            max_batch=3, max_horizon=1, schedule=schedule)

    def test_radix_hit_sharing_pinned_frames_admits_at_exact_fit(self):
        """pf(lifetime)=9 > attainable=7, but 2 of those 9 frames are the
        pinned prefix frames the radix hit re-shares — own demand is 7,
        an exact fit.  Pre-fix accounting (no pinned-shared deduction)
        failed this admission as unreachable."""
        from _fault_plane import drive, expected_output
        sched, plane = self._replica()
        sched.vmem.map_seq(sched.PREFIX_ID, len(self.PREFIX))
        sched.prefix_len = len(self.PREFIX)
        sched.register_resident(sched.PREFIX_ID, self.PREFIX)

        prompt = np.concatenate([self.PREFIX,
                                 np.arange(200, 204, dtype=np.int32)])
        r = Request(req_id=0, prompt=prompt, max_new_tokens=22)
        # the pre-fix falsity, stated on the numbers: lifetime demand
        # counted per-sequence exceeds reach, counted per-frame it fits
        lifetime = len(prompt) + r.max_new_tokens - 1
        assert sched.vmem.config.pages_for(lifetime) \
            > sched.attainable_pages()

        sched.submit(r)
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.counters.get("failed_unreachable") == 0
        assert sched.done[0].status == "done"
        assert sched.counters.get("prefix_hits") == 1
        assert sched.counters.get("pages_reused") == 2
        assert sched.counters.get("prefill_tokens_skipped") == 8
        # the radix hit produced the exact cold-admission stream
        assert [int(x) for x in sched.done[0].output] == expected_output(r)
        sched.vmem.check_invariants()

    def test_sharing_with_preemptible_owner_does_not_extend_reach(self):
        """The false-ADMIT guard: a radix hit on a plain (non-pinned)
        owner shares frames that preemption can reclaim, so they must
        count fully in the child's demand — deducting them would admit a
        request whose footprint can never be mapped alone (pf(37)=10 >
        pool=9) and revive the restore livelock."""
        from _fault_plane import drive
        owner_prompt = np.arange(100, 108, dtype=np.int32)
        child = Request(
            req_id=1,
            prompt=np.concatenate([owner_prompt,
                                   np.arange(200, 204, dtype=np.int32)]),
            max_new_tokens=26,
        )
        # scripted late arrival: the owner's prompt is committed (and
        # radix-registered) before the child is probed
        sched, plane = self._replica((("submit", 3, child),))
        sched.submit(Request(req_id=0, prompt=owner_prompt,
                             max_new_tokens=4))
        steps = drive(sched, plane, max_steps=200)
        assert steps < 200 and not sched.has_work
        assert sched.done[0].status == "done"
        assert sched.done[1].status == "failed"
        assert sched.counters.get("failed_unreachable") == 1
        sched.vmem.check_invariants()


def test_scheduler_imports_no_jax_arrays():
    """The policy plane must stay host-only: no jnp/jax usage in module."""
    import inspect

    import repro.serve.scheduler as S
    src = inspect.getsource(S)
    assert "import jax" not in src and "jnp." not in src
