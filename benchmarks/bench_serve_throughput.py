"""Serving split before/after: decode rate + context-switch bytes moved.

Runs the same preempting workload through the frozen seed engine
(``repro.serve.reference.ReferenceEngine``, monolithic host loop: full
page-table re-upload each step, full-pool stack+reshape per spill/restore)
and the refactored Scheduler/Executor engine (persistent delta-updated
device page table, donated jitted steps, page-granular spill), and reports:

  * decode steps/s (wall; CPU-interpret numbers — the *ratio* is the
    signal, absolute rates are hardware-dependent);
  * spill/restore bytes actually moved per context switch.  The seed's
    *counter* already counted victim pages only, so its data-plane
    pathology is reported separately as ``touched`` bytes: every seed
    spill stacks both full pools (2 x pool bytes) and every restore
    rebuilds them (2 x more), regardless of victim size;
  * page-table rows uploaded to the device per decode step (seed: all
    ``max_batch`` rows, every step).
"""

from __future__ import annotations

import copy
import time

import numpy as np


def _workload(cfg, n=6, seed=0, max_new=12):
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    return [
        Request(req_id=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=int(rng.integers(6, 16))
                                    ).astype(np.int32),
                max_new_tokens=max_new)
        for i in range(n)
    ]


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    return done, wall


def main() -> list[str]:
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import Engine, ReferenceEngine, ServeConfig

    cfg = get_config("qwen2-7b", reduced=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    serve_cfg = ServeConfig(page_size=4, num_pages=16, max_pages_per_seq=16,
                            max_batch=3)
    reqs = _workload(cfg)

    results = {}
    for name, eng_cls in (("seed", ReferenceEngine), ("split", Engine)):
        # warm the jit caches so the timed run measures steady-state decode
        _drive(eng_cls(model, params, serve_cfg), _workload(cfg, n=2, seed=1,
                                                            max_new=3))
        eng = eng_cls(model, params, serve_cfg)
        done, wall = _drive(eng, reqs)
        c = eng.counters
        steps = c.get("decode_tokens")
        st = eng.switcher.stats
        kp = eng.kv.k_pools
        n_layers, n_frames, page, hkv, hd = kp.shape
        per_page = n_layers * page * hkv * hd * kp.dtype.itemsize
        pool_bytes = n_frames * per_page
        if name == "seed":
            # data plane actually touched: jnp.stack of BOTH full pools on
            # every spill and every restore, plus the full-pool rebuild
            # after the restore scatter (2x pool each time)
            touched = (st.switches + c.get("restores")) * 2 * pool_bytes
            # full [max_batch, max_pages] table re-uploaded on every engine
            # step that decoded (upper-bounded by total steps)
            ptab_rows = eng._step_i * eng.cfg.max_batch
        else:
            touched = st.bytes_spilled + st.bytes_restored
            ptab_rows = c.get("ptab_rows_uploaded")
        decode_s = c.seconds("decode") or wall
        results[name] = dict(
            wall=wall, tokens=sum(len(r.output) for r in done.values()),
            decode_steps=steps, decode_seconds=decode_s,
            switches=st.switches, moved=st.bytes_spilled + st.bytes_restored,
            touched=touched, ptab_rows=ptab_rows,
        )
        print(f"{name:>6}: {results[name]['tokens']} tokens in {wall:.1f}s, "
              f"{st.switches} switches, "
              f"{results[name]['moved']} B victim pages moved, "
              f"{touched} B pool bytes touched, "
              f"{ptab_rows} page-table rows uploaded")

    seed, split = results["seed"], results["split"]
    rate_seed = seed["decode_steps"] / max(seed["decode_seconds"], 1e-9)
    rate_split = split["decode_steps"] / max(split["decode_seconds"], 1e-9)
    print(f"decode tokens/s: seed {rate_seed:.1f} -> split {rate_split:.1f} "
          f"({rate_split / max(rate_seed, 1e-9):.2f}x, CPU interpret)")
    print(f"bytes touched per switch: seed "
          f"{seed['touched'] // max(seed['switches'], 1)} -> split "
          f"{split['touched'] // max(split['switches'], 1)}")
    return [
        f"serve_decode_tok_per_s_seed,0,{rate_seed:.2f}",
        f"serve_decode_tok_per_s_split,0,{rate_split:.2f}",
        f"serve_ctx_bytes_touched_seed,0,{seed['touched']}",
        f"serve_ctx_bytes_touched_split,0,{split['touched']}",
        f"serve_ptab_rows_uploaded_seed,0,{seed['ptab_rows']}",
        f"serve_ptab_rows_uploaded_split,0,{split['ptab_rows']}",
    ]


if __name__ == "__main__":
    main()
