"""Host-side serving policy — the CVA6/OS plane of the serving split.

AraOS keeps the scalar core (CVA6) in charge of translation and OS policy
while the Ara2 vector datapath streams bursts; the vector unit only hits
peak when the scalar side stays off its critical path.  The serving engine
mirrors that split: this module is the *scalar/OS plane* — admission
control, victim selection, fork bookkeeping, page-table policy — and it
owns **no device arrays**.  All state here is Python/NumPy, so the
scheduler is unit-testable without a device (see
``tests/test_serve_scheduler.py``, which drives it with a fake data plane).

Data movement (KV page copies, prefill/decode dispatch) is delegated to a
:class:`DataPlane` — in production the device-resident
:class:`repro.serve.executor.Executor`; in tests the :class:`HostOnlyPlane`
stub below.  The scheduler decides *what* moves; the plane decides *how*.

All per-replica mutable scheduling state (queues, running set, swap
records, the step clock, the resident-prefix length) is factored into
:class:`ReplicaState`, so a multi-replica control plane
(:class:`repro.serve.router.ReplicaRouter`) is N schedulers over N data
planes with zero shared mutable state — the single-replica engine is
exactly the N=1 instance of that layering.

**Radix prefix layer.**  Admission consults a per-replica
:class:`~repro.serve.prefix_cache.PrefixCache` — a page-granularity radix
trie over the token content of resident mapped runs — before allocating:
a prompt whose leading whole pages match a registered run is admitted by
COW-forking those pages from the owner (``fork_seq`` refcounts, no fork
API on the request) and prefilling only the divergent chunk through the
same batched continuation path forked admissions use.  Sequences are
registered only after their prompt KV commits (``finish_prefill`` /
``_flush_forked`` / ``register_resident``) and evicted automatically via
the ``VirtualMemory`` unmap hook, so the trie always describes live
frames.  Counters: ``prefix_hits``, ``pages_reused``,
``prefill_tokens_skipped``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Protocol

import numpy as np

from repro.core import CostModel, OutOfPagesError, PerfCounters, VirtualMemory
from repro.serve.prefix_cache import PrefixCache


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray              # [len] int32 (or [len, K] audio)
    max_new_tokens: int
    output: list[Any] = dataclasses.field(default_factory=list)
    status: str = "queued"          # queued|running|swapped|done|failed
    arrival: int = 0                # engine step of submission
    share_prefix: bool = False      # fork from the engine's resident prefix

    prefix_len: int = 0             # set by the scheduler on forked admission

    #: per-token stream sink (set from ``ServeRequest.stream_callback``);
    #: invoked by the async detokenize thread, never by the scheduler
    stream_callback: Callable | None = None
    #: SLO timestamps (``time.perf_counter``), captured by the scheduler
    #: at host-visible commit points — submit / first committed token /
    #: every committed token — NEVER at detokenize, so async streaming
    #: cannot skew TTFT/TPOT (see repro.serve.api.RequestTiming)
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_last_token: float = 0.0
    #: peak mapped-page footprint over the request's lifetime
    pages_peak: int = 0

    @property
    def total_len(self) -> int:
        return self.prefix_len + len(self.prompt) + len(self.output)

    @property
    def remaining(self) -> int:
        return self.max_new_tokens - len(self.output)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    page_size: int = 16
    num_pages: int = 256            # physical frames (1 reserved as scratch)
    max_pages_per_seq: int = 32
    max_batch: int = 8
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    tick_every_steps: int = 50      # scheduler tick accounting cadence
    #: fused decode horizon cap: the executor may run up to this many
    #: chained decode steps per dispatch (1 disables fusion).  The auto
    #: horizon is rounded down to a power of two so the jit cache stays
    #: O(log max_horizon) entries.
    max_horizon: int = 8
    #: partial restore: after this many CONSECUTIVE capacity-blocked
    #: ``try_restore`` passes at the swap-FIFO head, the scheduler stops
    #: waiting for an all-or-nothing restore, restores the longest
    #: page-aligned prefix of the victim that fits the pool right now and
    #: re-enqueues the request to re-prefill only the evicted tail through
    #: the continuation path (``partial_restores``/``pages_refilled``).
    #: 0 disables partial restore (strict all-or-nothing restores).
    restore_patience: int = 6
    #: second-chance restore scan: how many victims PAST a
    #: ``RestoreFailure``-blocked FIFO head one ``try_restore`` pass may
    #: attempt (mirroring the bounded admission scan), so a head pinned to
    #: a failing plane cannot starve the rest of the swap queue.  The head
    #: is never popped out of order.  0 restores strict head-only retry.
    restore_scan_limit: int = 4
    #: explicit escape hatch (``--no-kernels`` in launch.serve): dispatch
    #: every compute step through a ``use_kernels=False`` twin of the
    #: model — the jnp reference paths.  Never implied by a mesh anymore
    #: (kernels shard_map over it, see kernels/ops.py); any dispatch
    #: through the twin is counted as ``ref_path_dispatches`` so fallback
    #: is observable, not silent.
    use_ref_path: bool = False
    #: KV pool storage dtype: "native" keeps the model compute dtype;
    #: "int8" makes the executor bind a quantized-pool model twin — pools
    #: allocate int8 under the same shardings, writes quantize, and the
    #: paged-attention kernels dequantize in VMEM (the scale rides the
    #: scalar-prefetch plane), so the kernel path stays live
    #: (``quant_dispatches`` counts it).  Spill/restore then moves the
    #: narrow bytes verbatim.  ``--kv-dtype`` in launch.serve.
    kv_dtype: str = "native"
    #: global radix prefix cache: admissions whose leading whole pages
    #: match a resident registered run are COW-mapped from the owner and
    #: prefill skips the matched tokens (continuation path).  Token
    #: streams are identical either way (causal KV is a pure function of
    #: the token prefix); disable for a cold-admission baseline
    #: (``--no-prefix-cache`` in launch.serve, the bench reference).
    prefix_cache: bool = True
    #: AOT-bucketed prefill: prompt batches are padded up to the smallest
    #: of these lengths and dispatched through executables pre-lowered and
    #: compiled at engine build (``aot_compile`` against
    #: ``ShapeDtypeStruct``s), so no request pays a first-hit jit stall.
    #: Buckets must be positive ``page_size`` multiples within the
    #: page-table reach; ``None`` (default) keeps the plain shape-keyed
    #: jit path.  Padding is numerically inert — pad rows carry lens=0 and
    #: INVALID_PAGE table rows (routed to the scratch/trash frame) and
    #: causal masking keeps pad columns out of every real row — so greedy
    #: streams are bit-identical to the unbucketed dispatch.  Counters:
    #: ``aot_hits`` / ``aot_misses`` / ``bucket_pad_tokens``.
    aot_buckets: tuple[int, ...] | None = None
    #: serve-mesh request: "off" (single device), "auto" (factor all
    #: visible devices over ('kv','hd')), or an integer device count.
    #: Resolved to a concrete mesh by :meth:`build_mesh` — the one place
    #: the flag is interpreted (``--serve-mesh`` in launch.serve).
    serve_mesh: str = "off"

    def __post_init__(self) -> None:
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 2:
            raise ValueError(
                f"num_pages must be >= 2 (one frame is reserved as the "
                f"masked-lane scratch), got {self.num_pages}")
        if self.max_batch < 1 or self.max_horizon < 1:
            raise ValueError(
                f"max_batch ({self.max_batch}) and max_horizon "
                f"({self.max_horizon}) must be >= 1")
        if self.restore_patience < 0 or self.restore_scan_limit < 0:
            raise ValueError(
                f"restore_patience ({self.restore_patience}) and "
                f"restore_scan_limit ({self.restore_scan_limit}) must be "
                ">= 0 (0 disables the mechanism)")
        if self.kv_dtype not in ("native", "int8"):
            raise ValueError(
                f"kv_dtype must be 'native' or 'int8', got "
                f"{self.kv_dtype!r} (fp8 pools are a roadmap item, not a "
                "silent fallback)")
        if not self.greedy and self.temperature <= 0:
            raise ValueError(
                f"temperature must be > 0 for stochastic sampling, got "
                f"{self.temperature}")
        if self.serve_mesh not in ("off", "auto"):
            try:
                int(self.serve_mesh)
            except (TypeError, ValueError):
                raise ValueError(
                    f"serve_mesh must be 'off', 'auto' or a device count, "
                    f"got {self.serve_mesh!r}") from None
        if self.aot_buckets is not None:
            buckets = tuple(sorted({int(b) for b in self.aot_buckets}))
            if not buckets:
                object.__setattr__(self, "aot_buckets", None)
                return
            reach = self.max_pages_per_seq * self.page_size
            for b in buckets:
                if b <= 0 or b % self.page_size:
                    raise ValueError(
                        f"aot_buckets must be positive multiples of "
                        f"page_size={self.page_size}, got {b}")
                if b > reach:
                    raise ValueError(
                        f"aot bucket {b} exceeds the page-table reach "
                        f"({self.max_pages_per_seq} pages x "
                        f"{self.page_size} = {reach} tokens): no prompt "
                        "that long can ever be admitted")
            object.__setattr__(self, "aot_buckets", buckets)

    # ------------------------------------------------------------------
    # the ONE flag surface (launch.serve and every benchmark share it)
    # ------------------------------------------------------------------

    @staticmethod
    def add_args(ap) -> None:
        """Register the serving flags on an ``argparse`` parser — the
        single authoritative flag set (``from_args`` consumes it)."""
        ap.add_argument("--page-size", type=int, default=8)
        ap.add_argument("--num-pages", type=int, default=64,
                        help="small pools force preemption (context "
                             "switches)")
        ap.add_argument("--max-batch", type=int, default=4)
        ap.add_argument("--max-horizon", type=int, default=8,
                        help="fused decode horizon cap: up to K chained "
                             "decode steps per dispatch with on-device "
                             "sampling (1 disables fusion)")
        ap.add_argument("--serve-mesh", default="off",
                        help="shard the executor's KV pools over a "
                             "('kv','hd') serve mesh: 'auto' factors all "
                             "visible devices, an integer caps the device "
                             "count, 'off' (default) keeps single-device "
                             "placement; Pallas kernels stay LIVE on the "
                             "mesh via shard_map")
        ap.add_argument("--no-prefix-cache", action="store_true",
                        help="disable the radix prefix cache (cold-"
                             "admission baseline)")
        ap.add_argument("--no-kernels", action="store_true",
                        help="explicit escape hatch: dispatch every "
                             "compute step through the jnp reference twin "
                             "(counted as ref_path_dispatches)")
        ap.add_argument("--kv-dtype", choices=("native", "int8"),
                        default="native",
                        help="KV pool storage dtype: int8 stores "
                             "quantized pages; the paged-attention "
                             "kernels dequantize in VMEM "
                             "(quant_dispatches)")
        ap.add_argument("--aot-buckets", default="off",
                        help="comma-separated prompt-length buckets to "
                             "AOT-compile prefill/continuation "
                             "executables for at engine build (e.g. "
                             "'16,32,64'); 'off' keeps the plain jit "
                             "path")

    @classmethod
    def from_args(cls, args, **overrides) -> "ServeConfig":
        """Build a validated config from an ``add_args`` namespace.

        This replaces the per-call-site flag re-parsing that used to live
        in ``launch.serve`` (manual ServeConfig construction, a separate
        mesh block, ad-hoc stats headers): one parse, one validation, one
        ``describe()``.  ``overrides`` wins over flags (callers computing
        ``max_pages_per_seq`` from the workload pass it here).
        """
        buckets: tuple[int, ...] | None = None
        raw = getattr(args, "aot_buckets", "off")
        if raw not in (None, "", "off"):
            try:
                buckets = tuple(int(b) for b in str(raw).split(","))
            except ValueError:
                raise ValueError(
                    f"--aot-buckets must be a comma-separated int list or "
                    f"'off', got {raw!r}") from None
        fields = dict(
            page_size=args.page_size,
            num_pages=args.num_pages,
            max_batch=args.max_batch,
            max_horizon=args.max_horizon,
            use_ref_path=args.no_kernels,
            prefix_cache=not args.no_prefix_cache,
            kv_dtype=args.kv_dtype,
            serve_mesh=args.serve_mesh,
            aot_buckets=buckets,
        )
        if hasattr(args, "seed"):
            fields["seed"] = args.seed
        fields.update(overrides)
        return cls(**fields)

    def describe(self) -> str:
        """The shared stats header: one canonical rendering of the
        config, printed by ``launch.serve`` and the benchmarks."""
        compute = "jnp-ref (explicit hatch)" if self.use_ref_path \
            else "pallas kernels"
        buckets = ",".join(str(b) for b in self.aot_buckets) \
            if self.aot_buckets else "off (shape-keyed jit)"
        return (
            f"serve config: page_size={self.page_size} "
            f"num_pages={self.num_pages} (1 scratch) "
            f"max_pages_per_seq={self.max_pages_per_seq} "
            f"max_batch={self.max_batch} max_horizon={self.max_horizon}\n"
            f"  compute: {compute}, kv_dtype={self.kv_dtype}, "
            f"prefix_cache={'on' if self.prefix_cache else 'off'}, "
            f"sampling={'greedy' if self.greedy else f'T={self.temperature}'}"
            f"\n  aot prefill buckets: {buckets}\n"
            f"  serve mesh: {self.serve_mesh}"
        )

    def build_mesh(self, model_cfg):
        """Resolve ``serve_mesh`` to a concrete ('kv','hd') mesh (or
        ``None``) — the one place the flag is interpreted."""
        if self.serve_mesh in (None, "off"):
            return None
        from repro.launch.mesh import make_host_serve_mesh
        n_dev = None if self.serve_mesh == "auto" else int(self.serve_mesh)
        return make_host_serve_mesh(
            model_cfg.num_kv_heads, model_cfg.head_dim, n_dev
        )


class RestoreFailure(RuntimeError):
    """A data plane's restore transiently failed (device OOM, transfer
    error, an injected fault).  The contract: the plane must raise BEFORE
    any side effect (no pages re-mapped, no bytes moved), so the scheduler
    can leave the victim at the head of the swap FIFO and retry on a later
    step.  Counted as ``restore_failures``."""


@dataclasses.dataclass
class ReplicaState:
    """All per-replica mutable scheduling state, in one introspectable
    object.

    Factored out of :class:`Scheduler` so a multi-replica router can hold
    N of these (one per data plane) and reason about them uniformly —
    request conservation, page accounting, clock skew — while the
    scheduler's policy methods stay exactly the single-replica code.  The
    scheduler exposes the historical attribute names (``queue``,
    ``running``, ``step_i``, ...) as properties over this object, so the
    N=1 path is byte-for-byte the pre-router behavior.
    """

    replica_id: int = 0
    queue: deque[Request] = dataclasses.field(default_factory=deque)
    swapped: deque[int] = dataclasses.field(default_factory=deque)
    running: dict[int, Request] = dataclasses.field(default_factory=dict)
    done: dict[int, Request] = dataclasses.field(default_factory=dict)
    slot_of: dict[int, int] = dataclasses.field(default_factory=dict)
    swap_requests: dict[int, Request] = dataclasses.field(
        default_factory=dict)
    spilled_tokens: dict[int, int] = dataclasses.field(default_factory=dict)
    #: spill-time provenance: the victim's leading frames that WERE the
    #: pinned prefix's frames (refcount-shared).  A restore re-shares them
    #: instead of demanding fresh frames — the reason a victim whose full
    #: footprint exceeds the preemptible pool can still be reachable.
    spilled_shared: dict[int, list[int]] = dataclasses.field(
        default_factory=dict)
    #: consecutive capacity-blocked ``try_restore`` passes per FIFO-head
    #: victim — the patience clock that arms a partial restore
    restore_blocked: dict[int, int] = dataclasses.field(default_factory=dict)
    #: partial-restore continuations awaiting re-admission:
    #: ``req_id -> (kept_tokens, evicted_tail_tokens, cache_reg_or_None)``.
    #: The request sits in ``queue`` with its kept prefix still MAPPED
    #: (like the pinned prefix: resident but not running); admission
    #: re-prefills the tail through ``admit_forked_batch``.
    partial_resume: dict[int, tuple[int, np.ndarray, Any]] = (
        dataclasses.field(default_factory=dict))
    step_i: int = 0
    prefix_len: int = 0

    @property
    def num_tracked(self) -> int:
        """Requests this replica currently accounts for (conservation
        checks: submitted == queued + running + swapped + done)."""
        return (len(self.queue) + len(self.running) + len(self.swapped)
                + len(self.done))


@dataclasses.dataclass
class SwapExport:
    """Portable migration record for one spilled request — everything a
    DESTINATION replica needs to adopt the victim.

    ``record`` is the opaque plane-level swap payload (for the real
    executor: the switcher's :class:`~repro.core.context_switch.
    SpilledState`, host bytes in the pool storage dtype — int8 records
    stay narrow).  ``shared_prefix_pages`` carries the pinned-prefix
    provenance as a COUNT, not frame ids: source frame ids mean nothing in
    another pool, so the importer re-resolves the claim against the
    *destination's* prefix mapping (its first k frames hold the same bytes
    under the fleet invariant that every preloaded prefix is identical).
    A destination without a prefix — or with a shorter one — simply
    shrinks the claim to zero and restores every page from the record,
    which carries ALL the victim's pages including the formerly-shared
    leading ones.
    """

    req: Request
    num_tokens: int
    shared_prefix_pages: int
    record: Any


@dataclasses.dataclass
class DecodePlan:
    """Full-slot decode batch: host arrays only, indexed by device slot.

    ``horizon`` is the number of chained decode steps the executor runs in
    one dispatch; ``steps_left[slot]`` is how many of those inner steps the
    lane participates in (it retires — stops writing KV, freezes its
    position — after that many, masked on device).  ``horizon == 1`` is
    exactly the pre-horizon single-step plan.
    """

    tokens: np.ndarray              # [B, ...] last sampled token per slot
    pre_lens: np.ndarray            # [B] position of the new token
    active: np.ndarray              # [B] bool — slots decoding this step
    horizon: int = 1                # fused inner decode steps this dispatch
    steps_left: np.ndarray | None = None   # [B] int32 active steps per lane


class DataPlane(Protocol):
    """The narrow device interface the scheduler drives.

    Implementations: :class:`repro.serve.executor.Executor` (real device
    state) and :class:`HostOnlyPlane` (tests).  Every method is invoked at
    the exact point in the scheduling loop where the seed engine performed
    the equivalent device work, so policy decisions (which frames are free,
    who gets preempted) see identical allocator state.
    """

    def spill(self, req: Request) -> None:
        """Copy the victim's pages out, then free its mapping
        (``vmem.spill_seq``)."""
        ...

    def restore(self, req: Request, num_tokens: int,
                shared_pages: list[int] | None = None) -> None:
        """Re-map the sequence (``vmem.restore_seq``) and copy its pages
        back in.  ``shared_pages``: leading frames to re-share by refcount
        (still resident under the pinned prefix) instead of re-mapping —
        they are neither allocated nor copied."""
        ...

    def discard(self, req: Request) -> None:
        """Drop a spilled request's swap record without restoring it (the
        scheduler failed it); frees any host-side page copies."""
        ...

    def export_swap(self, req: Request) -> Any:
        """Detach ``req``'s swap record as a portable host-side payload
        (cross-replica migration source side).  After this the plane holds
        NOTHING for the request — the record rides the
        :class:`SwapExport`."""
        ...

    def import_swap(self, req: Request, record: Any) -> None:
        """Adopt a swap record exported from another replica's plane
        (migration destination side).  Must raise BEFORE any side effect
        on rejection, so the router can re-import at the source."""
        ...

    def admit_forked_batch(
        self, reqs: list[Request], start_lens: list[int],
        tail_copies: list[tuple[int, int] | None],
    ) -> list[Any]:
        """COW tail-page copies + ONE batched continuation prefill of all
        ``reqs[i].prompt`` chunks at offsets ``start_lens[i]``; returns the
        first sampled token per request (request order)."""
        ...

    # -- compute surface (lets Scheduler.step_plane drive a full engine
    # -- step against ANY plane: the Executor, a host stub, a fault fake)

    def prefill(self, reqs: list[Request]) -> list[Any]:
        """Batched prefill of freshly admitted requests; returns the first
        sampled token per request (request order)."""
        ...

    def decode(self, tokens: np.ndarray, pre_lens: np.ndarray,
               active: np.ndarray) -> np.ndarray:
        """One full-slot decode step; returns sampled tokens by slot."""
        ...

    def decode_multi(self, plan: DecodePlan) -> np.ndarray:
        """Fused K-step decode horizon; returns the ``[K, B, ...]`` token
        block (step-major)."""
        ...


class HostOnlyPlane:
    """Data-plane stub: page-table bookkeeping only, no arrays.

    Lets scheduler unit tests exercise admission order, victim policy and
    fork accounting on a bare :class:`VirtualMemory`.  Records every call
    in ``events`` for assertions.
    """

    def __init__(self, vmem: VirtualMemory):
        self.vmem = vmem
        self.events: list[tuple] = []

    def spill(self, req: Request) -> None:
        self.events.append(("spill", req.req_id))
        self.vmem.spill_seq(req.req_id)

    def restore(self, req: Request, num_tokens: int,
                shared_pages: list[int] | None = None) -> None:
        self.events.append(("restore", req.req_id))
        self.vmem.restore_seq(req.req_id, num_tokens, shared_pages)

    def discard(self, req: Request) -> None:
        self.events.append(("discard", req.req_id))

    def export_swap(self, req: Request):
        self.events.append(("export_swap", req.req_id))
        return ("swap_record", req.req_id)

    def import_swap(self, req: Request, record) -> None:
        self.events.append(("import_swap", req.req_id))

    def admit_forked_batch(self, reqs, start_lens, tail_copies):
        self.events.append(
            ("admit_forked_batch", [r.req_id for r in reqs])
        )
        for req, start, tail in zip(reqs, start_lens, tail_copies):
            self.events.append(("admit_forked", req.req_id, start, tail))
        return [np.int32(0)] * len(reqs)

    # compute surface: all-zero token streams, enough for step_plane loops

    def prefill(self, reqs):
        self.events.append(("prefill", [r.req_id for r in reqs]))
        return [np.int32(0)] * len(reqs)

    def decode(self, tokens, pre_lens, active):
        self.events.append(("decode", int(active.sum())))
        return np.zeros(np.shape(tokens), np.int32)

    def decode_multi(self, plan):
        self.events.append(("decode_multi", plan.horizon))
        return np.zeros((plan.horizon,) + np.shape(plan.tokens), np.int32)


class Scheduler:
    """Continuous-batching policy: queues, admission, preemption, forks.

    Mirrors the seed engine's policy decisions exactly (same admission
    order, same victim key ``(remaining, -arrival)``, same FIFO restore)
    so the refactored engine is token-for-token equivalent; only the data
    plane changed.
    """

    def __init__(self, cfg: ServeConfig, vmem: VirtualMemory,
                 cost: CostModel | None = None,
                 counters: PerfCounters | None = None,
                 replica_id: int = 0):
        self.cfg = cfg
        self.vmem = vmem
        self.cost = cost or CostModel()
        self.counters = counters or PerfCounters()
        #: every piece of per-replica mutable scheduling state lives here
        #: (the router holds N of these); the properties below keep the
        #: historical single-replica attribute surface intact.
        self.state = ReplicaState(replica_id=replica_id)
        #: shared-prefix ("system prompt") support: one resident sequence
        #: whose whole pages are refcount-shared into forked requests.
        self.PREFIX_ID = -1
        self.plane: DataPlane | None = None
        #: radix index over resident token runs — admission probes it and
        #: COW-maps matched whole pages (no fork API needed).  Eviction is
        #: wired to the vmem unmap hook so the trie tracks residency (and
        #: therefore refcount drops) automatically.
        self.prefix_cache: PrefixCache | None = (
            PrefixCache(cfg.page_size) if cfg.prefix_cache else None
        )
        if self.prefix_cache is not None:
            vmem.add_unmap_hook(self.prefix_cache.release)
        #: optional stream sink (an AsyncDetokenizer): every committed
        #: token of a stream_callback-bearing request is pushed here, AT
        #: the commit point, AFTER the timing stamps — so delivery lag
        #: can never skew TTFT/TPOT.
        self.stream = None

    def attach_plane(self, plane: DataPlane) -> None:
        self.plane = plane

    def attach_stream(self, stream) -> None:
        """Attach the async detokenize/stream sink (push-only duck type:
        ``stream.push(req, token, final)``)."""
        self.stream = stream

    def _emit(self, req: Request, token: Any, final: bool) -> None:
        if self.stream is not None and req.stream_callback is not None:
            self.stream.push(req, token, final)

    def _stamp_commit(self, req: Request, now: float) -> None:
        """Timing capture point: the host-visible commit of a sampled
        token (finish_prefill / _flush_forked / commit_decode) — NEVER
        the detokenize thread.  Also tracks the peak mapped footprint."""
        if req.t_first_token == 0.0:
            req.t_first_token = now
        req.t_last_token = now
        if self.vmem.has_seq(req.req_id):
            req.pages_peak = max(req.pages_peak,
                                 len(self.vmem.seq(req.req_id).pages))

    # ------------------------------------------------------------------
    # per-replica state (delegated to ReplicaState)
    # ------------------------------------------------------------------

    @property
    def replica_id(self) -> int:
        return self.state.replica_id

    @property
    def queue(self) -> deque[Request]:
        return self.state.queue

    @property
    def swapped(self) -> deque[int]:
        return self.state.swapped

    @property
    def running(self) -> dict[int, Request]:
        return self.state.running

    @property
    def done(self) -> dict[int, Request]:
        return self.state.done

    @property
    def slot_of(self) -> dict[int, int]:
        return self.state.slot_of

    @property
    def _swap_requests(self) -> dict[int, Request]:
        return self.state.swap_requests

    @property
    def _spilled_tokens(self) -> dict[int, int]:
        return self.state.spilled_tokens

    @property
    def step_i(self) -> int:
        return self.state.step_i

    @step_i.setter
    def step_i(self, v: int) -> None:
        self.state.step_i = v

    @property
    def prefix_len(self) -> int:
        return self.state.prefix_len

    @prefix_len.setter
    def prefix_len(self, v: int) -> None:
        self.state.prefix_len = v

    # ------------------------------------------------------------------
    # queue API
    # ------------------------------------------------------------------

    @property
    def has_work(self) -> bool:
        return bool(self.queue or self.running or self.swapped)

    def submit(self, req: Request) -> None:
        req.arrival = self.step_i
        if req.t_enqueue == 0.0:      # router may have stamped queue entry
            req.t_enqueue = time.perf_counter()
        self.queue.append(req)
        self.counters.inc("submitted")
        self.counters.snapshot("submit", req.req_id)

    def begin_step(self) -> None:
        self.step_i += 1
        if self.step_i % self.cfg.tick_every_steps == 0:
            # 100 Hz scheduler tick accounting (paper §3.1)
            self.counters.inc("ticks")
            self.counters.inc(
                "modeled_tick_cycles", self.cost.sched_tick_cycles
            )

    def step_plane(self) -> None:
        """One full engine step against the attached :class:`DataPlane`.

        The canonical serving step — restore, admit (+prefill), plan a
        fused horizon, decode, commit — factored out of ``Engine.step`` so
        the single-replica engine, the multi-replica router and the fake-
        plane test harnesses all drive the exact same loop.  Each replica
        of a router runs this independently; nothing here reads any state
        outside ``self``/the plane, which is what makes N replicas
        trivially isolated.
        """
        self.begin_step()
        self.try_restore()
        admitted = self.admit()
        if admitted:
            first = self.plane.prefill(admitted)
            self.finish_prefill(admitted, first)
        # plan_decode picks a fused horizon K (1 under pool pressure or
        # pending admissions/restores) and pre-faults every page K steps
        # will touch in one batched allocation
        plan = self.plan_decode()
        if plan is not None:
            if plan.horizon > 1:
                block = self.plane.decode_multi(plan)
                self.commit_decode(block, horizon=plan.horizon)
            else:
                sampled = self.plane.decode(
                    plan.tokens, plan.pre_lens, plan.active
                )
                self.commit_decode(sampled)

    # ------------------------------------------------------------------
    # reach checks (livelock prevention)
    # ------------------------------------------------------------------

    def attainable_pages(self) -> int:
        """Frames preemption could EVER free: the pool minus the DISTINCT
        physical frames pinned by the resident shared prefix (never a
        preemption victim).  Counted as a frame set, not per mapping —
        a frame refcount-shared into K running sequences is still ONE
        pinned frame, deducted once."""
        if self.vmem.has_seq(self.PREFIX_ID):
            pinned = len({int(p)
                          for p in self.vmem.seq(self.PREFIX_ID).pages})
        else:
            pinned = 0
        return self.vmem.pool.num_pages - pinned

    def _pinned_shared_pages(self, owner: int | None, matched: int) -> int:
        """Of the ``matched // page_size`` frames a radix hit would share
        from ``owner``, how many are PINNED-prefix frames.

        These frames already sit inside :meth:`attainable_pages`' pinned
        deduction, so counting them against the request's own demand
        would charge one physical frame once per sharer (the satellite-1
        accounting bug).  Frames shared with a *non-pinned* owner are NOT
        deducted: they must still coexist with the request's footprint
        inside the preemptible pool, so they legitimately count."""
        if not matched or owner is None:
            return 0
        if not (self.vmem.has_seq(owner)
                and self.vmem.has_seq(self.PREFIX_ID)):
            return 0
        pinned = set(self.vmem.seq(self.PREFIX_ID).pages)
        whole = matched // self.cfg.page_size
        return sum(1 for p in self.vmem.seq(owner).pages[:whole]
                   if p in pinned)

    def _admission_unreachable(self, req: Request, matched: int = 0,
                               owner: int | None = None) -> bool:
        """True if ``req`` could never run mapped to completion: its
        lifetime page demand (prompt + every future token, fork/radix
        sharing included) exceeds what preemption can ever free, or the
        page-table reach.  Admitting it ends either in a restore livelock
        (if it is ever spilled) or in a degraded scratch-routed decode
        tail — fail fast at admission instead.

        The demand counts each PHYSICAL frame once: frames shared with
        the pinned prefix (directly for forks, through the radix owner's
        leading pages for prefix hits) are already inside the
        :meth:`attainable_pages` deduction and cost the request nothing.
        """
        pf = self.vmem.config.pages_for
        # The FINAL sampled token is never grown into the table — the
        # request retires inside commit_decode — so the mapped lifetime is
        # one token short of prompt + max_new (floor: the first decode
        # position is always mapped, even for max_new == 1).
        gen = max(req.max_new_tokens, 2) - 1
        if req.share_prefix:
            lifetime = self.prefix_len + len(req.prompt) + gen
            shared = self.prefix_len // self.cfg.page_size
        else:
            lifetime = len(req.prompt) + gen
            shared = self._pinned_shared_pages(owner, matched)
        own = pf(lifetime) - shared
        return (lifetime > self.vmem.config.max_tokens_per_seq
                or own > self.attainable_pages())

    def _fail(self, req: Request, reason: str) -> None:
        """Terminal parking for a request that can never fit (reach check):
        surfaced through ``done`` with status ``failed`` so callers see it
        and ``run()`` terminates instead of spinning until ``max_steps``."""
        req.status = "failed"
        req.t_last_token = time.perf_counter()
        if req.t_first_token == 0.0:
            req.t_first_token = req.t_last_token
        self.done[req.req_id] = req
        self.counters.inc("failed_unreachable")
        self.counters.snapshot("failed_" + reason, req.req_id)
        # streams always terminate: a failed request still gets a final
        # event (token=None) so a client waiting on `final` never hangs
        self._emit(req, None, final=True)

    # ------------------------------------------------------------------
    # restore (swap-in)
    # ------------------------------------------------------------------

    def _restorable_shared(self, req_id: int) -> list[int]:
        """The spill-time pinned-prefix frames of ``req_id`` that are
        STILL the prefix's leading frames — the portion of a restore that
        re-shares by refcount instead of allocating.  Validated against
        the live prefix mapping each call, so a stale provenance record
        can only shrink the claim, never corrupt a restore."""
        shared = self.state.spilled_shared.get(req_id)
        if not shared or not self.vmem.has_seq(self.PREFIX_ID):
            return []
        pre = self.vmem.seq(self.PREFIX_ID).pages
        if len(shared) <= len(pre) and shared == pre[:len(shared)]:
            return list(shared)
        return []

    def can_restore(self, req_id: int) -> bool:
        if req_id not in self._spilled_tokens:
            return False
        need = (self.vmem.config.pages_for(self._spilled_tokens[req_id])
                - len(self._restorable_shared(req_id)))
        return (self.vmem.pool.num_free >= need
                and self.vmem.num_free_slots > 0)

    def _commit_restore(self, req_id: int, req: Request,
                        shared: list[int]) -> None:
        """Shared tail of every successful full restore (the caller has
        already removed ``req_id`` from the ``swapped`` deque)."""
        del self._swap_requests[req_id]
        del self._spilled_tokens[req_id]
        self.state.spilled_shared.pop(req_id, None)
        self.state.restore_blocked.pop(req_id, None)
        if shared:
            self.counters.inc("shared_restores")
            self.counters.inc("pages_reused", len(shared))
        req.status = "running"
        self.running[req_id] = req
        self.slot_of[req_id] = self.vmem.seq(req_id).slot
        self.counters.inc("restores")
        self.counters.snapshot("restore", req_id)

    def try_restore(self) -> list[Request]:
        restored: list[Request] = []
        for _ in range(len(self.swapped)):
            req_id = self.swapped[0]
            # Reach check, re-evaluated on every pass: the victim's
            # pinned-prefix-shared run restores by RE-SHARING the still-
            # resident frames (no fresh allocation), so only the unshared
            # remainder demands frames preemption could free.  Only when
            # that remainder can never fit is the victim truly
            # unreachable — otherwise the FIFO head would block the swap
            # queue until ``run(max_steps)`` expires (the ROADMAP
            # livelock) — fail it then, and only then.  (Under a router
            # the migration sweep runs FIRST, so this verdict only lands
            # when no replica can host the adjusted demand.)
            shared = self._restorable_shared(req_id)
            need = (self.vmem.config.pages_for(self._spilled_tokens[req_id])
                    - len(shared))
            if need > self.attainable_pages():
                self.swapped.popleft()
                self._spilled_tokens.pop(req_id)
                self.state.spilled_shared.pop(req_id, None)
                self.state.restore_blocked.pop(req_id, None)
                req = self._swap_requests.pop(req_id)
                self.plane.discard(req)    # free the host-side swap record
                self._fail(req, "restore")
                continue
            if len(self.running) >= self.cfg.max_batch:
                break
            if not self.can_restore(req_id):
                # Capacity-blocked head: strict FIFO wait, but after
                # ``restore_patience`` consecutive blocked passes stop
                # waiting for the all-or-nothing restore and bring back
                # the longest page-aligned prefix that fits RIGHT NOW
                # (the evicted tail re-prefills through the continuation
                # path once admission finds it pages — with preemption
                # power a restore never has).
                blocked = self.state.restore_blocked.get(req_id, 0) + 1
                self.state.restore_blocked[req_id] = blocked
                if (self.cfg.restore_patience > 0
                        and blocked >= self.cfg.restore_patience
                        and self._try_partial_restore(req_id, shared)):
                    continue
                break
            req = self._swap_requests[req_id]
            try:
                self.plane.restore(req, self._spilled_tokens[req_id],
                                   shared_pages=shared or None)
            except RestoreFailure:
                # Transient data-plane failure, raised before any side
                # effect (the RestoreFailure contract): leave the victim
                # at the FIFO head and retry on a later step — but give
                # the victims queued BEHIND it a bounded second chance,
                # or a head pinned to a failing plane starves the queue.
                self.counters.inc("restore_failures")
                self.counters.snapshot("restore_failure", req_id)
                self._second_chance_scan(restored)
                break
            self.swapped.popleft()
            self._commit_restore(req_id, req, shared)
            restored.append(req)
        return restored

    def _second_chance_scan(self, restored: list[Request]) -> None:
        """Bounded scan past a ``RestoreFailure``-blocked FIFO head
        (mirroring the admission scan's bounded look-ahead): fully restore
        up to ``restore_scan_limit`` later victims that fit, WITHOUT
        popping the head — it keeps its FIFO position and retries first on
        the next pass, so completion-order guarantees never invert, the
        queue just stops starving behind one pinned victim."""
        scanned = 0
        i = 1
        while i < len(self.swapped) and scanned < self.cfg.restore_scan_limit:
            if len(self.running) >= self.cfg.max_batch:
                break
            req_id = self.swapped[i]
            scanned += 1
            if not self.can_restore(req_id):
                i += 1
                continue
            shared = self._restorable_shared(req_id)
            req = self._swap_requests[req_id]
            try:
                self.plane.restore(req, self._spilled_tokens[req_id],
                                   shared_pages=shared or None)
            except RestoreFailure:
                self.counters.inc("restore_failures")
                self.counters.snapshot("restore_failure", req_id)
                i += 1
                continue
            del self.swapped[i]
            self._commit_restore(req_id, req, shared)
            self.counters.inc("second_chance_restores")
            restored.append(req)

    def _try_partial_restore(self, req_id: int, shared: list[int]) -> bool:
        """Restore the longest page-aligned prefix of the FIFO-head victim
        that fits the pool now (re-sharing ``shared`` pinned frames),
        consume its swap record, and re-enqueue the request at the queue
        FRONT as a partial-resume continuation — admission re-prefills the
        evicted tail through ``admit_forked_batch`` (causal KV is a pure
        function of the token prefix, so the recompute is exact) and drops
        the recomputed chunk's sampled token, which the stream already
        carries.  Returns False (leaving full-restore waiting in place)
        whenever the tail is not host-reconstructable or nothing useful
        fits."""
        if (self.state.partial_resume      # one outstanding continuation:
                # stacked kept-but-idle mappings could exhaust the pool
                # with nothing running (hence nothing preemptible)
                or self.vmem.num_free_slots <= 0
                or len(self.running) >= self.cfg.max_batch
                or np.ndim(self._swap_requests[req_id].prompt) != 1):
            return False
        page = self.cfg.page_size
        req = self._swap_requests[req_id]
        spilled = self._spilled_tokens[req_id]
        total_pages = self.vmem.config.pages_for(spilled)
        keep_pages = min(len(shared) + self.vmem.pool.num_free,
                         total_pages - 1)
        keep = keep_pages * page
        base = req.prefix_len
        # the tail must be reconstructable from prompt+output alone —
        # positions below prefix_len belong to the (fork/radix) parent
        if (keep_pages < 1 or keep_pages < len(shared) or keep < base
                or keep >= spilled):
            return False
        try:
            stream = np.concatenate([
                np.asarray(req.prompt, np.int32).reshape(-1),
                np.asarray([int(np.asarray(t)) for t in req.output],
                           np.int32),
            ])
        except (TypeError, ValueError, OverflowError):
            return False
        if spilled - base > len(stream):
            return False
        tail = stream[keep - base: spilled - base]
        if tail.size == 0:
            return False
        try:
            self.plane.restore(req, keep, shared_pages=shared or None)
        except RestoreFailure:
            self.counters.inc("restore_failures")
            self.counters.snapshot("restore_failure", req_id)
            return False
        # full committed content, for re-registering the restored run
        # with the radix cache at resume time (best effort: a fork's
        # leading positions come from the registered prefix tokens)
        reg = None
        if self.prefix_cache is not None:
            if base == 0:
                reg = stream[:spilled]
            else:
                pre = self.prefix_cache.tokens_of(self.PREFIX_ID)
                if (req.share_prefix and pre is not None
                        and np.ndim(pre) == 1 and len(pre) >= base):
                    reg = np.concatenate(
                        [np.asarray(pre, np.int32)[:base], stream]
                    )[:spilled]
        self.swapped.popleft()
        del self._swap_requests[req_id]
        del self._spilled_tokens[req_id]
        self.state.spilled_shared.pop(req_id, None)
        self.state.restore_blocked.pop(req_id, None)
        if shared:
            self.counters.inc("shared_restores")
            self.counters.inc("pages_reused", len(shared))
        req.status = "queued"
        self.state.partial_resume[req_id] = (keep, tail, reg)
        self.queue.appendleft(req)     # keeps the victim's FIFO priority
        self.counters.inc("partial_restores")
        self.counters.snapshot("partial_restore", (req_id, keep))
        return True

    # ------------------------------------------------------------------
    # cross-replica swap migration (router-driven)
    # ------------------------------------------------------------------

    def export_swapped(self, req_id: int) -> SwapExport:
        """Detach a spilled victim for migration to another replica: pops
        every piece of swap bookkeeping AND the plane's swap record, so
        this replica keeps no reference (the satellite leak audit's
        migration-source path).  The pinned-prefix provenance travels as a
        page COUNT — the destination re-resolves it against its own prefix
        mapping (:meth:`import_swapped`)."""
        if req_id not in self._swap_requests:
            raise KeyError(f"req {req_id} is not swapped on replica "
                           f"{self.replica_id}")
        self.swapped.remove(req_id)
        req = self._swap_requests.pop(req_id)
        num_tokens = self._spilled_tokens.pop(req_id)
        k = len(self.state.spilled_shared.pop(req_id, []) or [])
        self.state.restore_blocked.pop(req_id, None)
        record = self.plane.export_swap(req)
        self.counters.inc("swap_exports")
        return SwapExport(req=req, num_tokens=num_tokens,
                          shared_prefix_pages=k, record=record)

    def import_swapped(self, exp: SwapExport, front: bool = False) -> None:
        """Adopt a migrated victim: hand the plane its swap record (which
        must raise BEFORE side effects on rejection — the router then
        re-imports at the source) and re-resolve the pinned-prefix claim
        against THIS replica's prefix: its first k whole pages hold the
        same bytes as the source prefix's under the fleet invariant that
        preloaded prefixes are identical; a missing/shorter prefix just
        shrinks the claim and the restore moves those pages from the
        record instead.  ``front=True`` preserves FIFO priority (rollback
        re-imports at the source head)."""
        rid = exp.req.req_id
        self.plane.import_swap(exp.req, exp.record)   # may raise: no-op then
        exp.req.status = "swapped"
        if front:
            self.swapped.appendleft(rid)
        else:
            self.swapped.append(rid)
        self._swap_requests[rid] = exp.req
        self._spilled_tokens[rid] = exp.num_tokens
        shared: list[int] = []
        k = exp.shared_prefix_pages
        if k and self.vmem.has_seq(self.PREFIX_ID):
            pre = self.vmem.seq(self.PREFIX_ID).pages
            if k <= min(len(pre), self.prefix_len // self.cfg.page_size):
                shared = [int(p) for p in pre[:k]]
        self.state.spilled_shared[rid] = shared
        self.counters.inc("swap_imports")

    # ------------------------------------------------------------------
    # preemption (context-switch policy)
    # ------------------------------------------------------------------

    def select_victim(self, protect: int | None = None) -> Request | None:
        """Policy: most remaining work (cheapest to delay), oldest last."""
        victims = [r for rid, r in self.running.items() if rid != protect]
        if not victims:
            return None
        return max(victims, key=lambda r: (r.remaining, -r.arrival))

    def preempt_for(self, pages_needed: int,
                    protect: int | None = None) -> bool:
        """Spill victims until ``pages_needed`` frames are free."""
        while self.vmem.pool.num_free < pages_needed:
            victim = self.select_victim(protect)
            if victim is None:
                return False
            self.spill(victim)
        return True

    def _pinned_prefix_frames(self, req_id: int) -> list[int]:
        """Leading frames of ``req_id`` that ARE the pinned prefix's frames
        (positionally identical — fork and radix sharing both preserve the
        logical page index).  Whole shared pages are immutable while
        refcounted and the prefix is never unmapped, so these frames stay
        resident with identical bytes for the life of the engine: a later
        restore may re-share them instead of demanding fresh frames."""
        if not (self.vmem.has_seq(self.PREFIX_ID)
                and self.vmem.has_seq(req_id)):
            return []
        own = self.vmem.seq(req_id).pages
        pre = self.vmem.seq(self.PREFIX_ID).pages
        k = 0
        while k < min(len(own), len(pre)) and own[k] == pre[k]:
            k += 1
        return [int(p) for p in own[:k]]

    def spill(self, victim: Request) -> None:
        self._spilled_tokens[victim.req_id] = self.vmem.seq_len(victim.req_id)
        # provenance BEFORE the plane frees the mapping: which leading
        # frames were pinned-prefix shares (restorable by re-sharing)
        self.state.spilled_shared[victim.req_id] = (
            self._pinned_prefix_frames(victim.req_id)
        )
        self.plane.spill(victim)       # copies pages out + frees the mapping
        victim.status = "swapped"
        self.swapped.append(victim.req_id)
        self._swap_requests[victim.req_id] = victim
        del self.running[victim.req_id]
        del self.slot_of[victim.req_id]
        self.counters.inc("preemptions")
        self.counters.snapshot("preempt", victim.req_id)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def required_pages(self, req: Request) -> int:
        return self.vmem.config.pages_for(len(req.prompt) + 1)

    def probe_prefix(self, req: Request) -> tuple[int, int | None]:
        """Longest radix-cached resident prefix a cold admission of
        ``req`` could COW-share: ``(matched_tokens, owner_seq_id)``.

        Page-aligned, capped so at least one prompt token survives as the
        continuation chunk (its logits seed the first sampled token), and
        validated against the owner's live mapping.  ``(0, None)`` for
        explicit forks (they share through the fork path) and on a miss.
        Pure — safe for the router to call when ranking replicas.
        """
        if (self.prefix_cache is None or req.share_prefix
                or req.prefix_len or len(req.prompt) <= 1):
            return 0, None
        matched, owner = self.prefix_cache.match(req.prompt)
        cap = ((len(req.prompt) - 1) // self.cfg.page_size
               ) * self.cfg.page_size
        matched = min(matched, cap)
        if matched <= 0 or owner is None:
            return 0, None
        if not self.vmem.has_seq(owner) \
                or self.vmem.seq_len(owner) < matched:
            return 0, None
        return matched, owner

    def register_resident(self, seq_id: int, tokens: np.ndarray) -> None:
        """Index an already-committed resident run in the radix cache
        (``Engine.preload_prefix`` calls this for the pinned system
        prefix after its KV is written)."""
        if self.prefix_cache is not None:
            self.prefix_cache.register(seq_id, np.asarray(tokens))

    def admit(self) -> list[Request]:
        """Pop queue-front requests that fit; returns the plain-prefill
        batch.  Forked requests — and radix prefix hits, which reuse the
        same COW machinery — have their page tables forked inline (so
        allocator state evolves in the same order as the seed engine) but
        their continuation prefills are accumulated and issued as ONE
        batched data-plane call per step (``admit_forked_batch``)."""
        admitted: list[Request] = []
        pending: list[
            tuple[Request, int, tuple[int, int] | None, Any,
                  Request | None]] = []
        while self.queue and (
            len(self.running) + len(admitted) + len(pending)
            < self.cfg.max_batch
        ):
            req = self.queue[0]
            if req.req_id in self.state.partial_resume:
                # partial-restore continuation: the kept prefix is already
                # mapped; only the evicted tail needs frames — and HERE the
                # request holds preemption power an in-place restore never
                # had (the whole point of re-enqueueing it).  No reach
                # check: the tail demand is strictly below the admission
                # demand that already passed.
                keep, tail, _ = self.state.partial_resume[req.req_id]
                need = (self.vmem.config.pages_for(keep + len(tail))
                        - len(self.vmem.seq(req.req_id).pages))
                if need > self.vmem.pool.num_free:
                    self._flush_forked(pending)
                    if not self.preempt_for(need, protect=req.req_id):
                        break              # retried at the head next step
                entry = self._resume_bookkeeping(req)
                if entry is None:
                    break
                pending.append(entry)
                self.queue.popleft()
                continue
            matched, owner = self.probe_prefix(req)
            if self._admission_unreachable(req, matched, owner):
                self.queue.popleft()
                self._fail(req, "admit")
                continue
            if matched:
                # the matched whole pages arrive by refcount, not
                # allocation — only the divergent remainder needs frames
                need = (self.required_pages(req)
                        - matched // self.cfg.page_size)
            else:
                need = self.required_pages(req)
            if need > self.vmem.pool.num_free:
                # pending forks must be committed (running) before victim
                # selection so they are preemptible, like the seed's inline
                # admission order
                self._flush_forked(pending)
                if not self.preempt_for(
                        need, protect=owner if matched else None):
                    break                      # nothing left to preempt
            if req.share_prefix:
                entry = self._fork_bookkeeping(req)
                if entry is None:
                    break
                pending.append(entry)
                self.queue.popleft()
                continue
            if matched:
                entry = self._radix_bookkeeping(req, matched, owner)
                if entry is not None:
                    pending.append(entry)
                    self.queue.popleft()
                    continue
                # hit could not be honored (owner raced away / pool
                # exhausted mid-fork): fall through to cold admission
            try:
                self.vmem.map_seq(req.req_id, len(req.prompt))
            except OutOfPagesError:
                break
            self.queue.popleft()
            admitted.append(req)
        self._flush_forked(pending)
        return admitted

    def _resume_bookkeeping(
        self, req: Request
    ) -> tuple[Request, int, tuple[int, int] | None, Any, Request] | None:
        """Map the evicted tail of a partial-restore continuation and build
        its pending entry.  The plane prefills a SHADOW request (the tail
        as prompt, the kept length as prefix) so the real request's
        prompt/output — and therefore ``total_len`` and the committed
        stream — stay untouched; ``_flush_forked`` discards the shadow's
        sampled token, which position arithmetic shows is exactly the last
        committed ``output`` entry (logits at position spilled-1 sample
        position spilled)."""
        keep, tail, reg = self.state.partial_resume[req.req_id]
        try:
            faults = self.vmem.append_tokens(req.req_id, int(len(tail)))
        except OutOfPagesError:
            return None                    # entry stays; retried next step
        del self.state.partial_resume[req.req_id]
        self.counters.inc("pages_refilled", len(faults))
        shadow = dataclasses.replace(
            req, prompt=np.asarray(tail, np.int32), prefix_len=keep,
            output=[], stream_callback=None)
        return (shadow, keep, None, reg, req)

    def _fork_bookkeeping(
        self, req: Request
    ) -> tuple[Request, int, tuple[int, int] | None, Any,
               Request | None] | None:
        """Fork the resident prefix's page table for ``req`` (host state
        only — the data-plane call is deferred to ``_flush_forked``)."""
        page = self.cfg.page_size
        try:
            state = self.vmem.fork_seq(self.PREFIX_ID, req.req_id,
                                       self.prefix_len)
        except OutOfPagesError:
            return None
        tail_copy: tuple[int, int] | None = None
        if self.prefix_len % page:
            # partial tail page is copied; whole pages are shared read-only
            tail_idx = self.prefix_len // page
            parent = self.vmem.seq(self.PREFIX_ID)
            tail_copy = (parent.pages[tail_idx], state.pages[tail_idx])
        try:
            self.vmem.append_tokens(req.req_id, len(req.prompt))
        except OutOfPagesError:
            self.vmem.unmap_seq(req.req_id)    # roll the fork back cleanly
            return None
        self.counters.inc("forked_admissions")
        # the child's committed content is prefix+prompt; register it so
        # later admissions can radix-match THROUGH the fork (content known
        # only if the prefix itself was registered)
        reg = None
        if self.prefix_cache is not None:
            pre = self.prefix_cache.tokens_of(self.PREFIX_ID)
            if pre is not None and np.ndim(pre) == np.ndim(req.prompt):
                try:
                    reg = np.concatenate(
                        [np.asarray(pre)[:self.prefix_len], req.prompt])
                except ValueError:
                    reg = None
        return (req, self.prefix_len, tail_copy, reg, None)

    def _radix_bookkeeping(
        self, req: Request, matched: int, owner: int
    ) -> tuple[Request, int, tuple[int, int] | None, Any,
               Request | None] | None:
        """COW-map the radix-matched whole pages of ``owner`` for ``req``
        (host state only — the continuation prefill is deferred to
        ``_flush_forked``).  ``req.prompt`` is sliced to the unmatched
        chunk and ``prefix_len`` takes the matched length, so every
        downstream length computation (``total_len``, decode positions,
        the continuation offsets) is the forked-admission arithmetic
        unchanged.  Returns None when the hit cannot be honored — the
        caller falls back to cold admission."""
        if not self.vmem.has_seq(owner) \
                or self.vmem.seq_len(owner) < matched:
            return None
        full = req.prompt
        try:
            # page-aligned: shares matched//page_size whole pages, no tail
            self.vmem.fork_seq(owner, req.req_id, matched)
        except OutOfPagesError:
            return None
        try:
            self.vmem.append_tokens(req.req_id, len(full) - matched)
        except OutOfPagesError:
            self.vmem.unmap_seq(req.req_id)    # roll the fork back cleanly
            return None
        req.prompt = full[matched:]
        self.counters.inc("prefix_hits")
        self.counters.inc("pages_reused", matched // self.cfg.page_size)
        self.counters.inc("prefill_tokens_skipped", matched)
        self.counters.snapshot("prefix_hit", (req.req_id, matched))
        return (req, matched, None, full, None)

    def _flush_forked(
        self,
        pending: list[tuple[Request, int, tuple[int, int] | None, Any,
                            Request | None]],
    ) -> None:
        """Run all pending forked/radix-hit admissions — and partial-
        restore continuations — as ONE batched continuation prefill and
        commit them to ``running`` (request order).  Each entry's
        registration tokens (the request's full committed content) enter
        the radix cache only HERE — after the plane call wrote the chunk's
        KV — so a same-step admission can never match pages whose KV is
        not yet committed.

        Resume entries (5th element set) prefilled a SHADOW request: the
        REAL request goes running with its stream untouched, and the
        shadow's sampled token is dropped — the recomputed chunk ends at
        position ``spilled-1``, whose logits sample position ``spilled``,
        a token the stream committed before the spill."""
        if not pending:
            return
        reqs = [e[0] for e in pending]
        firsts = self.plane.admit_forked_batch(
            reqs, [e[1] for e in pending], [e[2] for e in pending]
        )
        now = time.perf_counter()
        for (req, start_len, _, reg, orig), first in zip(pending, firsts):
            if orig is not None:
                req = orig                  # commit the REAL request;
                                            # `first` is discarded (above)
            else:
                req.prefix_len = start_len
                req.output.append(first)
            req.status = "running"
            self.running[req.req_id] = req
            self.slot_of[req.req_id] = self.vmem.seq(req.req_id).slot
            if reg is not None and self.prefix_cache is not None:
                self.prefix_cache.register(req.req_id, reg)
            self._stamp_commit(req, now)
            if orig is None:
                self._emit(req, req.output[-1], final=False)
        self.counters.inc("fork_batches")
        pending.clear()

    def finish_prefill(self, reqs: list[Request], first_tokens: Any) -> None:
        """Commit a plain-prefill batch: mark running, record accounting.
        The prompts enter the radix cache here — the plane call that
        committed their KV has completed."""
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.status = "running"
            r.output.append(np.asarray(first_tokens[i]))
            self.running[r.req_id] = r
            self.slot_of[r.req_id] = self.vmem.seq(r.req_id).slot
            if self.prefix_cache is not None:
                self.prefix_cache.register(r.req_id, r.prompt)
            self._stamp_commit(r, now)
            self._emit(r, r.output[-1], final=False)
        lens = [len(r.prompt) for r in reqs]
        self.counters.inc("prefill_tokens", int(sum(lens)))
        self.counters.inc("prefill_translation_bursts", int(
            sum(self.vmem.config.pages_for(int(x)) for x in lens)
        ))
        self.counters.snapshot("prefill", [r.req_id for r in reqs])

    # ------------------------------------------------------------------
    # decode planning
    # ------------------------------------------------------------------

    def grow_running(self) -> None:
        """Fault in pages for every running sequence's next position,
        preempting victims when the pool is exhausted (idempotent: a
        restore may already cover the position)."""
        for req_id in list(self.running):
            r = self.running.get(req_id)
            if r is None:
                continue  # spilled by an earlier victim selection this step
            grow = r.total_len - self.vmem.seq_len(req_id)
            if grow <= 0:
                continue
            try:
                faults = self.vmem.append_tokens(req_id, grow)
            except OutOfPagesError:
                if not self.preempt_for(1, protect=req_id):
                    # Stays running; retried next step.  Decode proceeds
                    # anyway (seed semantics): the executor routes writes
                    # at unmapped positions to the scratch frame, so the
                    # request keeps producing tokens and terminates — this
                    # is degraded, not deadlocked.  (The genuinely
                    # unterminating cases — admission and restore of
                    # requests whose demand can never be met — are failed
                    # by the reach checks above.)
                    continue
                faults = self.vmem.append_tokens(req_id, grow)
            if faults:
                self.counters.inc("page_faults", len(faults))
                self.counters.inc(
                    "modeled_fault_cycles",
                    len(faults) * (self.cost.ptw_cycles
                                   + self.cost.post_fault_flush_cycles),
                )

    @staticmethod
    def _steps_until_retire(r: Request) -> int:
        """Decode steps before ``r`` retires: it commits one token per step
        and retires when ``len(output) >= max_new_tokens`` — checked AFTER
        the append, so even a satisfied request decodes once more (seed
        semantics; the reason the floor is 1)."""
        return max(1, r.remaining)

    def plan_horizon(self) -> int:
        """Safe fused-decode horizon K for this step (pure policy — no
        allocation happens here).

        The scalar/OS plane may stay off the data path for K tokens iff no
        scheduler event can become due mid-horizon: pending admissions and
        restores collapse K to 1, because every retirement changes the
        slot/frame availability their policy reads.  Otherwise K is capped
        by the longest-living lane (shorter lanes retire mid-horizon inside
        the fused step, masked on device) and rounded down to a power of
        two so the executor's jit cache stays O(log max_horizon).
        """
        if self.cfg.max_horizon <= 1 or not self.running:
            return 1
        if self.queue or self.swapped:
            return 1
        k = min(
            self.cfg.max_horizon,
            max(self._steps_until_retire(r) for r in self.running.values()),
        )
        return 1 << (k.bit_length() - 1)

    def grow_horizon(self, horizon: int) -> int:
        """Pre-fault every page a K-step fused decode will touch, as ONE
        all-or-nothing batched allocation (one dirty-row flush when the
        executor syncs).  Returns the horizon actually in effect: under
        pool pressure (or a reach breach) it collapses to 1 and the
        per-step fault path — :meth:`grow_running`, with its preemption
        fallback — reproduces pre-horizon behavior exactly."""
        if horizon <= 1:
            self.grow_running()
            return 1
        grows: list[tuple[int, int]] = []
        for req_id, r in self.running.items():
            steps = min(horizon, self._steps_until_retire(r))
            # a retiring lane's FINAL sampled token is never mapped (it
            # retires inside commit_decode), hence the -1
            target = r.total_len + steps - 1
            grow = target - self.vmem.seq_len(req_id)
            if grow > 0:
                grows.append((req_id, grow))
        try:
            faults = self.vmem.append_tokens_batch(grows)
        except (OutOfPagesError, ValueError):
            self.counters.inc("horizon_collapses")
            self.grow_running()
            return 1
        if faults:
            self.counters.inc("page_faults", len(faults))
            self.counters.inc(
                "modeled_fault_cycles",
                len(faults) * (self.cost.ptw_cycles
                               + self.cost.post_fault_flush_cycles),
            )
        return horizon

    def plan_decode(self) -> DecodePlan | None:
        """One call per engine step: pick the horizon, fault in every page
        it needs, and build the decode plan (what ``Engine.step`` drives)."""
        k = self.grow_horizon(self.plan_horizon())
        return self.decode_plan(k)

    def decode_plan(self, horizon: int = 1) -> DecodePlan | None:
        if not self.running:
            return None  # everything got preempted this step
        b = self.cfg.max_batch
        sample = next(iter(self.running.values())).output[-1]
        tokens = np.zeros((b,) + np.shape(sample), np.int32)
        pre_lens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        steps_left = np.zeros((b,), np.int32)
        for req_id, r in self.running.items():
            slot = self.slot_of[req_id]
            tokens[slot] = r.output[-1]
            pre_lens[slot] = r.total_len - 1   # position of the new token
            active[slot] = True
            steps_left[slot] = min(horizon, self._steps_until_retire(r))
        return DecodePlan(tokens=tokens, pre_lens=pre_lens, active=active,
                          horizon=horizon, steps_left=steps_left)

    def commit_decode(self, sampled: np.ndarray, horizon: int = 1) -> None:
        """Append sampled tokens (indexed by slot), retire finished
        requests.

        ``horizon == 1``: ``sampled`` is the single-step ``[B, ...]`` slot
        array.  ``horizon > 1``: ``sampled`` is the fused ``[K, B, ...]``
        token block; it is committed step-major — inner step t for every
        lane before step t+1 — so retirement order (and therefore the
        slot/frame free order the allocator sees) matches a K=1 run
        exactly.  A lane stops consuming the block the moment it retires;
        later block rows for that slot are device scratch output.
        """
        block = sampled if horizon > 1 else [sampled]
        for t in range(horizon):
            if not self.running:
                break
            if t:
                # the fused dispatch compressed K token-steps into one
                # engine step; advance the scheduler's logical clock per
                # inner step so step_i, the 100 Hz tick accounting and
                # run() budgets stay in TOKEN-steps — identical to a K=1
                # run of the same workload
                self.begin_step()
            self.counters.inc("decode_tokens", len(self.running))
            self.counters.inc("decode_translations", len(self.running))
            now = time.perf_counter()
            for req_id in list(self.running):
                r = self.running[req_id]
                slot = self.slot_of[req_id]
                r.output.append(np.asarray(block[t][slot]))
                # SLO timing capture point: the host-visible commit of
                # this token — stamped BEFORE the async stream push, so
                # detokenize lag cannot skew TTFT/TPOT
                self._stamp_commit(r, now)
                retired = len(r.output) >= r.max_new_tokens
                if retired:
                    r.status = "done"
                    self.done[req_id] = r
                    del self.running[req_id]
                    del self.slot_of[req_id]
                    self.vmem.unmap_seq(req_id)
                    self.counters.inc("completed")
                    self.counters.snapshot("done", req_id)
                self._emit(r, r.output[-1], final=retired)
