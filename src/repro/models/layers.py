"""Shared pure-JAX layers (no flax): norms, RoPE/M-RoPE, GQA attention, MLP.

Conventions:
  * params are plain dict pytrees of jnp arrays;
  * every layer is an (init, apply) pair of pure functions;
  * compute dtype follows the input; normalization and softmax statistics
    accumulate in f32; RoPE tables are built in f32;
  * weight layouts put the sharded dimension last where possible so the
    `model` mesh axis lands on contiguous memory (DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

Params = dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE + M-RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim // 2], f32."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,            # [..., S, H, D]
    positions: jax.Array,    # [..., S]  (broadcastable)
    theta: float,
) -> jax.Array:
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,            # [B, S, H, D]
    positions: jax.Array,    # [3, B, S]  (temporal, height, width)
    theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the D/2 frequency slots are split into
    t/h/w sections, each rotated by its own position stream.  Text tokens
    carry identical t==h==w positions, reducing to standard RoPE."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                       # [D/2]
    ang_thw = positions[..., None].astype(jnp.float32) * inv  # [3, B, S, D/2]
    sel = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )                                                # [D/2] -> which stream
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_thw, 0, -1),                # [B, S, D/2, 3]
        sel[None, None, :, None], axis=-1,
    )[..., 0]                                        # [B, S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def attention_init(key, cfg, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def qkv_project(params: Params, x: jax.Array, cfg) -> tuple[jax.Array, ...]:
    """x [B, S, D] -> q [B, S, H, hd], k/v [B, S, Hkv, hd]."""
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def attention_train(
    params: Params,
    x: jax.Array,             # [B, S, D]
    positions: jax.Array,     # [B, S] or [3, B, S] for mrope
    cfg,
    *,
    use_kernel: bool = False,
    window: int | None = None,
) -> jax.Array:
    """Full (or sliding-window) causal self-attention for train/prefill."""
    q, k, v = qkv_project(params, x, cfg)
    if cfg.mrope_sections:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    qt, kt, vt = (t.swapaxes(1, 2) for t in (q, k, v))  # [B, H, S, hd]
    s_len = x.shape[1]
    if use_kernel and window is None:
        o = ops.flash_attention(qt, kt, vt, causal=True)
    elif s_len > 1024 or window is not None:
        # chunked online-softmax with flash custom-VJP: never materializes
        # [Sq, Sk] in either pass, O(Sq) backward residuals
        o = ref.chunked_attention_flashbwd_ref(
            qt, kt, vt, causal=True, window=window
        )
    else:
        o = ref.flash_attention_ref(qt, kt, vt, causal=True)
    b, s = x.shape[:2]
    return o.swapaxes(1, 2).reshape(b, s, -1) @ params["wo"]


def _windowed_attention(q, k, v, window: int) -> jax.Array:
    """Causal attention restricted to the last `window` keys (RG local)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, d)
    scores = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d ** -0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = (q_pos >= k_pos) & (q_pos - k_pos < window)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, s, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_init(key, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d, f, dtype),
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params[
        "w_down"
    ]


# ---------------------------------------------------------------------------
# LM head + loss
# ---------------------------------------------------------------------------


def softmax_xent(
    logits: jax.Array,     # [B, S, V]
    labels: jax.Array,     # [B, S] int32
    mask: jax.Array | None = None,
) -> jax.Array:
    """Mean causal-LM cross entropy, f32 statistics."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
